//! End-to-end validation driver (DESIGN.md §End-to-end validation).
//!
//! Proves all three layers compose on a real small workload:
//!   L1/L2  JAX+Pallas artifacts (fw_step / eig_topd / project), AOT-
//!          lowered by `make artifacts`, executed from rust via PJRT
//!          during projection training and database projection;
//!   L3     the rust coordinator serving batched requests over the
//!          Vamana + LVQ search-and-rerank index.
//!
//! Workload: a synthetic rqa-768-style question-answering dataset
//! (OOD queries), 20k x 768 by default. Reports build breakdown,
//! QPS / p50 / p99 latency and recall@10; the run is recorded in
//! EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`
//! Flags: --n N --queries Q --workers W --no-pjrt

use leanvec::config::{Compression, ProjectionKind};
use leanvec::coordinator::{BatchPolicy, Engine, EngineConfig, QueryProjectorKind};
use leanvec::data::gt::ground_truth;
use leanvec::data::synth::{generate, SynthSpec};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::SearchParams;
use leanvec::leanvec::model::TrainBackends;
use leanvec::runtime::{default_artifacts_dir, PjrtFwStepper, PjrtProjector, PjrtTopd};
use leanvec::util::cli::Args;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let n = args.usize("n", 20_000);
    let n_queries = args.usize("queries", 4_000);
    let workers = args.usize("workers", 0);
    let use_pjrt = !args.switch("no-pjrt");
    let k = 10;

    // ---- dataset: rqa-768-style OOD (question vs answer encoders)
    let mut spec = SynthSpec::ood("rqa-768-e2e", 768, n, 1_000);
    spec.seed = 0xE2E;
    let ds = generate(&spec);
    println!(
        "[e2e] dataset {}: {} x {} ({}), OOD queries",
        ds.name,
        ds.database.len(),
        ds.dim,
        ds.similarity.name()
    );

    // ---- build through the PJRT artifacts (L1+L2 on the build path)
    let mut builder = IndexBuilder::new()
        .projection(ProjectionKind::OodEigSearch)
        .target_dim(160)
        .primary(Compression::Lvq8)
        .secondary(Compression::F16);
    let mut pjrt_used = false;
    if use_pjrt {
        match leanvec::runtime::executor::open_shared(&default_artifacts_dir()) {
            Ok(rt) => {
                builder = builder
                    .backends(TrainBackends {
                        fw: Box::new(PjrtFwStepper::new(rt.clone())),
                        topd: Box::new(PjrtTopd::new(rt.clone())),
                    })
                    .projector(Box::new(PjrtProjector::new(rt)));
                pjrt_used = true;
                println!("[e2e] training + projection through PJRT artifacts");
            }
            Err(e) => println!("[e2e] PJRT unavailable ({e}); native build path"),
        }
    }
    let t_build = std::time::Instant::now();
    let index = Arc::new(builder.build(&ds.database, Some(&ds.learn_queries), ds.similarity));
    let b = index.build_breakdown;
    println!(
        "[e2e] built in {:.1}s: train {:.1}s | project {:.1}s | quantize {:.1}s | graph {:.1}s",
        t_build.elapsed().as_secs_f64(),
        b.train_seconds,
        b.project_seconds,
        b.quantize_seconds,
        b.graph_seconds
    );
    println!(
        "[e2e] primary: {} B/vec -> {:.1}x compression vs FP16 full-D (paper: 9.6x at 768->160)",
        index.primary.bytes_per_vector(),
        index.primary_compression_vs_fp16()
    );

    // ---- ground truth for the test queries
    println!("[e2e] computing exact ground truth...");
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);

    // ---- serve a batched workload through the coordinator
    let queries: Vec<Vec<f32>> = (0..n_queries)
        .map(|i| ds.test_queries[i % ds.test_queries.len()].clone())
        .collect();
    let truth_rep: Vec<Vec<u32>> = (0..n_queries)
        .map(|i| truth[i % truth.len()].clone())
        .collect();
    let cfg = EngineConfig {
        workers: if workers == 0 { 1 } else { workers },
        batch: BatchPolicy {
            max_batch: 64,
            max_wait: std::time::Duration::from_micros(300),
        },
        search: SearchParams {
            window: 60,
            rerank_window: 60,
        },
        projector: QueryProjectorKind::Native,
        ..EngineConfig::default()
    };
    println!("[e2e] serving {n_queries} requests...");
    let (_responses, report) =
        Engine::run_workload(Arc::clone(&index), cfg, &queries, k, Some(&truth_rep));
    println!("[e2e] {}", report.metrics);
    println!("[e2e] recall@{k} = {:.3}", report.recall_at_k);
    println!(
        "[e2e] layers composed: artifacts({}) -> index -> coordinator OK",
        if pjrt_used { "pjrt" } else { "native-fallback" }
    );
    anyhow::ensure!(report.recall_at_k > 0.8, "e2e recall too low");
    Ok(())
}
