//! Fig.-7-style comparison at example scale: SVS-LeanVec vs SVS-LVQ vs
//! Vamana(f32) vs HNSW vs IVF-PQ on one OOD dataset, printing the
//! QPS-recall frontier of each.
//!
//! Run: `cargo run --release --example compare_baselines`

use leanvec::config::{Compression, ProjectionKind, Similarity};
use leanvec::data::gt::{ground_truth, recall_at_k};
use leanvec::data::synth::{generate, Dataset, SynthSpec};
use leanvec::graph::beam::SearchCtx;
use leanvec::index::builder::{build_hnsw_baseline, IndexBuilder};
use leanvec::index::ivfpq::{IvfPqIndex, IvfPqParams};
use leanvec::index::query::{Query, VectorIndex};
use std::time::Instant;

/// One generic sweep serves every arm through the `VectorIndex` trait:
/// for the graph indexes the `Query` window is the search buffer, for
/// IVF-PQ it is `nprobe`.
fn sweep<I: VectorIndex>(
    name: &str,
    index: &I,
    windows: &[usize],
    ds: &Dataset,
    truth: &[Vec<u32>],
    k: usize,
) {
    let mut ctx = SearchCtx::new(index.len());
    for &w in windows {
        let t0 = Instant::now();
        let got: Vec<Vec<u32>> = ds
            .test_queries
            .iter()
            .map(|q| index.search(&mut ctx, &Query::new(q).k(k).window(w)).ids)
            .collect();
        let qps = ds.test_queries.len() as f64 / t0.elapsed().as_secs_f64();
        let r = recall_at_k(&got, truth, k);
        println!("{name:<14} {w:>8} {r:>10.3} {qps:>8.0}");
    }
}

fn main() {
    let ds = generate(&SynthSpec::ood("compare", 256, 8_000, 400));
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let windows = [10usize, 20, 40, 80, 160];

    println!("dataset: {} x {} ({} queries)", ds.database.len(), ds.dim, ds.test_queries.len());
    println!("\n{:<14} {:>8} {:>10} {:>8}", "method", "window", "recall@10", "QPS");

    // --- SVS-LeanVec (OOD projection 256->96, LVQ8 + FP16 rerank)
    let leanvec = IndexBuilder::new()
        .projection(ProjectionKind::OodEigSearch)
        .target_dim(96)
        .primary(Compression::Lvq8)
        .secondary(Compression::F16)
        .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
    // --- SVS-LVQ (no reduction, LVQ4x8)
    let lvq = IndexBuilder::new()
        .projection(ProjectionKind::None)
        .primary(Compression::Lvq4x8)
        .secondary(Compression::F16)
        .build(&ds.database, None, ds.similarity);
    // --- plain Vamana on f32
    let vamana = IndexBuilder::new()
        .projection(ProjectionKind::None)
        .primary(Compression::F32)
        .secondary(Compression::F32)
        .build(&ds.database, None, ds.similarity);

    for (name, index) in [("svs-leanvec", &leanvec), ("svs-lvq", &lvq), ("vamana-f32", &vamana)] {
        sweep(name, index, &windows, &ds, &truth, k);
    }

    // --- HNSW baseline (window = ef)
    let hnsw = build_hnsw_baseline(&ds.database, Similarity::InnerProduct, Compression::F16, 5);
    sweep("hnsw", &hnsw, &windows, &ds, &truth, k);

    // --- IVF-PQ baseline (window = nprobe)
    let ivf = IvfPqIndex::build(
        &ds.database,
        IvfPqParams {
            nlist: 90,
            m: 8,
            ksub: 256,
            kmeans_iters: 8,
        },
        Similarity::InnerProduct,
        7,
    );
    sweep("faiss-ivfpq", &ivf, &[1usize, 4, 8, 16, 32], &ds, &truth, k);

    println!("\nExpected shape (paper Fig. 7): svs-leanvec dominates at high");
    println!("recall; svs-lvq second; graph methods beat IVF-PQ at high recall.");
}
