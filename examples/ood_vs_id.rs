//! The paper's core claim, demonstrated: on out-of-distribution
//! queries, query-aware dimensionality reduction (LeanVec-OOD, both
//! optimizers) beats database-only PCA (LeanVec-ID) — and on ID queries
//! the two coincide (Proposition 1's seamless fallback).
//!
//! Run: `cargo run --release --example ood_vs_id`

use leanvec::config::ProjectionKind;
use leanvec::data::gt::{ground_truth, recall_at_k};
use leanvec::data::synth::{generate, QueryDist, SynthSpec};
use leanvec::index::flat::FlatIndex;
use leanvec::leanvec::eigsearch::{eigsearch, NativeTopd};
use leanvec::leanvec::loss::ood_loss;
use leanvec::leanvec::model::{rows_to_matrix, train_projection, TrainBackends};
use leanvec::leanvec::pca::pca;

fn brute_recall(
    ds: &leanvec::data::synth::Dataset,
    model: &leanvec::leanvec::model::LeanVecModel,
    k: usize,
    truth: &[Vec<u32>],
) -> f64 {
    // exhaustive search in the reduced space + exact rerank of 5k
    let reduced = model.project_database(&ds.database);
    let flat_r = FlatIndex::new(&reduced, ds.similarity);
    let flat_f = FlatIndex::new(&ds.database, ds.similarity);
    let got: Vec<Vec<u32>> = ds
        .test_queries
        .iter()
        .map(|q| {
            let qp = model.project_query(q);
            let (cands, _) = flat_r.search(&qp, 5 * k);
            let mut scored: Vec<(f32, u32)> = cands
                .iter()
                .map(|&id| (flat_f.score_one(q, id), id))
                .collect();
            scored.sort_by(|a, b| b.0.total_cmp(&a.0));
            scored.into_iter().take(k).map(|(_, id)| id).collect()
        })
        .collect();
    recall_at_k(&got, truth, k)
}

fn run_case(name: &str, queries: QueryDist) {
    let spec = SynthSpec {
        name: name.to_string(),
        dim: 256,
        n: 6_000,
        n_learn_queries: 512,
        n_test_queries: 256,
        similarity: leanvec::config::Similarity::InnerProduct,
        queries,
        decay: 0.6,
        seed: 0x0DD,
    };
    let ds = generate(&spec);
    let d = 64;
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);

    let kx = rows_to_matrix(&ds.database).second_moment();
    let kq = rows_to_matrix(&ds.learn_queries).second_moment();

    println!("\n=== {name} (d = {d}, D = {}) ===", ds.dim);
    let p_id = pca(&kx, d);
    let loss_id = ood_loss(&p_id, &p_id, &kq, &kx);
    let es = eigsearch(&kq, &kx, d, &mut NativeTopd);
    println!(
        "loss: LeanVec-ID (PCA) {loss_id:.4e} | LeanVec-OOD (ES, beta={:.2}) {:.4e}",
        es.beta, es.loss
    );
    assert!(es.loss <= loss_id * (1.0 + 1e-6), "Prop. 1 violated");

    let mut backends = TrainBackends::default();
    for kind in [
        ProjectionKind::Id,
        ProjectionKind::OodEigSearch,
        ProjectionKind::OodFrankWolfe,
        ProjectionKind::Random,
    ] {
        let model = train_projection(
            kind,
            &ds.database,
            Some(&ds.learn_queries),
            d,
            &mut backends,
            1,
        );
        let r = brute_recall(&ds, &model, k, &truth);
        println!("  {:<16} recall@{k} (exhaustive+rerank) = {r:.3}", kind.name());
    }
}

fn main() {
    run_case("in-distribution", QueryDist::InDistribution);
    run_case("out-of-distribution", QueryDist::OutOfDistribution(0.8));
    println!("\nExpected shape: ID case — all learners comparable;");
    println!("OOD case — leanvec-ood-* > leanvec-id > random.");
}
