//! Quickstart: build a LeanVec index over a synthetic OOD dataset,
//! search it through the unified `Query` -> `VectorIndex` ->
//! `SearchResult` API, and print recall — the 60-second tour.
//!
//! Run: `cargo run --release --example quickstart`
//! Flags (CI smokes a tiny configuration): --n N --dim D --target-dim d
//!        --queries Q --window W

use leanvec::config::{Compression, ProjectionKind};
use leanvec::data::gt::{ground_truth, recall_at_k};
use leanvec::data::synth::{generate, SynthSpec};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::query::{Query, VectorIndex};
use leanvec::util::cli::Args;

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let n = args.usize("n", 5_000);
    let dim = args.usize("dim", 256);
    let target_dim = args.usize("target-dim", (dim / 2).clamp(2, 96));
    let n_queries = args.usize("queries", 200);
    let window = args.usize("window", 60);

    // 1. A synthetic cross-modal-style dataset with out-of-distribution
    //    queries (text-vs-image style).
    let ds = generate(&SynthSpec::ood("quickstart", dim, n, n_queries));
    println!(
        "dataset: {} vectors x {} dims, {} learn + {} test queries ({})",
        ds.database.len(),
        ds.dim,
        ds.learn_queries.len(),
        ds.test_queries.len(),
        ds.similarity.name()
    );

    // 2. Build: LeanVec-OOD projection dim -> target_dim, LVQ8 primaries
    //    for graph traversal, FP16 secondaries for re-ranking.
    let index = IndexBuilder::new()
        .projection(ProjectionKind::OodEigSearch)
        .target_dim(target_dim)
        .primary(Compression::Lvq8)
        .secondary(Compression::F16)
        .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
    let b = index.build_breakdown;
    println!(
        "built in {:.2}s (train {:.2}s, graph {:.2}s); primary {} B/vec = {:.1}x vs FP16",
        b.total(),
        b.train_seconds,
        b.graph_seconds,
        index.primary.bytes_per_vector(),
        index.primary_compression_vs_fp16()
    );

    // 3. Search with re-ranking and measure recall against brute force.
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let got: Vec<Vec<u32>> = ds
        .test_queries
        .iter()
        .map(|q| index.search_one(&Query::new(q).k(k).window(window)).ids)
        .collect();
    let recall = recall_at_k(&got, &truth, k);
    println!("recall@{k} = {recall:.3} at search window {window}");
    assert!(recall > 0.8, "quickstart recall unexpectedly low: {recall}");

    // 4. One query end to end: builder -> search -> SearchResult.
    //    Split buffer: re-rank 3x the window without widening traversal.
    let result = index.search_one(
        &Query::new(&ds.test_queries[0])
            .k(5)
            .window(window)
            .rerank_window(window * 3),
    );
    println!("top-5 for query 0: {:?}", result.ids);
    println!("scores:           {:?}", result.scores);
    println!(
        "stats: scored {} | reranked {} | {} bytes | {} hops",
        result.stats.primary_scored,
        result.stats.reranked,
        result.stats.bytes_touched,
        result.stats.hops
    );

    // 5. Filtered search: only even ids may be returned; the predicate
    //    is pushed into traversal, so excluded ids are never re-ranked.
    let even_only = |id: u32| id % 2 == 0;
    let filtered = index.search_one(
        &Query::new(&ds.test_queries[0])
            .k(5)
            .window(window)
            .filter(&even_only),
    );
    assert!(filtered.ids.iter().all(|id| id % 2 == 0));
    println!(
        "filtered top-5 (even ids only): {:?} ({} candidates filtered out)",
        filtered.ids, filtered.stats.filtered
    );
}
