//! Quickstart: build a LeanVec index over a synthetic OOD dataset,
//! search it, and print recall — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use leanvec::config::{Compression, ProjectionKind};
use leanvec::data::gt::{ground_truth, recall_at_k};
use leanvec::data::synth::{generate, SynthSpec};
use leanvec::index::builder::IndexBuilder;

fn main() {
    // 1. A synthetic cross-modal-style dataset: 5k database vectors in
    //    256 dims, out-of-distribution queries (text-vs-image style).
    let ds = generate(&SynthSpec::ood("quickstart", 256, 5_000, 200));
    println!(
        "dataset: {} vectors x {} dims, {} learn + {} test queries ({})",
        ds.database.len(),
        ds.dim,
        ds.learn_queries.len(),
        ds.test_queries.len(),
        ds.similarity.name()
    );

    // 2. Build: LeanVec-OOD projection 256 -> 96, LVQ8 primaries for
    //    graph traversal, FP16 secondaries for re-ranking.
    let index = IndexBuilder::new()
        .projection(ProjectionKind::OodEigSearch)
        .target_dim(96)
        .primary(Compression::Lvq8)
        .secondary(Compression::F16)
        .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
    let b = index.build_breakdown;
    println!(
        "built in {:.2}s (train {:.2}s, graph {:.2}s); primary {} B/vec = {:.1}x vs FP16",
        b.total(),
        b.train_seconds,
        b.graph_seconds,
        index.primary.bytes_per_vector(),
        index.primary_compression_vs_fp16()
    );

    // 3. Search with re-ranking and measure recall against brute force.
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let got: Vec<Vec<u32>> = ds
        .test_queries
        .iter()
        .map(|q| index.search(q, k, 60).0)
        .collect();
    let recall = recall_at_k(&got, &truth, k);
    println!("recall@{k} = {recall:.3} at search window 60");
    assert!(recall > 0.8, "quickstart recall unexpectedly low: {recall}");

    // 4. One query end to end.
    let (ids, scores) = index.search(&ds.test_queries[0], 5, 60);
    println!("top-5 for query 0: {ids:?}");
    println!("scores:           {scores:?}");
}
