//! Offline stand-in for the `anyhow` crate: the subset of its API this
//! workspace uses (`Error`, `Result`, `anyhow!`, `ensure!`, `Context`),
//! implemented over a plain message chain. The build image has no
//! network access, so the real crate cannot be fetched; this shim keeps
//! the same call sites compiling and behaving equivalently for error
//! construction, context wrapping and `{:#}` chain formatting.

use std::fmt;

/// A dynamic error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message (what `Context` adds).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like anyhow
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// The blanket conversion that makes `?` work on any std error. Legal
// because `Error` itself deliberately does NOT implement
// `std::error::Error` (the same trick the real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-wrapping extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Return early with the given error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn macro_formats() {
        let name = "x";
        let e = anyhow!("bad thing: {name}");
        assert_eq!(format!("{e}"), "bad thing: x");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(format!("{e}"), "1 and 2");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn context_wraps_outermost() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "loading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing");
    }

    #[test]
    fn ensure_returns_error() {
        fn f(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            Ok(v)
        }
        assert!(f(5).is_ok());
        assert_eq!(format!("{}", f(20).unwrap_err()), "v too big: 20");
    }

    #[test]
    fn option_context() {
        let v: Option<usize> = None;
        let e = v.context("absent").unwrap_err();
        assert_eq!(format!("{e}"), "absent");
    }
}
