//! Compile-time stub for the `xla` crate (the xla_extension PJRT
//! bindings). The real bindings link the xla_extension C++ runtime,
//! which is not present in the offline build image. This stub mirrors
//! the API surface `leanvec::runtime` uses so the crate compiles;
//! [`PjRtClient::cpu`] always errors, so every caller takes its
//! documented native fallback and the PJRT integration tests skip.

use std::fmt;

/// Error type matching the `{e:?}`-style formatting call sites use.
#[derive(Clone)]
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "xla runtime unavailable: this build uses the offline stub".to_string(),
    ))
}

/// Tensor element types the manifest layer distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U8,
}

/// PJRT CPU client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// A compiled executable (unreachable in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// A device buffer (unreachable in the stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Parsed HLO module (stub: parsing always fails).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (stub: carries no data).
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn get_first_element<T>(&self) -> Result<T, Error> {
        unavailable()
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn ty(&self) -> Result<ElementType, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_errors_cleanly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("offline stub"));
    }

    #[test]
    fn literal_constructors_error() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0; 16])
            .is_err());
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
    }
}
