"""Layer-2: the JAX computation graphs that get AOT-lowered to artifacts.

Every function here is a jit-able composition of the Layer-1 Pallas
kernels (python/compile/kernels/). aot.py lowers them for a fixed set of
(D, d, batch) shapes and the rust runtime executes the resulting HLO via
PJRT — Python never runs at serve time.

Exported computations (names match artifacts/manifest.json entries):
  fw_step     — one LeanVec-OOD Frank-Wolfe BCD iteration (Algorithm 1)
  eig_topd    — top-d eigenbasis of K_beta (Algorithm 2 inner step)
  project     — batch projection Y = P X (database or query batches)
  score_batch — fused LVQ dequant+dot scoring for a candidate block
"""

import jax.numpy as jnp

from .kernels.fw_step import (
    eig_topd,
    eig_topd_xla,
    fw_step,
    fw_step_xla,
    loss,
    polar,
)
from .kernels.lvq_dot import lvq_dot
from .kernels.matmul import pmatmul

__all__ = [
    "fw_step",
    "fw_step_xla",
    "eig_topd",
    "eig_topd_xla",
    "project",
    "score_batch",
    "loss_full",
    "polar",
]


def project(p, x):
    """Y = P X. p: (d, D); x: (D, B) column-stacked vectors."""
    return pmatmul(p, x)


def score_batch(codes, delta, lo, q, qstats):
    """Fused LVQ scores for one query against a block of primary vectors."""
    return lvq_dot(codes, delta, lo, q, qstats)


def loss_full(a, b, kq, kx):
    """Absolute LeanVec-OOD loss ||Q^T A^T B X - Q^T X||_F^2 (Eq. 8)."""
    const = jnp.sum(kq * kx.T)  # Tr(Kq Kx)
    return loss(a, b, kq, kx) + const
