"""Pure-jnp oracles for every Pallas kernel and L2 composite.

These are the correctness ground truth: python/tests/ asserts
`assert_allclose(kernel(...), ref(...))` over hypothesis-generated shape
and value sweeps. Nothing here is ever lowered to an artifact.
"""

import jax.numpy as jnp
import numpy as np


def ref_matmul(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def ref_lvq_dot(codes, delta, lo, q, qstats):
    """<q, x_i> for LVQ-coded vectors; see lvq_dot.py for the factorization."""
    dots = codes.astype(jnp.float32) @ q[:, 0]
    return delta * dots + lo * qstats[0] + qstats[1]


def ref_grad_a(a, b, kq, kx):
    """Eq. (13): d/dA f = 2 B Kx B^T A Kq - 2 B Kx Kq."""
    bkx = b @ kx
    return 2.0 * (bkx @ b.T @ a @ kq) - 2.0 * (bkx @ kq)


def ref_grad_b(a, b, kq, kx):
    """Eq. (13): d/dB f = 2 A Kq A^T B Kx - 2 A Kq Kx."""
    akq = a @ kq
    return 2.0 * (akq @ a.T @ b @ kx) - 2.0 * (akq @ kx)


def ref_loss(a, b, kq, kx):
    """Eq. (8): Tr(A Kq A^T B Kx B^T + Kq Kx - 2 Kq A^T B Kx)."""
    t1 = jnp.trace(a @ kq @ a.T @ b @ kx @ b.T)
    t2 = jnp.trace(kq @ kx)
    t3 = jnp.trace(kq @ a.T @ b @ kx)
    return t1 + t2 - 2.0 * t3


def ref_polar(c):
    """Orthogonal polar factor U V^T of a (d, D) matrix (Jaggi 2013 LMO)."""
    u, _, vt = np.linalg.svd(np.asarray(c, dtype=np.float64), full_matrices=False)
    return jnp.asarray(u @ vt, dtype=jnp.float32)


def ref_topd(k, d):
    """(d, D) matrix of the top-d eigenvectors of symmetric PSD k."""
    w, v = np.linalg.eigh(np.asarray(k, dtype=np.float64))
    order = np.argsort(w)[::-1][:d]
    return jnp.asarray(v[:, order].T, dtype=jnp.float32)


def ref_fw_step(a, b, kq, kx, gamma):
    """One Algorithm-1 BCD iteration with an exact (SVD) linear oracle."""
    sa = ref_polar(-ref_grad_a(a, b, kq, kx))
    a1 = (1.0 - gamma) * a + gamma * sa
    sb = ref_polar(-ref_grad_b(a1, b, kq, kx))
    b1 = (1.0 - gamma) * b + gamma * sb
    return a1, b1, ref_loss(a1, b1, kq, kx)


def ref_project(p, x):
    return jnp.dot(p, x, preferred_element_type=jnp.float32)
