"""Layer-1/2 compute for the LeanVec-OOD Frank-Wolfe BCD step (Algorithm 1).

The paper computes the linear-minimization oracle over the spectral-norm
ball with a LAPACK SVD (S = U V^T of the negated gradient; Jaggi 2013).
A TPU has no SVD unit, so we rethink the oracle for the MXU
(DESIGN.md §Hardware-Adaptation): the orthogonal polar factor U V^T is
computed with a Newton-Schulz iteration — a fixed-length chain of
matmuls, each of which runs through the Pallas tiled-matmul kernel.

    X_0     = C / ||C||_F                      (spectral norm <= 1)
    X_{t+1} = 1.5 X_t - 0.5 X_t X_t^T X_t      (converges to polar(C))

Newton-Schulz converges for singular values in (0, sqrt(3)); the
Frobenius normalization guarantees that. Convergence is quadratic once
the spectrum approaches 1, and an *inexact* LMO is fine for Frank-Wolfe:
the convergence proof (Appendix C) only needs a descent direction, and
python/tests/ checks both orthonormality of the result and loss descent.
"""

import functools

import jax
import jax.numpy as jnp

from .matmul import pmatmul

# Fixed iteration count so the lowered HLO is static. 14 iterations takes
# a Frobenius-normalized spectrum to ~1 within f32 precision for the
# well-conditioned gradients seen in practice (tests cover this).
NEWTON_SCHULZ_ITERS = 14


def _jnp_mm(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def polar(c, *, iters=NEWTON_SCHULZ_ITERS, mm=pmatmul):
    """Orthogonal polar factor of a (d, D) matrix, matmul-only."""
    norm = jnp.sqrt(jnp.sum(c * c)) + 1e-30
    x = c / norm
    for _ in range(iters):
        xxt = mm(x, x.T)  # (d, d) — the small Gram side
        x = 1.5 * x - 0.5 * mm(xxt, x)
    return x


def grad_a(a, b, kq, kx, mm=pmatmul):
    """Eq. (13): d/dA f = 2 B Kx B^T A Kq - 2 B Kx Kq."""
    bkx = mm(b, kx)  # (d, D)
    lhs = mm(mm(mm(bkx, b.T), a), kq)
    return 2.0 * lhs - 2.0 * mm(bkx, kq)


def grad_b(a, b, kq, kx, mm=pmatmul):
    """Eq. (13): d/dB f = 2 A Kq A^T B Kx - 2 A Kq Kx."""
    akq = mm(a, kq)  # (d, D)
    lhs = mm(mm(mm(akq, a.T), b), kx)
    return 2.0 * lhs - 2.0 * mm(akq, kx)


def loss(a, b, kq, kx, mm=pmatmul):
    """Eq. (8) without the constant Tr(Kq Kx) term (added by callers that
    need the absolute Frobenius loss)."""
    akq = mm(a, kq)  # (d, D)
    bkx = mm(b, kx)  # (d, D)
    m1 = mm(akq, a.T)  # (d, d) = A Kq A^T
    m2 = mm(bkx, b.T)  # (d, d) = B Kx B^T
    t1 = jnp.sum(m1 * m2.T)  # Tr(A Kq A^T B Kx B^T)
    t3 = jnp.sum(akq * bkx)  # Tr(Kq A^T B Kx)
    return t1 - 2.0 * t3


def fw_step_impl(a, b, kq, kx, gamma, mm):
    """One Algorithm-1 BCD iteration.

    Args:
      a, b:   (d, D) current iterates (inside the spectral ball).
      kq, kx: (D, D) second-moment matrices Q Q^T and X X^T.
      gamma:  () step size 1/(t+1)^alpha.
      mm:     matmul primitive (pallas tile kernel or jnp.dot).

    Returns:
      (a_next, b_next, loss_next) — loss without the constant term.
    """
    sa = polar(-grad_a(a, b, kq, kx, mm), mm=mm)
    a1 = (1.0 - gamma) * a + gamma * sa
    sb = polar(-grad_b(a1, b, kq, kx, mm), mm=mm)
    b1 = (1.0 - gamma) * b + gamma * sb
    return a1, b1, loss(a1, b1, kq, kx, mm=mm)


# Pallas lowering: the TPU-targeted kernel (interpret=True on CPU is a
# correctness vehicle — its unfused while-loop HLO is slow on CPU).
fw_step = jax.jit(functools.partial(fw_step_impl, mm=pmatmul))
# XLA lowering: same math through jnp.dot, fused by XLA-CPU — the fast
# artifact the rust runtime executes on this testbed (EXPERIMENTS §Perf).
fw_step_xla = jax.jit(functools.partial(fw_step_impl, mm=_jnp_mm))


def eig_topd_impl(k, v0, iters, mm):
    """Top-d eigenbasis of a symmetric PSD (D, D) matrix via orthogonal
    (subspace) iteration, orthonormalizing with Newton-Schulz instead of
    QR so the HLO stays LAPACK-free:

        V <- orth(K V),  repeated `iters` times.

    Args:
      k:  (D, D) symmetric PSD.
      v0: (D, d) full-column-rank start basis (random from the caller).

    Returns:
      (d, D) row-orthonormal P spanning the top-d eigenspace.
    """
    v = v0
    for _ in range(iters):
        v = mm(k, v)  # (D, d)
        v = polar(v.T, mm=mm).T  # orthonormalize the columns
    return v.T


@functools.partial(jax.jit, static_argnames=("iters",))
def eig_topd(k, v0, *, iters=30):
    return eig_topd_impl(k, v0, iters, pmatmul)


@functools.partial(jax.jit, static_argnames=("iters",))
def eig_topd_xla(k, v0, *, iters=30):
    return eig_topd_impl(k, v0, iters, _jnp_mm)
