"""Layer-1 Pallas kernel: fused LVQ dequantization + batched inner product.

This is the paper's search hot-spot (Section 2, Eq. 1): scoring a block of
LVQ-compressed database vectors against one projected query. LVQ stores,
per database vector i, a u8/u4 code vector c_i plus two scalars
(delta_i, lo_i) such that

    x_i  ~  mu + c_i * delta_i + lo_i          (componentwise)

so the inner product factorizes into a single u8xf32 dot plus two scalar
fixups (the trick that makes LVQ fast on any hardware):

    <q, x_i>  ~  delta_i * <q, c_i>  +  lo_i * sum(q)  +  <q, mu>.

The kernel fuses the dequantization into the dot: codes stream in
(block_n x d) tiles, are widened to f32 on the VPU, and hit the MXU as a
(block_n x d) @ (d x 1) matmul. The query tile (plus its precomputed sum
and <q, mu>) is replicated across the grid via a constant index_map.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's AVX-512
VPMADDUBSW-style inner loop becomes a VMEM-tiled dequant feeding the
systolic array; block_n=256, d<=512 keeps the code tile under
256*512 = 128 KiB of VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lvq_dot_kernel(codes_ref, delta_ref, lo_ref, q_ref, qstats_ref, o_ref):
    codes = codes_ref[...].astype(jnp.float32)  # (bn, d) u8 -> f32 on VPU
    q = q_ref[...]  # (d, 1)
    dots = jnp.dot(codes, q, preferred_element_type=jnp.float32)[:, 0]
    q_sum = qstats_ref[0]
    q_mu = qstats_ref[1]
    o_ref[...] = delta_ref[...] * dots + lo_ref[...] * q_sum + q_mu


@functools.partial(jax.jit, static_argnames=("block_n",))
def lvq_dot(codes, delta, lo, q, qstats, *, block_n=256):
    """Fused LVQ scores for a block of vectors.

    Args:
      codes:  (n, d) uint8 LVQ codes, n a multiple of block_n.
      delta:  (n,) f32 per-vector quantization step.
      lo:     (n,) f32 per-vector lower bound.
      q:      (d, 1) f32 projected query.
      qstats: (2,) f32 = [sum(q), <q, mu>].

    Returns:
      (n,) f32 approximate inner products <q, x_i>.
    """
    n, d = codes.shape
    assert n % block_n == 0, (n, block_n)
    return pl.pallas_call(
        _lvq_dot_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(codes, delta, lo, q, qstats)
