"""Layer-1 Pallas kernel: tiled matrix multiplication.

This is the MXU-shaped workhorse for every L2 computation (Frank-Wolfe
gradients, subspace iteration, batch projection). The kernel follows the
canonical Pallas accumulate-over-k pattern: the grid is
(M/bm, N/bn, K/bk); the output block (whose index map is independent of
the k grid axis, so the same VMEM tile is revisited) is zeroed on the
first k-step and accumulated into on every step.

TPU adaptation notes (see DESIGN.md §Hardware-Adaptation):
  * block sizes default to 128x128x128 — one MXU systolic pass per step,
    3 * 128*128*4 B = 192 KiB of VMEM, far below the ~16 MiB budget, which
    leaves room for double-buffered HBM->VMEM prefetch.
  * `pmatmul` pads arbitrary shapes up to the block grid; padding with
    zeros is exact for matmul.

The kernel MUST run with interpret=True in this repo: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers the kernel
to plain HLO (while-loop over the grid) that the rust runtime executes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile; accumulates over the k grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulate; on real TPU the operands would be bf16 feeding the
    # MXU, but the CPU interpret path keeps f32 end to end.
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm=128, bn=128, bk=128):
    """`x @ y` for shapes that are exact multiples of the block sizes."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, y.shape)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, h: (i, h)),
            pl.BlockSpec((bk, bn), lambda i, j, h: (h, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, h: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _pad_to(x, rows, cols):
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


def _ceil_to(v, b):
    return -(-v // b) * b


def pmatmul(x, y, *, block=128):
    """Padded Pallas matmul for arbitrary (m, k) x (k, n) f32 operands.

    Zero-pads every dimension up to a multiple of the block size (exact
    for matmul), runs the tiled kernel, and slices the result back. This
    is the matmul primitive the L2 model uses for its large products.
    """
    m, k = x.shape
    _, n = y.shape
    bm = min(block, _ceil_to(m, 8))
    bn = min(block, _ceil_to(n, 8))
    bk = min(block, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = _pad_to(x.astype(jnp.float32), mp, kp)
    yp = _pad_to(y.astype(jnp.float32), kp, np_)
    out = matmul(xp, yp, bm=bm, bn=bn, bk=bk)
    return out[:m, :n]
