"""AOT lowering: JAX/Pallas (L1+L2) -> HLO text artifacts for the rust runtime.

Run once at build time (`make artifacts`):

    cd python && python -m compile.aot --out ../artifacts

Emits one `<name>.hlo.txt` per (computation, shape) pair plus
`manifest.json` describing inputs/outputs so the rust runtime
(rust/src/runtime/) can pick the right artifact and build literals.

Interchange format is HLO *text*, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published `xla` crate) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (D, d) projection shapes lowered by default. These cover the synthetic
# stand-ins for the paper's datasets (Table 1): rqa-768, open-images-512 /
# wit-512, deep-256, t2i-200.
DEFAULT_SHAPES = [(768, 160), (512, 128), (256, 96), (200, 128)]
PROJECT_DB_BATCH = 1024  # columns per database-projection dispatch
PROJECT_Q_BATCH = 64  # columns per query-projection dispatch
SCORE_BLOCK = 1024  # candidates per scoring dispatch


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dt(dtype):
    return {"float32": "f32", "uint8": "u8"}[jnp.dtype(dtype).name]


def _io_entry(spec):
    return {"shape": list(spec.shape), "dtype": _dt(spec.dtype)}


def build_plan(shapes):
    """Yield (name, fn, arg_specs, meta) for every artifact to lower."""
    for D, d in shapes:
        dd = _spec((d, D))
        DD = _spec((D, D))
        one = _spec((1,))

        def fw(a, b, kq, kx, gamma):
            return model.fw_step(a, b, kq, kx, gamma[0])

        yield (
            f"fw_step_D{D}_d{d}",
            fw,
            [dd, dd, DD, DD, one],
            {"fn": "fw_step", "D": D, "d": d},
        )

        def fw_xla(a, b, kq, kx, gamma):
            return model.fw_step_xla(a, b, kq, kx, gamma[0])

        # Same math, jnp.dot lowering: XLA-CPU fuses it, so this is the
        # variant the rust runtime prefers on this testbed (the pallas
        # variant is the TPU kernel; interpret-mode HLO is slow on CPU).
        yield (
            f"fw_step_xla_D{D}_d{d}",
            fw_xla,
            [dd, dd, DD, DD, one],
            {"fn": "fw_step_xla", "D": D, "d": d},
        )

        def eig(k, v0):
            return (model.eig_topd(k, v0),)

        yield (
            f"eig_topd_D{D}_d{d}",
            eig,
            [DD, _spec((D, d))],
            {"fn": "eig_topd", "D": D, "d": d},
        )

        def eig_xla(k, v0):
            return (model.eig_topd_xla(k, v0),)

        yield (
            f"eig_topd_xla_D{D}_d{d}",
            eig_xla,
            [DD, _spec((D, d))],
            {"fn": "eig_topd_xla", "D": D, "d": d},
        )

        def proj(p, x):
            return (model.project(p, x),)

        yield (
            f"project_db_D{D}_d{d}",
            proj,
            [dd, _spec((D, PROJECT_DB_BATCH))],
            {"fn": "project", "D": D, "d": d, "batch": PROJECT_DB_BATCH},
        )
        yield (
            f"project_q_D{D}_d{d}",
            proj,
            [dd, _spec((D, PROJECT_Q_BATCH))],
            {"fn": "project", "D": D, "d": d, "batch": PROJECT_Q_BATCH},
        )

        def score(codes, delta, lo, q, qstats):
            return (model.score_batch(codes, delta, lo, q, qstats),)

        yield (
            f"score_D{D}_d{d}",
            score,
            [
                _spec((SCORE_BLOCK, d), jnp.uint8),
                _spec((SCORE_BLOCK,)),
                _spec((SCORE_BLOCK,)),
                _spec((d, 1)),
                _spec((2,)),
            ],
            {"fn": "score_batch", "D": D, "d": d, "batch": SCORE_BLOCK},
        )


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument(
        "--shapes",
        default=",".join(f"{D}x{d}" for D, d in DEFAULT_SHAPES),
        help="comma-separated DxD_low pairs, e.g. 768x160,512x128",
    )
    args = parser.parse_args()

    shapes = []
    for tok in args.shapes.split(","):
        D, d = tok.lower().split("x")
        shapes.append((int(D), int(d)))

    os.makedirs(args.out, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    for name, fn, specs, meta in build_plan(shapes):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *specs)
        entry = {
            "name": name,
            "file": fname,
            "inputs": [_io_entry(s) for s in specs],
            "outputs": [_io_entry(s) for s in out_specs],
        }
        entry.update(meta)
        manifest["artifacts"].append(entry)
        print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
