"""Kernel vs pure-jnp-reference correctness — the core L1 signal.

hypothesis sweeps shapes/values; every Pallas kernel must match ref.py.
Interpret-mode Pallas is slow, so example counts are kept moderate but
the shape ranges cover the padding/tiling edge cases (non-multiples of
the block, tiny dims, tall/wide).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fw_step import fw_step, grad_a, grad_b, loss, polar
from compile.kernels.lvq_dot import lvq_dot
from compile.kernels.matmul import pmatmul

SETTINGS = dict(max_examples=12, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- matmul
@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31),
)
def test_pmatmul_matches_ref(m, k, n, seed):
    r = _rng(seed)
    x = r.normal(size=(m, k)).astype(np.float32)
    y = r.normal(size=(k, n)).astype(np.float32)
    got = np.asarray(pmatmul(jnp.asarray(x), jnp.asarray(y)))
    want = np.asarray(ref.ref_matmul(x, y))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_pmatmul_exact_blocks():
    """Shapes that are exact multiples of 128 take the unpadded path."""
    r = _rng(7)
    x = r.normal(size=(256, 128)).astype(np.float32)
    y = r.normal(size=(128, 384)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(pmatmul(jnp.asarray(x), jnp.asarray(y))),
        x @ y,
        rtol=2e-5,
        atol=2e-4,
    )


def test_pmatmul_identity():
    x = np.eye(50, dtype=np.float32)
    y = _rng(3).normal(size=(50, 77)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(pmatmul(jnp.asarray(x), jnp.asarray(y))), y, atol=1e-6
    )


# ---------------------------------------------------------------- lvq_dot
@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 3),
    d=st.integers(2, 160),
    seed=st.integers(0, 2**31),
)
def test_lvq_dot_matches_ref(nblocks, d, seed):
    r = _rng(seed)
    n = 256 * nblocks
    codes = r.integers(0, 256, size=(n, d)).astype(np.uint8)
    delta = r.uniform(1e-4, 1e-2, n).astype(np.float32)
    lo = (r.normal(size=n) * 0.01).astype(np.float32)
    q = r.normal(size=(d, 1)).astype(np.float32)
    qstats = np.array([q.sum(), r.normal()], dtype=np.float32)
    got = np.asarray(lvq_dot(*(jnp.asarray(v) for v in (codes, delta, lo, q, qstats))))
    want = np.asarray(ref.ref_lvq_dot(codes, delta, lo, q, qstats))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-4)


def test_lvq_dot_zero_codes():
    """All-zero codes reduce to lo*sum(q) + <q,mu>."""
    n, d = 256, 32
    codes = np.zeros((n, d), dtype=np.uint8)
    delta = np.full(n, 0.5, dtype=np.float32)
    lo = np.linspace(-1, 1, n).astype(np.float32)
    q = np.ones((d, 1), dtype=np.float32)
    qstats = np.array([float(d), 2.5], dtype=np.float32)
    got = np.asarray(lvq_dot(*(jnp.asarray(v) for v in (codes, delta, lo, q, qstats))))
    np.testing.assert_allclose(got, lo * d + 2.5, rtol=1e-6)


# ---------------------------------------------------------------- polar
@settings(**SETTINGS)
@given(d=st.integers(2, 48), D=st.integers(48, 160), seed=st.integers(0, 2**31))
def test_polar_orthonormal_rows(d, D, seed):
    c = _rng(seed).normal(size=(d, D)).astype(np.float32)
    # the production iteration count gives a loose bound (ill-conditioned
    # draws converge slowly; an inexact LMO is fine for Frank-Wolfe) ...
    p = np.asarray(polar(jnp.asarray(c)))
    np.testing.assert_allclose(p @ p.T, np.eye(d), atol=5e-2)
    # ... and more iterations must tighten it (convergence property)
    p = np.asarray(polar(jnp.asarray(c), iters=28))
    np.testing.assert_allclose(p @ p.T, np.eye(d), atol=5e-3)


@settings(**SETTINGS)
@given(d=st.integers(2, 32), D=st.integers(32, 128), seed=st.integers(0, 2**31))
def test_polar_is_lmo_over_spectral_ball(d, D, seed):
    """The Newton-Schulz polar factor must be a near-exact linear
    minimization oracle: <S, C> within 1% of the nuclear norm of C
    (the optimum over the spectral-norm unit ball, Jaggi 2013)."""
    c = _rng(seed).normal(size=(d, D)).astype(np.float32)
    s = np.asarray(polar(jnp.asarray(c)))
    nuc = np.linalg.svd(c.astype(np.float64), compute_uv=False).sum()
    assert float((s * c).sum()) >= 0.99 * nuc


def test_polar_of_orthonormal_is_identity_map():
    r = _rng(11)
    q, _ = np.linalg.qr(r.normal(size=(64, 24)))
    c = q.T.astype(np.float32)  # already row-orthonormal
    p = np.asarray(polar(jnp.asarray(c)))
    np.testing.assert_allclose(p, c, atol=5e-3)


# ---------------------------------------------------------------- gradients / loss
def _problem(seed, D=96, d=24, n=500, m=300):
    r = _rng(seed)
    X = r.normal(size=(D, n)).astype(np.float32)
    Q = r.normal(size=(D, m)).astype(np.float32)
    kx = (X @ X.T / n).astype(np.float32)
    kq = (Q @ Q.T / m).astype(np.float32)
    a = np.linalg.qr(r.normal(size=(D, d)))[0].T.astype(np.float32)
    b = np.linalg.qr(r.normal(size=(D, d)))[0].T.astype(np.float32)
    return a, b, kq, kx


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31))
def test_grads_match_ref(seed):
    a, b, kq, kx = _problem(seed)
    np.testing.assert_allclose(
        np.asarray(grad_a(*map(jnp.asarray, (a, b, kq, kx)))),
        np.asarray(ref.ref_grad_a(a, b, kq, kx)),
        rtol=1e-3,
        atol=1e-3,
    )
    np.testing.assert_allclose(
        np.asarray(grad_b(*map(jnp.asarray, (a, b, kq, kx)))),
        np.asarray(ref.ref_grad_b(a, b, kq, kx)),
        rtol=1e-3,
        atol=1e-3,
    )


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31))
def test_loss_matches_ref(seed):
    a, b, kq, kx = _problem(seed)
    got = float(loss(*map(jnp.asarray, (a, b, kq, kx))))
    const = float(np.trace(kq @ kx))
    want = float(ref.ref_loss(a, b, kq, kx))
    np.testing.assert_allclose(got + const, want, rtol=2e-3)


def test_loss_is_frobenius_norm():
    """Eq. (8) trace form == the direct ||Q^T A^T B X - Q^T X||_F^2 / (nm) form."""
    r = _rng(5)
    D, d, n, m = 48, 12, 200, 100
    X = r.normal(size=(D, n)).astype(np.float32)
    Q = r.normal(size=(D, m)).astype(np.float32)
    a = np.linalg.qr(r.normal(size=(D, d)))[0].T.astype(np.float32)
    b = np.linalg.qr(r.normal(size=(D, d)))[0].T.astype(np.float32)
    kq, kx = Q @ Q.T, X @ X.T
    direct = np.linalg.norm(Q.T @ a.T @ b @ X - Q.T @ X) ** 2
    got = float(loss(*map(jnp.asarray, (a, b, kq, kx)))) + float(np.trace(kq @ kx))
    np.testing.assert_allclose(got, direct, rtol=2e-3)


# ---------------------------------------------------------------- fw_step
def test_fw_step_descends():
    a, b, kq, kx = _problem(17)
    A, B = jnp.asarray(a), jnp.asarray(b)
    kq, kx = jnp.asarray(kq), jnp.asarray(kx)
    losses = []
    for t in range(10):
        A, B, l = fw_step(A, B, kq, kx, jnp.float32(1.0 / (t + 2) ** 0.7))
        losses.append(float(l))
    assert losses[-1] < losses[0], losses


def test_fw_step_iterates_stay_in_spectral_ball():
    a, b, kq, kx = _problem(23)
    A, B = jnp.asarray(a), jnp.asarray(b)
    kq, kx = jnp.asarray(kq), jnp.asarray(kx)
    for t in range(5):
        A, B, _ = fw_step(A, B, kq, kx, jnp.float32(1.0 / (t + 1) ** 0.7))
    for M in (A, B):
        top = np.linalg.svd(np.asarray(M), compute_uv=False)[0]
        assert top <= 1.0 + 1e-2, top


def test_fw_step_matches_ref_one_step():
    """Against the exact-SVD-LMO reference for a well-conditioned gradient."""
    a, b, kq, kx = _problem(29)
    ga, gb, gl = (
        np.asarray(v)
        for v in fw_step(*map(jnp.asarray, (a, b, kq, kx)), jnp.float32(0.5))
    )
    ra, rb, rl = ref.ref_fw_step(*map(jnp.asarray, (a, b, kq, kx)), 0.5)
    np.testing.assert_allclose(ga, np.asarray(ra), atol=2e-2)
    np.testing.assert_allclose(gb, np.asarray(rb), atol=2e-2)
    const = float(np.trace(kq @ kx))
    np.testing.assert_allclose(gl + const, float(rl), rtol=5e-3)
