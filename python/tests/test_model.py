"""L2 model-level tests: shapes, composite semantics, Proposition 1."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=8, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


@settings(**SETTINGS)
@given(
    D=st.integers(16, 200),
    d=st.integers(4, 16),
    B=st.integers(1, 130),
    seed=st.integers(0, 2**31),
)
def test_project_matches_ref(D, d, B, seed):
    r = _rng(seed)
    p = r.normal(size=(d, D)).astype(np.float32)
    x = r.normal(size=(D, B)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(model.project(jnp.asarray(p), jnp.asarray(x))),
        np.asarray(ref.ref_project(p, x)),
        rtol=2e-5,
        atol=2e-4,
    )


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31))
def test_eig_topd_captures_top_energy(seed):
    """Subspace iteration must capture (nearly) as much K-energy as the
    exact eigenbasis: Tr(P K P^T) >= 0.99 * sum of top-d eigenvalues."""
    r = _rng(seed)
    D, d = 64, 12
    # decaying spectrum so the top-d subspace is well separated
    u = np.linalg.qr(r.normal(size=(D, D)))[0]
    w = 1.0 / np.arange(1, D + 1) ** 1.2
    k = (u * w) @ u.T
    k = ((k + k.T) / 2).astype(np.float32)
    v0 = r.normal(size=(D, d)).astype(np.float32)
    p = np.asarray(model.eig_topd(jnp.asarray(k), jnp.asarray(v0)))
    # row-orthonormal
    np.testing.assert_allclose(p @ p.T, np.eye(d), atol=7e-3)
    got = np.trace(p @ k @ p.T)
    want = np.sort(np.linalg.eigvalsh(k.astype(np.float64)))[::-1][:d].sum()
    assert got >= 0.99 * want, (got, want)


def test_loss_full_zero_for_identity_projection():
    """With d == D and A = B = I the approximation is exact."""
    r = _rng(2)
    D = 24
    X = r.normal(size=(D, 100)).astype(np.float32)
    Q = r.normal(size=(D, 50)).astype(np.float32)
    kx, kq = X @ X.T, Q @ Q.T
    eye = jnp.eye(D, dtype=jnp.float32)
    l = float(model.loss_full(eye, eye, jnp.asarray(kq), jnp.asarray(kx)))
    scale = float(np.trace(kq @ kx))
    assert abs(l) <= 1e-3 * scale, (l, scale)


def test_proposition1_pca_bound():
    """Prop. 1: min loss over A,B is upper-bounded by the PCA solution;
    therefore the FW iterates, once converged, must not be (much) worse
    than PCA, and PCA itself must satisfy the bound exactly."""
    r = _rng(3)
    D, d = 48, 12
    X = r.normal(size=(D, 400)).astype(np.float32)
    Q = r.normal(size=(D, 200)).astype(np.float32)
    kx, kq = (X @ X.T).astype(np.float32), (Q @ Q.T).astype(np.float32)
    pca = ref.ref_topd(kx, d)
    loss_pca = float(model.loss_full(pca, pca, jnp.asarray(kq), jnp.asarray(kx)))
    # SVD residual bound (Eq. 19 with the Q renormalization of Eq. 21):
    # ||Q||_F^2 * ||X - P^T P X||_F^2
    resid = X - np.asarray(pca).T @ (np.asarray(pca) @ X)
    bound = (np.linalg.norm(Q) ** 2) * (np.linalg.norm(resid) ** 2)
    assert loss_pca <= bound * (1 + 1e-4), (loss_pca, bound)


def test_fw_improves_over_random_init_toward_pca_level():
    r = _rng(4)
    D, d = 64, 16
    u = np.linalg.qr(r.normal(size=(D, D)))[0]
    w = 1.0 / np.arange(1, D + 1) ** 0.8
    X = ((u * w) @ r.normal(size=(D, 800))).astype(np.float32)
    Q = ((u * w) @ r.normal(size=(D, 300))).astype(np.float32)  # ID case
    kx = jnp.asarray(X @ X.T / 800)
    kq = jnp.asarray(Q @ Q.T / 300)
    A = jnp.asarray(np.linalg.qr(r.normal(size=(D, d)))[0].T.astype(np.float32))
    B = jnp.asarray(np.linalg.qr(r.normal(size=(D, d)))[0].T.astype(np.float32))
    l0 = float(model.loss_full(A, B, kq, kx))
    for t in range(30):
        A, B, _ = model.fw_step(A, B, kq, kx, jnp.float32(1.0 / (t + 2) ** 0.7))
    l1 = float(model.loss_full(A, B, kq, kx))
    pca = ref.ref_topd(np.asarray(kx), d)
    lp = float(model.loss_full(jnp.asarray(pca), jnp.asarray(pca), kq, kx))
    assert l1 < l0, (l0, l1)
    # ID case: FW has a sublinear rate (Theorem 1), so after 30 iterations
    # from a random init we only require it lands in the PCA ballpark
    # (the production driver initializes FW from PCA/eigsearch instead).
    assert l1 <= 5.0 * lp + 1e-6, (l1, lp)
