"""AOT pipeline tests: lowering produces parseable HLO text + sane manifest."""

import json
import os

import jax
import numpy as np
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_roundtrips_simple_fn():
    lowered = jax.jit(lambda x, y: (jnp.dot(x, y),)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_build_plan_covers_all_functions():
    plan = list(aot.build_plan([(200, 128)]))
    names = [p[0] for p in plan]
    assert names == [
        "fw_step_D200_d128",
        "fw_step_xla_D200_d128",
        "eig_topd_D200_d128",
        "eig_topd_xla_D200_d128",
        "project_db_D200_d128",
        "project_q_D200_d128",
        "score_D200_d128",
    ]
    for _, fn, specs, meta in plan:
        out = jax.eval_shape(fn, *specs)
        assert isinstance(out, tuple) and len(out) >= 1
        assert meta["D"] == 200 and meta["d"] == 128


def test_lower_project_artifact_small(tmp_path):
    """Lower the smallest artifact end-to-end and validate manifest wiring."""
    name, fn, specs, meta = [
        p for p in aot.build_plan([(200, 128)]) if p[3]["fn"] == "project"
    ][1]  # project_q: (128,200) x (200,64)
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    p = np.random.default_rng(0).normal(size=(128, 200)).astype(np.float32)
    x = np.random.default_rng(1).normal(size=(200, 64)).astype(np.float32)
    got = np.asarray(fn(jnp.asarray(p), jnp.asarray(x))[0])
    np.testing.assert_allclose(got, p @ x, rtol=2e-5, atol=2e-4)


def test_manifest_written(tmp_path):
    """Full aot main() on one small shape set writes consistent manifest."""
    import sys
    from unittest import mock

    out = str(tmp_path)
    argv = ["aot", "--out", out, "--shapes", "64x16"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    assert len(manifest["artifacts"]) == 7
    for entry in manifest["artifacts"]:
        path = os.path.join(out, entry["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            assert "HloModule" in f.read(200)
        assert all("shape" in io and "dtype" in io for io in entry["inputs"])
        assert all("shape" in io and "dtype" in io for io in entry["outputs"])
