//! `repro` — the LeanVec reproduction CLI.
//!
//! Subcommands:
//!   experiment <id>   regenerate a paper table/figure (or `all`)
//!   build             build an index over a synthetic dataset, report timing
//!   search            build + search, print QPS/recall
//!   serve             run the batching engine on a synthetic workload
//!   artifacts         verify the PJRT artifacts load + execute
//!
//! Common flags: --out DIR, --scale S, --seed N, --pjrt,
//!               --dataset NAME, --dim d, --window W, --k K,
//!               --threads T (build workers; 0 = all cores, 1 = serial)

use leanvec::config::{Compression, ProjectionKind};
use leanvec::coordinator::{BatchPolicy, Engine, EngineConfig, QueryProjectorKind};
use leanvec::data::synth::{generate, paper_datasets, paper_target_dim};
use leanvec::experiments::harness::ExpContext;
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::SearchParams;
use leanvec::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("build") => cmd_build(&args),
        Some("search") => cmd_search(&args),
        Some("serve") => cmd_serve(&args),
        Some("artifacts") => cmd_artifacts(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "usage: repro <experiment|build|search|serve|artifacts> [flags]\n\
         \n\
         repro experiment all --out results --scale 0.35\n\
         repro experiment fig5 --pjrt\n\
         repro build --dataset rqa-768 --dim 160 --threads 0\n\
         repro search --dataset wit-512 --projection ood-es --window 50\n\
         repro serve --dataset rqa-768 --queries 2000 --workers 2\n\
         repro artifacts"
    );
}

fn ctx_from(args: &Args) -> ExpContext {
    ExpContext {
        out_dir: args.str("out", "results").into(),
        scale: args.f64("scale", 0.35),
        use_pjrt: args.switch("pjrt"),
        seed: args.usize("seed", 7) as u64,
    }
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    leanvec::experiments::run(&id, &ctx_from(args))
}

fn dataset_from(args: &Args, ctx: &ExpContext) -> anyhow::Result<leanvec::data::synth::Dataset> {
    let name = args.str("dataset", "rqa-768");
    let spec = paper_datasets(ctx.scale)
        .into_iter()
        .find(|s| s.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
    Ok(generate(&spec))
}

fn build_index(
    args: &Args,
    ctx: &ExpContext,
    ds: &leanvec::data::synth::Dataset,
) -> anyhow::Result<leanvec::index::leanvec_index::LeanVecIndex> {
    let proj = ProjectionKind::parse(&args.str("projection", "ood-es"))
        .ok_or_else(|| anyhow::anyhow!("bad --projection"))?;
    let d = args.usize("dim", paper_target_dim(&ds.name));
    let primary = Compression::parse(&args.str("primary", "lvq8"))
        .ok_or_else(|| anyhow::anyhow!("bad --primary"))?;
    let secondary = Compression::parse(&args.str("secondary", "f16"))
        .ok_or_else(|| anyhow::anyhow!("bad --secondary"))?;
    let mut builder = IndexBuilder::new()
        .projection(proj)
        .target_dim(d)
        .primary(primary)
        .secondary(secondary)
        .graph_params(ctx.graph_params(ds.similarity))
        .seed(ctx.seed)
        .build_threads(args.usize("threads", 1));
    if ctx.use_pjrt {
        let rt = leanvec::runtime::executor::open_shared(
            &leanvec::runtime::default_artifacts_dir(),
        )?;
        builder = builder
            .backends(leanvec::leanvec::model::TrainBackends {
                fw: Box::new(leanvec::runtime::PjrtFwStepper::new(rt.clone())),
                topd: Box::new(leanvec::runtime::PjrtTopd::new(rt.clone())),
            })
            .projector(Box::new(leanvec::runtime::PjrtProjector::new(rt)));
    }
    Ok(builder.build(&ds.database, Some(&ds.learn_queries), ds.similarity))
}

fn cmd_build(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args);
    let ds = dataset_from(args, &ctx)?;
    println!(
        "building index over {} ({} x {}, {})...",
        ds.name,
        ds.database.len(),
        ds.dim,
        ds.similarity.name()
    );
    let index = build_index(args, &ctx, &ds)?;
    let b = index.build_breakdown;
    println!(
        "built: train {:.2}s | project {:.2}s | quantize {:.2}s | graph {:.2}s | total {:.2}s",
        b.train_seconds,
        b.project_seconds,
        b.quantize_seconds,
        b.graph_seconds,
        b.total()
    );
    println!(
        "primary {} B/vec ({:.1}x vs FP16 full-D), avg degree {:.1}",
        index.primary.bytes_per_vector(),
        index.primary_compression_vs_fp16(),
        index.graph.adj.avg_degree()
    );
    Ok(())
}

fn cmd_search(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args);
    let ds = dataset_from(args, &ctx)?;
    let k = args.usize("k", 10);
    let window = args.usize("window", 50);
    let index = build_index(args, &ctx, &ds)?;
    let truth =
        leanvec::data::gt::ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let curve = leanvec::experiments::harness::qps_recall_curve(
        &index,
        &ds.test_queries,
        &truth,
        k,
        &[window],
    );
    let p = curve[0];
    println!(
        "{}: window {} -> recall@{k} {:.3}, {:.0} QPS, {:.0} bytes/query",
        ds.name, p.window, p.recall, p.qps, p.bytes_per_query
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args);
    let ds = dataset_from(args, &ctx)?;
    let k = args.usize("k", 10);
    let n_queries = args.usize("queries", 2000);
    let index = Arc::new(build_index(args, &ctx, &ds)?);
    let truth =
        leanvec::data::gt::ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    // repeat test queries to reach the workload size
    let queries: Vec<Vec<f32>> = (0..n_queries)
        .map(|i| ds.test_queries[i % ds.test_queries.len()].clone())
        .collect();
    let truth_rep: Vec<Vec<u32>> = (0..n_queries)
        .map(|i| truth[i % truth.len()].clone())
        .collect();
    let cfg = EngineConfig {
        workers: args.usize("workers", 0).max(1),
        batch: BatchPolicy {
            max_batch: args.usize("batch", 64),
            max_wait: std::time::Duration::from_micros(args.usize("wait-us", 500) as u64),
        },
        search: SearchParams {
            window: args.usize("window", 50),
            rerank_window: args.usize("window", 50),
        },
        projector: if ctx.use_pjrt {
            QueryProjectorKind::Pjrt(leanvec::runtime::default_artifacts_dir())
        } else {
            QueryProjectorKind::Native
        },
    };
    let (_responses, report) = Engine::run_workload(index, cfg, &queries, k, Some(&truth_rep));
    println!("{}", report.metrics);
    println!("recall@{k}: {:.3}", report.recall_at_k);
    Ok(())
}

fn cmd_artifacts(_args: &Args) -> anyhow::Result<()> {
    use leanvec::runtime::PjrtRuntime;
    let dir = leanvec::runtime::default_artifacts_dir();
    let mut rt = PjrtRuntime::open(&dir)?;
    println!(
        "manifest: {} artifacts in {dir:?}",
        rt.manifest().artifacts.len()
    );
    // smoke-execute the smallest project artifact
    let spec = rt
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.fn_name == "project")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("no project artifact"))?;
    let (d, dd, b) = (spec.small_d, spec.big_d, spec.batch.unwrap_or(1));
    let mut rng = leanvec::util::rng::Rng::new(1);
    let p = leanvec::linalg::Matrix::randn(d, dd, &mut rng);
    let x = leanvec::linalg::Matrix::randn(dd, b, &mut rng);
    let out = rt.execute(
        &spec.name,
        &[
            leanvec::runtime::client::lit_from_matrix(&p)?,
            leanvec::runtime::client::lit_from_matrix(&x)?,
        ],
    )?;
    let y = leanvec::runtime::client::matrix_from_lit(&out[0], d, b)?;
    let want = p.matmul(&x);
    let err = y.max_abs_diff(&want);
    println!(
        "executed {} -> output ({d} x {b}), max |err| vs native = {err:.2e}",
        spec.name
    );
    anyhow::ensure!(err < 1e-2, "artifact numerics mismatch");
    println!("artifacts OK");
    Ok(())
}
