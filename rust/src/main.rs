//! `repro` — the LeanVec reproduction CLI.
//!
//! Subcommands:
//!   experiment <id>   regenerate a paper table/figure (or `all`)
//!   build             build an index, write it to a snapshot, report timing
//!   search            search an index (from --index snapshot, or build ad hoc)
//!   serve             run the batching engine (from --index snapshot, or build)
//!   mutate            churn driver: streaming inserts/deletes + search
//!                     on a snapshot-loaded live index
//!   metrics           run a short workload and print the telemetry
//!                     exposition (Prometheus text, or --json)
//!   artifacts         verify the PJRT artifacts load + execute
//!
//! The build/serve split: `build` constructs the index once and
//! snapshots it to disk (`--index PATH`, default `<dataset>.leanvec`);
//! `search`, `serve` and `mutate` given `--index PATH` read the
//! snapshot and answer queries without ever touching the training path.
//!
//! Common flags: --out DIR, --scale S, --seed N, --pjrt,
//!               --dataset NAME, --dim d, --window W,
//!               --rerank-window R (split buffer; may exceed W), --k K,
//!               --index PATH (snapshot to write/read),
//!               --threads T (build workers; 0 = all cores, 1 = serial),
//!               --baseline leanvec|ivfpq|flat (search arm),
//!               --nprobe N (IVF-PQ probe count),
//!               --insert-rate/--delete-rate R (mutate churn, in [0,1]),
//!               --shards N (hash-partitioned build/serve),
//!               --collection NAME (serve: collection to register/route)
//!
//! Numeric flags are validated up front: garbage or out-of-range values
//! produce a usage-style error instead of a panic (or silent fallback)
//! deep in the stack.

use leanvec::config::{BuildParams, Compression, ProjectionKind};
use leanvec::coordinator::{
    BatchPolicy, Engine, EngineConfig, EngineError, Metrics, QueryProjectorKind, QuerySpec,
    ServeReport, ShedPolicy,
};
use leanvec::data::synth::{generate, paper_datasets, paper_target_dim};
use leanvec::experiments::harness::ExpContext;
use leanvec::index::builder::IndexBuilder;
use leanvec::index::ivfpq::{IvfPqIndex, IvfPqParams};
use leanvec::index::leanvec_index::{LeanVecIndex, SearchParams};
use leanvec::index::persist::SnapshotMeta;
use leanvec::index::query::{Query, VectorIndex};
use leanvec::index::FlatIndex;
use leanvec::mutate::LiveIndex;
use leanvec::shard::{
    Collection, CollectionRegistry, ShardSpec, ShardedIndex, DEFAULT_COLLECTION, MANIFEST_NAME,
};
use leanvec::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("build") => cmd_build(&args),
        Some("search") => cmd_search(&args),
        Some("serve") => cmd_serve(&args),
        Some("mutate") => cmd_mutate(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("fsck") => cmd_fsck(&args),
        Some("swap") => cmd_swap(&args),
        Some("artifacts") => cmd_artifacts(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        // engine failures carry a distinct exit code per class (10-16)
        // so scripts can branch on WHAT failed; everything else stays
        // the generic 1
        let code = e
            .downcast_ref::<EngineError>()
            .map(EngineError::exit_code)
            .unwrap_or(1);
        std::process::exit(code);
    }
}

fn print_usage() {
    println!(
        "usage: repro <experiment|build|search|serve|mutate|metrics|fsck|swap|artifacts> [flags]\n\
         \n\
         repro experiment all --out results --scale 0.35\n\
         repro experiment fig5 --pjrt\n\
         repro build --dataset rqa-768 --dim 160 --threads 0 --index rqa-768.leanvec\n\
         repro build --dataset rqa-768 --shards 4 --threads 0 --index rqa-768.lvshards\n\
         repro search --index rqa-768.leanvec --window 50 --rerank-window 150\n\
         repro serve --index rqa-768.leanvec --queries 2000 --workers 2 --rerank-window 100\n\
         repro serve --index rqa-768.leanvec --mmap   (serve off a memory map; bigger-than-RAM)\n\
         repro serve --index rqa-768.lvshards --collection tenant-a --workers 4\n\
         repro serve --dataset wit-512 --shards 4   (ad hoc sharded build + serve)\n\
         repro mutate --index rqa-768.leanvec --insert-rate 0.2 --delete-rate 0.1\n\
         repro metrics --index rqa-768.leanvec --queries 500   (scrape after a workload)\n\
         repro metrics --index rqa-768.leanvec --json\n\
         repro serve --index rqa-768.leanvec --metrics-every 500   (periodic exposition)\n\
         repro fsck --index rqa-768.leanvec   (deep consistency check; exit 2 on violations)\n\
         repro fsck --index rqa-768.lvshards  (checks every shard + routing/ownership)\n\
         repro swap --index a.leanvec --next b.leanvec   (hot-swap under load, 0 dropped)\n\
         repro serve --index rqa-768.leanvec --watch-snapshot   (hot-swap on file change)\n\
         repro search --dataset wit-512 --projection ood-es   (ad hoc, no snapshot)\n\
         repro search --dataset deep-256 --baseline ivfpq --nprobe 16\n\
         repro artifacts\n\
         \n\
         search knobs: --window W (graph search buffer), --rerank-window R\n\
         (candidates re-ranked; may exceed W — split buffer), --k K,\n\
         --baseline leanvec|ivfpq|flat (ad hoc arms), --nprobe N (IVF-PQ)\n\
         mutate knobs: --insert-rate/--delete-rate R (fraction of the live\n\
         corpus churned, in [0,1]), --consolidate-threshold F (tombstone\n\
         fraction triggering compaction; 0 disables that trigger), --queries N\n\
         shard knobs: --shards N (hash-partition the corpus across N shards;\n\
         build writes a shard directory + manifest, serve scatter-gathers),\n\
         --collection NAME (serve: register/route under this collection name)\n\
         telemetry: repro metrics --index F [--queries N] [--json] scrapes the\n\
         registry after a workload; serve --metrics-every N dumps a validated\n\
         exposition every N responses and prints the slow-query flight\n\
         recorder on exit (LEANVEC_NO_TELEMETRY=1 disables the whole layer)\n\
         robustness: --timeout-ms MS (per-request deadline; expired requests\n\
         resolve to a typed error, exit code 14), --allow-partial (partial\n\
         results instead), --max-queue-depth N / --max-queue-wait-ms MS\n\
         (overload shedding at admission, exit code 15; see docs/ROBUSTNESS.md)"
    );
}

/// Validated `--key` that must be a positive integer (usage-style error
/// on garbage or zero, default when absent).
fn positive_usize(args: &Args, key: &str, default: usize) -> anyhow::Result<usize> {
    let v = args
        .checked_usize(key, default)
        .map_err(|m| anyhow::anyhow!("{m}; run `repro` for usage"))?;
    anyhow::ensure!(v > 0, "--{key} must be >= 1, got {v}; run `repro` for usage");
    Ok(v)
}

/// Validated `--key` that must be a fraction in [0, 1].
fn rate_flag(args: &Args, key: &str, default: f64) -> anyhow::Result<f64> {
    let v = args
        .checked_f64(key, default)
        .map_err(|m| anyhow::anyhow!("{m}; run `repro` for usage"))?;
    anyhow::ensure!(
        (0.0..=1.0).contains(&v),
        "--{key} must be in [0, 1], got {v}; run `repro` for usage"
    );
    Ok(v)
}

/// Validated `--key` integer where zero is meaningful (0 = all cores /
/// disabled): only garbage is rejected, not any in-range value.
fn checked_usize_flag(args: &Args, key: &str, default: usize) -> anyhow::Result<usize> {
    args.checked_usize(key, default)
        .map_err(|m| anyhow::anyhow!("{m}; run `repro` for usage"))
}

fn ctx_from(args: &Args) -> anyhow::Result<ExpContext> {
    let scale = args
        .checked_f64("scale", 0.35)
        .map_err(|m| anyhow::anyhow!("{m}; run `repro` for usage"))?;
    anyhow::ensure!(
        scale > 0.0,
        "--scale must be > 0, got {scale}; run `repro` for usage"
    );
    Ok(ExpContext {
        out_dir: args.str("out", "results").into(),
        scale,
        use_pjrt: args.switch("pjrt"),
        seed: checked_usize_flag(args, "seed", 7)? as u64,
    })
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    leanvec::experiments::run(&id, &ctx_from(args)?)
}

fn dataset_from(args: &Args, ctx: &ExpContext) -> anyhow::Result<leanvec::data::synth::Dataset> {
    let name = args.str("dataset", "rqa-768");
    let spec = paper_datasets(ctx.scale)
        .into_iter()
        .find(|s| s.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
    Ok(generate(&spec))
}

fn build_index(
    args: &Args,
    ctx: &ExpContext,
    ds: &leanvec::data::synth::Dataset,
) -> anyhow::Result<leanvec::index::leanvec_index::LeanVecIndex> {
    let proj = ProjectionKind::parse(&args.str("projection", "ood-es"))
        .ok_or_else(|| anyhow::anyhow!("bad --projection"))?;
    let d = args.usize("dim", paper_target_dim(&ds.name));
    let primary = Compression::parse(&args.str("primary", "lvq8"))
        .ok_or_else(|| anyhow::anyhow!("bad --primary"))?;
    let secondary = Compression::parse(&args.str("secondary", "f16"))
        .ok_or_else(|| anyhow::anyhow!("bad --secondary"))?;
    let mut builder = IndexBuilder::new()
        .projection(proj)
        .target_dim(d)
        .primary(primary)
        .secondary(secondary)
        .graph_params(ctx.graph_params(ds.similarity))
        .seed(ctx.seed)
        .build_threads(checked_usize_flag(args, "threads", 1)?);
    if ctx.use_pjrt {
        let rt = leanvec::runtime::executor::open_shared(
            &leanvec::runtime::default_artifacts_dir(),
        )?;
        builder = builder
            .backends(leanvec::leanvec::model::TrainBackends {
                fw: Box::new(leanvec::runtime::PjrtFwStepper::new(rt.clone())),
                topd: Box::new(leanvec::runtime::PjrtTopd::new(rt.clone())),
            })
            .projector(Box::new(leanvec::runtime::PjrtProjector::new(rt)));
    }
    Ok(builder.build(&ds.database, Some(&ds.learn_queries), ds.similarity))
}

/// Load a snapshot, printing what was loaded and how long it took.
/// With `mmap` the index serves straight off a read-only memory map of
/// the file (codes, adjacency and re-rank vectors stay on disk until
/// touched), so an index larger than RAM can serve.
fn load_snapshot(path: &str, mmap: bool) -> anyhow::Result<(LeanVecIndex, SnapshotMeta)> {
    let t0 = std::time::Instant::now();
    let p = std::path::Path::new(path);
    let (index, meta) = if mmap {
        LeanVecIndex::load_mmap(p)?
    } else {
        LeanVecIndex::load(p)?
    };
    println!(
        "loaded snapshot {path}: {} vectors, {} -> {} dims, {}/{} stores{}, in {:.3}s",
        index.len(),
        index.model.input_dim(),
        index.model.target_dim(),
        index.primary_compression.name(),
        index.secondary_compression.name(),
        if index.is_mapped() {
            format!(", mmap-backed ({} MiB file)", index.mapped_bytes() >> 20)
        } else {
            String::new()
        },
        t0.elapsed().as_secs_f64()
    );
    Ok((index, meta))
}

/// Regenerate the dataset a snapshot was built from (provenance in the
/// META section), falling back to CLI flags when the snapshot predates
/// provenance or was built from external data. Validated against the
/// loaded index so a provenance mismatch fails loudly instead of
/// reporting recall against the wrong ground truth. `expect_n` is
/// `None` for live (mutated) indexes, whose live count legitimately
/// differs from the generator's corpus size.
fn dataset_for_snapshot(
    args: &Args,
    ctx: &ExpContext,
    meta: &SnapshotMeta,
    expect_n: Option<usize>,
    expect_dim: usize,
) -> anyhow::Result<leanvec::data::synth::Dataset> {
    // explicit flags override provenance (the escape hatch the mismatch
    // error below points at); provenance fills in whatever is absent
    let name = match args.opt_str("dataset") {
        Some(n) => n,
        None if !meta.dataset.is_empty() => meta.dataset.clone(),
        None => "rqa-768".to_string(),
    };
    let scale = if args.flags.contains_key("scale") || meta.scale <= 0.0 {
        ctx.scale
    } else {
        meta.scale
    };
    let spec = paper_datasets(scale)
        .into_iter()
        .find(|s| s.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}' in snapshot provenance"))?;
    let ds = generate(&spec);
    let n_ok = match expect_n {
        Some(n) => ds.database.len() == n,
        None => true,
    };
    let index_n = match expect_n {
        Some(n) => n.to_string(),
        None => "live".to_string(),
    };
    anyhow::ensure!(
        n_ok && ds.dim == expect_dim,
        "snapshot does not match dataset '{name}' at scale {scale} \
         ({} x {} vs index {index_n} x {expect_dim}); pass the original \
         --dataset/--scale flags",
        ds.database.len(),
        ds.dim,
    );
    Ok(ds)
}

/// Resolve [`SearchParams`] from `--window` / `--rerank-window` via
/// the one shared rule (`index::query::resolve_params`): explicit
/// flags win over the (snapshot-recommended) defaults, an explicit
/// `--window` without `--rerank-window` couples the two, and
/// `--rerank-window` may exceed `--window` (split buffer: more
/// candidates re-ranked without widening the traversal). Present flags
/// must parse and be positive — `Query::window(0)` would panic deep in
/// the stack, so reject it here with a usage error instead.
fn search_params_from(args: &Args, defaults: SearchParams) -> anyhow::Result<SearchParams> {
    let flag = |key: &str| -> anyhow::Result<Option<usize>> {
        match args.flags.get(key) {
            None => Ok(None),
            Some(_) => Ok(Some(positive_usize(args, key, 1)?)),
        }
    };
    Ok(leanvec::index::query::resolve_params(
        flag("window")?,
        flag("rerank-window")?,
        defaults,
    ))
}

/// Build a [`ShardedIndex`] from the same builder flags `build_index`
/// reads, with one shared projection model trained over the full corpus
/// (sharded builds train natively — the per-shard builds run on worker
/// threads, where PJRT handles cannot travel).
fn build_sharded_index(
    args: &Args,
    ctx: &ExpContext,
    ds: &leanvec::data::synth::Dataset,
    shards: usize,
) -> anyhow::Result<ShardedIndex> {
    anyhow::ensure!(
        !ctx.use_pjrt,
        "sharded builds train natively; drop --pjrt or --shards"
    );
    let proj = ProjectionKind::parse(&args.str("projection", "ood-es"))
        .ok_or_else(|| anyhow::anyhow!("bad --projection"))?;
    let d = args.usize("dim", paper_target_dim(&ds.name));
    let primary = Compression::parse(&args.str("primary", "lvq8"))
        .ok_or_else(|| anyhow::anyhow!("bad --primary"))?;
    let secondary = Compression::parse(&args.str("secondary", "f16"))
        .ok_or_else(|| anyhow::anyhow!("bad --secondary"))?;
    let gp = ctx.graph_params(ds.similarity);
    let seed = ctx.seed;
    let threads = checked_usize_flag(args, "threads", 1)?;
    let configure = move |b: IndexBuilder| {
        b.projection(proj)
            .target_dim(d)
            .primary(primary)
            .secondary(secondary)
            .graph_params(gp)
            .seed(seed)
    };
    Ok(ShardedIndex::build(
        &ds.database,
        Some(&ds.learn_queries),
        ds.similarity,
        ShardSpec::new(shards),
        threads,
        configure,
    ))
}

/// Build a sharded index and snapshot it as a per-shard directory with
/// a CRC'd routing manifest (`repro build --shards N`).
fn cmd_build_sharded(
    args: &Args,
    ctx: &ExpContext,
    ds: &leanvec::data::synth::Dataset,
    shards: usize,
) -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let sharded = build_sharded_index(args, ctx, ds, shards)?;
    println!(
        "built {} shards over {} vectors in {:.2}s (shared model: {} -> {} dims)",
        sharded.shards(),
        sharded.len(),
        t0.elapsed().as_secs_f64(),
        sharded.model().input_dim(),
        sharded.model().target_dim(),
    );
    let dir = args.str("index", &format!("{}.lvshards", ds.name));
    let meta = SnapshotMeta {
        dataset: ds.name.clone(),
        seed: ctx.seed,
        scale: ctx.scale,
        build: BuildParams {
            build_threads: checked_usize_flag(args, "threads", 1)?,
        },
        search_defaults: search_params_from(
            args,
            SearchParams {
                window: 50,
                rerank_window: 50,
            },
        )?,
    };
    let t0 = std::time::Instant::now();
    let bytes = sharded.save_dir(std::path::Path::new(&dir), &meta)?;
    println!(
        "shard dir {dir}: {} shard files + manifest, {:.1} MiB written in {:.3}s",
        sharded.shards(),
        bytes as f64 / (1024.0 * 1024.0),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_build(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args)?;
    let shards = positive_usize(args, "shards", 1)?;
    let ds = dataset_from(args, &ctx)?;
    println!(
        "building index over {} ({} x {}, {})...",
        ds.name,
        ds.database.len(),
        ds.dim,
        ds.similarity.name()
    );
    if shards > 1 {
        return cmd_build_sharded(args, &ctx, &ds, shards);
    }
    let index = build_index(args, &ctx, &ds)?;
    let b = index.build_breakdown;
    println!(
        "built: train {:.2}s | project {:.2}s | quantize {:.2}s | graph {:.2}s | total {:.2}s",
        b.train_seconds,
        b.project_seconds,
        b.quantize_seconds,
        b.graph_seconds,
        b.total()
    );
    println!(
        "primary {} B/vec ({:.1}x vs FP16 full-D), avg degree {:.1}",
        index.primary.bytes_per_vector(),
        index.primary_compression_vs_fp16(),
        index.graph.adj.avg_degree()
    );
    // snapshot to disk: the serve-side commands start from this file
    let path = args.str("index", &format!("{}.leanvec", ds.name));
    let meta = SnapshotMeta {
        dataset: ds.name.clone(),
        seed: ctx.seed,
        scale: ctx.scale,
        build: BuildParams {
            build_threads: checked_usize_flag(args, "threads", 1)?,
        },
        search_defaults: search_params_from(
            args,
            SearchParams {
                window: 50,
                rerank_window: 50,
            },
        )?,
    };
    let t0 = std::time::Instant::now();
    let bytes = index.save(std::path::Path::new(&path), &meta)?;
    println!(
        "snapshot {path}: {:.1} MiB written in {:.3}s",
        bytes as f64 / (1024.0 * 1024.0),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_search(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args)?;
    let k = positive_usize(args, "k", 10)?;
    let baseline = args.str("baseline", "leanvec");
    if baseline != "leanvec" {
        return cmd_search_baseline(args, &ctx, &baseline, k);
    }
    let (index, ds, params) = match args.opt_str("index") {
        // serve path: read the snapshot, never touch the training path
        Some(path) => {
            let (index, meta) = load_snapshot(&path, args.switch("mmap"))?;
            let ds = dataset_for_snapshot(
                args,
                &ctx,
                &meta,
                Some(index.len()),
                index.model.input_dim(),
            )?;
            let params = search_params_from(args, meta.search_defaults)?;
            (index, ds, params)
        }
        // ad hoc path: build in-process (kept for experimentation)
        None => {
            let ds = dataset_from(args, &ctx)?;
            let index = build_index(args, &ctx, &ds)?;
            (index, ds, search_params_from(args, SearchParams::default())?)
        }
    };
    let truth =
        leanvec::data::gt::ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    report_point_and_batch(args, &index, &ds, &truth, k, params)
}

/// Ad hoc baseline arms reached through the same `VectorIndex` trait:
/// `--baseline ivfpq` (with `--nprobe`) and `--baseline flat`.
fn cmd_search_baseline(
    args: &Args,
    ctx: &ExpContext,
    baseline: &str,
    k: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.opt_str("index").is_none(),
        "--baseline arms are ad hoc (built in-process); drop --index"
    );
    let ds = dataset_from(args, ctx)?;
    let sim = if ds.similarity == leanvec::Similarity::Cosine {
        leanvec::Similarity::InnerProduct
    } else {
        ds.similarity
    };
    let truth =
        leanvec::data::gt::ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    match baseline {
        "ivfpq" => {
            let nprobe = positive_usize(args, "nprobe", 8)?;
            // largest m in {8,4,2,1} dividing the dimensionality
            let m = [8usize, 4, 2, 1]
                .into_iter()
                .find(|m| ds.dim % m == 0)
                .unwrap();
            let nlist = (ds.database.len() as f64).sqrt().ceil() as usize;
            let ivf = IvfPqIndex::build(
                &ds.database,
                IvfPqParams {
                    nlist,
                    m,
                    ksub: 256,
                    kmeans_iters: 6,
                },
                sim,
                ctx.seed,
            );
            println!(
                "ivfpq baseline: built in {:.2}s ({nlist} lists, m={m})",
                ivf.build_seconds
            );
            // for IVF-PQ the trait reads Query::window as nprobe
            report_point_and_batch(
                args,
                &ivf,
                &ds,
                &truth,
                k,
                SearchParams {
                    window: nprobe,
                    rerank_window: nprobe,
                },
            )
        }
        "flat" => {
            let flat = FlatIndex::new(&ds.database, sim);
            report_point_and_batch(args, &flat, &ds, &truth, k, SearchParams::default())
        }
        other => anyhow::bail!("unknown --baseline '{other}' (leanvec|ivfpq|flat)"),
    }
}

/// Shared reporting: one single-thread QPS/recall point at `params`
/// plus a closed-loop parallel batch run — all through `VectorIndex`.
fn report_point_and_batch<I: VectorIndex>(
    args: &Args,
    index: &I,
    ds: &leanvec::data::synth::Dataset,
    truth: &[Vec<u32>],
    k: usize,
    params: SearchParams,
) -> anyhow::Result<()> {
    // single-thread point at the full per-request params (including a
    // split-buffer rerank window larger than the traversal window)
    let params = SearchParams {
        window: params.window.max(1),
        rerank_window: params.rerank_window.max(1),
    };
    let p = leanvec::experiments::harness::qps_recall_point(
        index,
        &ds.test_queries,
        truth,
        k,
        params,
    );
    println!(
        "{}: window {} (rerank {}) -> recall@{k} {:.3}, {:.0} QPS, {:.0} bytes/query",
        ds.name, params.window, params.rerank_window, p.recall, p.qps, p.bytes_per_query
    );
    // closed-loop parallel batch search over the same queries
    let threads = checked_usize_flag(args, "threads", 0)?;
    let queries: Vec<Query> = ds
        .test_queries
        .iter()
        .map(|q| {
            Query::new(q)
                .k(k)
                .window(params.window)
                .rerank_window(params.rerank_window)
        })
        .collect();
    let t0 = std::time::Instant::now();
    let got: Vec<Vec<u32>> = index
        .search_batch(&queries, threads)
        .into_iter()
        .map(|r| r.ids)
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    let recall = leanvec::data::gt::recall_at_k(&got, truth, k);
    println!(
        "batch: {} queries in {:.3}s -> {:.0} QPS, recall@{k} {:.3}",
        ds.test_queries.len(),
        wall,
        ds.test_queries.len() as f64 / wall.max(1e-9),
        recall
    );
    Ok(())
}

/// `repro fsck --index FILE|DIR`: deep offline consistency check over a
/// snapshot file (frozen or live) or a shard directory. Runs the same
/// `check_invariants` entry points the corruption test battery proves
/// out, prints the typed report, and exits 2 when violations are found
/// — exit 1 stays the generic error path for files too corrupt to
/// parse at all (bad magic, checksum, truncation).
fn cmd_fsck(args: &Args) -> anyhow::Result<()> {
    let path = args.opt_str("index").ok_or_else(|| {
        anyhow::anyhow!("repro fsck needs --index SNAPSHOT|SHARD_DIR; run `repro` for usage")
    })?;
    let p = std::path::Path::new(&path);
    let t0 = std::time::Instant::now();
    let report = if p.join(MANIFEST_NAME).is_file() {
        let (sharded, _meta) = ShardedIndex::load_dir(p)?;
        sharded.check_invariants()
    } else {
        match LeanVecIndex::load(p) {
            Ok((index, _meta)) => index.check_invariants(),
            // live snapshots are version-2 files the frozen loader
            // rejects by design; retry through the live loader
            Err(leanvec::index::persist::SnapshotError::UnsupportedVersion { .. }) => {
                let (live, _meta) = LiveIndex::load(p)?;
                live.check_invariants()
            }
            Err(e) => return Err(e.into()),
        }
    };
    println!("{report}");
    println!("fsck of {path} finished in {:.3}s", t0.elapsed().as_secs_f64());
    if !report.is_clean() {
        std::process::exit(2);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args)?;
    let k = positive_usize(args, "k", 10)?;
    let n_queries = positive_usize(args, "queries", 2000)?;
    let shards = positive_usize(args, "shards", 1)?;
    let collection = args.str("collection", DEFAULT_COLLECTION);
    let (sharded, ds, default_params) = match args.opt_str("index") {
        // serve path: snapshot in, engine up — no training code runs.
        // A directory with a shard manifest loads the whole sharded
        // layout; a plain file loads as a single-shard collection.
        Some(path) => {
            let p = std::path::Path::new(&path);
            let use_mmap = args.switch("mmap");
            if p.join(MANIFEST_NAME).is_file() {
                let t0 = std::time::Instant::now();
                let policy = if use_mmap {
                    Some(leanvec::index::MmapPolicy::default())
                } else {
                    None
                };
                let (sharded, meta) = ShardedIndex::load_dir_with(p, policy)?;
                println!(
                    "loaded shard dir {path}: {} shards, {} vectors, {} -> {} dims{}, in {:.3}s",
                    sharded.shards(),
                    sharded.len(),
                    sharded.model().input_dim(),
                    sharded.model().target_dim(),
                    if use_mmap { ", mmap-backed" } else { "" },
                    t0.elapsed().as_secs_f64()
                );
                let expect_n = if sharded.is_live() {
                    None // mutated live shards legitimately drift from the generator
                } else {
                    Some(sharded.len())
                };
                let ds = dataset_for_snapshot(
                    args,
                    &ctx,
                    &meta,
                    expect_n,
                    sharded.model().input_dim(),
                )?;
                (sharded, ds, meta.search_defaults)
            } else {
                let (index, meta) = load_snapshot(&path, use_mmap)?;
                let ds = dataset_for_snapshot(
                    args,
                    &ctx,
                    &meta,
                    Some(index.len()),
                    index.model.input_dim(),
                )?;
                (ShardedIndex::from_single(Arc::new(index)), ds, meta.search_defaults)
            }
        }
        None => {
            let ds = dataset_from(args, &ctx)?;
            let sharded = if shards > 1 {
                build_sharded_index(args, &ctx, &ds, shards)?
            } else {
                ShardedIndex::from_single(Arc::new(build_index(args, &ctx, &ds)?))
            };
            (sharded, ds, SearchParams::default())
        }
    };
    let truth =
        leanvec::data::gt::ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    // repeat test queries to reach the workload size
    let queries: Vec<Vec<f32>> = (0..n_queries)
        .map(|i| ds.test_queries[i % ds.test_queries.len()].clone())
        .collect();
    let truth_rep: Vec<Vec<u32>> = (0..n_queries)
        .map(|i| truth[i % truth.len()].clone())
        .collect();
    let params = search_params_from(args, default_params)?;
    let wait_us = checked_usize_flag(args, "wait-us", 500)? as u64;
    let cfg = EngineConfig {
        workers: checked_usize_flag(args, "workers", 0)?.max(1),
        batch: BatchPolicy {
            max_batch: positive_usize(args, "batch", 64)?,
            max_wait: std::time::Duration::from_micros(wait_us),
        },
        shed: ShedPolicy {
            max_queue_depth: checked_usize_flag(args, "max-queue-depth", 0)?,
            max_queue_wait_ms: checked_usize_flag(args, "max-queue-wait-ms", 0)? as u64,
        },
        search: params,
        projector: if ctx.use_pjrt {
            QueryProjectorKind::Pjrt(leanvec::runtime::default_artifacts_dir())
        } else {
            QueryProjectorKind::Native
        },
        ..EngineConfig::default()
    };
    let timeout_ms = args
        .opt_str("timeout-ms")
        .map(|s| {
            s.parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--timeout-ms must be an integer, got {s:?}"))
        })
        .transpose()?;
    let allow_partial = args.switch("allow-partial");
    // --watch-snapshot: poll the snapshot file while draining and
    // hot-swap the serving index when it changes (requires --index)
    let watch = if args.switch("watch-snapshot") {
        let p = args.opt_str("index").ok_or_else(|| {
            anyhow::anyhow!("--watch-snapshot needs --index SNAPSHOT to watch")
        })?;
        Some(std::path::PathBuf::from(p))
    } else {
        None
    };
    let mut last_mtime = watch.as_deref().and_then(snapshot_mtime);
    let n_shards = sharded.shards();
    let mut registry = CollectionRegistry::new();
    registry.register(Collection::new(collection.clone(), sharded).with_defaults(params));
    let metrics_every = checked_usize_flag(args, "metrics-every", 0)?;
    let engine = Engine::start_collections(registry, cfg);
    println!("serving collection {collection:?} ({n_shards} shards)");
    let t0 = std::time::Instant::now();
    let mut admitted = 0usize;
    let mut shed = 0usize;
    for q in &queries {
        let mut spec = QuerySpec::top_k(k).with_collection(&collection);
        if let Some(ms) = timeout_ms {
            spec = spec.with_timeout_ms(ms);
        }
        if allow_partial {
            spec = spec.with_allow_partial();
        }
        match engine.submit_spec(q.clone(), spec) {
            Ok(_) => admitted += 1,
            // shed requests are the overload policy working as designed:
            // count them and keep offering load
            Err(EngineError::Overloaded { .. }) => shed += 1,
            Err(e) => return Err(e.into()),
        }
    }
    // drain in chunks so a periodic exposition (and the snapshot watch)
    // can interleave with the workload; each dump round-trips through
    // the strict in-repo parser before printing, so a malformed
    // exposition fails the run loudly
    let mut responses = Vec::with_capacity(admitted);
    let mut drained = 0usize;
    while drained < admitted {
        let step = if metrics_every > 0 {
            metrics_every.min(admitted - drained)
        } else if watch.is_some() {
            256.min(admitted - drained)
        } else {
            admitted - drained
        };
        let mut chunk = engine.drain(step);
        drained += chunk.len();
        let short = chunk.len() < step;
        responses.append(&mut chunk);
        if metrics_every > 0 {
            let text = engine.metrics_text();
            let families = leanvec::obs::expo::parse_text(&text)
                .map_err(|e| anyhow::anyhow!("metrics exposition failed validation: {e}"))?;
            println!(
                "-- metrics after {drained}/{n_queries} responses \
                 ({} families, exposition validated) --",
                families.len()
            );
            print!("{text}");
        }
        if let Some(p) = watch.as_deref() {
            let mtime = snapshot_mtime(p);
            if mtime.is_some() && mtime != last_mtime {
                last_mtime = mtime;
                match engine.swap_collection(&collection, p) {
                    Ok(rep) => println!(
                        "-- snapshot changed: hot-swapped {:?} ({} shards in, \
                         drained={} in {:.3}s) --",
                        rep.collection, rep.shards, rep.drained, rep.drain_seconds
                    ),
                    // the old index keeps serving on any swap failure;
                    // the watch loop just reports and carries on
                    Err(e) => eprintln!("-- snapshot swap failed: {e} --"),
                }
            }
        }
        if short {
            break; // engine went away; leftovers are collected below
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    // the engine is consumed by shutdown; scrape forensics first
    let flights = engine.flight_records();
    let mut leftovers = engine.shutdown();
    responses.append(&mut leftovers);
    responses.sort_by_key(|r| r.id);
    let report = ServeReport::new(&responses, &truth_rep, k, wall);
    println!("{}", report.metrics);
    println!("recall@{k}: {:.3}", report.recall_at_k);
    let timeouts = responses.iter().filter(|r| !r.is_ok()).count();
    let partials = responses.iter().filter(|r| r.partial).count();
    let degraded = responses.iter().filter(|r| r.degraded).count();
    if shed + timeouts + partials + degraded > 0 {
        println!(
            "robustness: {shed} shed at admission, {timeouts} deadline-failed, \
             {partials} partial, {degraded} degraded (recall counts survivors only)"
        );
    }
    if !flights.is_empty() {
        println!("flight recorder ({} records, slowest first):", flights.len());
        for r in &flights {
            println!("  {r}");
        }
    }
    Ok(())
}

/// Modification time of a snapshot path (file or shard directory —
/// for directories the manifest's mtime is the signal, since a rebuild
/// rewrites it last).
fn snapshot_mtime(p: &std::path::Path) -> Option<std::time::SystemTime> {
    let target = if p.is_dir() { p.join(MANIFEST_NAME) } else { p.to_path_buf() };
    std::fs::metadata(target).and_then(|m| m.modified()).ok()
}

/// `repro swap --index A --next B`: the hot-swap demo. Serve a workload
/// from snapshot A and, mid-drain, atomically swap the collection to
/// snapshot B ([`Engine::swap_collection`]) — every query submitted
/// before, during, and after the swap must resolve (the zero-dropped
/// invariant the chaos soak enforces).
fn cmd_swap(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args)?;
    let k = positive_usize(args, "k", 10)?;
    let n_queries = positive_usize(args, "queries", 2000)?;
    let collection = args.str("collection", DEFAULT_COLLECTION);
    let path = args.opt_str("index").ok_or_else(|| {
        anyhow::anyhow!("repro swap needs --index SNAPSHOT; run `repro` for usage")
    })?;
    // default --next to the same snapshot: still a full load + fsck +
    // swap + drain cycle, just with identical data
    let next = args.str("next", &path);
    let (index, meta) = load_snapshot(&path, args.switch("mmap"))?;
    let ds = dataset_for_snapshot(args, &ctx, &meta, Some(index.len()), index.model.input_dim())?;
    let params = search_params_from(args, meta.search_defaults)?;
    let mut registry = CollectionRegistry::new();
    registry.register(
        Collection::new(collection.clone(), ShardedIndex::from_single(Arc::new(index)))
            .with_defaults(params),
    );
    let engine = Engine::start_collections(
        registry,
        EngineConfig {
            workers: checked_usize_flag(args, "workers", 0)?.max(1),
            search: params,
            ..EngineConfig::default()
        },
    );
    let t0 = std::time::Instant::now();
    for i in 0..n_queries {
        let q = ds.test_queries[i % ds.test_queries.len()].clone();
        engine.submit_spec(q, QuerySpec::top_k(k).with_collection(&collection))?;
    }
    // swap while roughly half the workload is still in flight
    let mut responses = engine.drain(n_queries / 2);
    let report = engine.swap_collection(&collection, std::path::Path::new(&next))?;
    println!(
        "hot-swap: collection {:?} now serving {} ({} shard(s)); \
         old index drained={} in {:.3}s",
        report.collection, next, report.shards, report.drained, report.drain_seconds
    );
    responses.extend(engine.drain(n_queries - responses.len()));
    let wall = t0.elapsed().as_secs_f64();
    let mut leftovers = engine.shutdown();
    responses.append(&mut leftovers);
    anyhow::ensure!(
        responses.len() == n_queries,
        "hot-swap dropped queries: {}/{} resolved",
        responses.len(),
        n_queries
    );
    let failed = responses.iter().filter(|r| !r.is_ok()).count();
    println!(
        "swap-under-load: {n_queries} submitted, {} resolved ({failed} failed), \
         0 dropped, {:.1} qps",
        responses.len(),
        n_queries as f64 / wall
    );
    Ok(())
}

/// `repro metrics --index F [--queries N] [--json]`: run a short
/// closed-loop workload against a snapshot, then print the telemetry
/// exposition — Prometheus text v0.0.4 by default (round-tripped
/// through the strict in-repo parser first), or the JSON form with
/// `--json`. The flight recorder's slowest queries follow.
fn cmd_metrics(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args)?;
    let k = positive_usize(args, "k", 10)?;
    let n_queries = positive_usize(args, "queries", 500)?;
    let path = args.opt_str("index").ok_or_else(|| {
        anyhow::anyhow!("repro metrics needs --index SNAPSHOT; run `repro` for usage")
    })?;
    let (index, meta) = load_snapshot(&path, args.switch("mmap"))?;
    let ds = dataset_for_snapshot(args, &ctx, &meta, Some(index.len()), index.model.input_dim())?;
    let params = search_params_from(args, meta.search_defaults)?;
    let cfg = EngineConfig {
        workers: checked_usize_flag(args, "workers", 0)?.max(1),
        batch: BatchPolicy::default(),
        search: params,
        ..EngineConfig::default()
    };
    let engine = Engine::start(Arc::new(index), cfg);
    for i in 0..n_queries {
        engine
            .submit(ds.test_queries[i % ds.test_queries.len()].clone(), k)
            .map_err(anyhow::Error::new)?;
    }
    let responses = engine.drain(n_queries);
    anyhow::ensure!(
        responses.len() == n_queries,
        "engine answered {}/{} queries",
        responses.len(),
        n_queries
    );
    if args.switch("json") {
        println!("{}", engine.metrics_json());
    } else {
        let text = engine.metrics_text();
        let families = leanvec::obs::expo::parse_text(&text)
            .map_err(|e| anyhow::anyhow!("metrics exposition failed validation: {e}"))?;
        print!("{text}");
        eprintln!("exposition OK ({} families)", families.len());
    }
    let flights = engine.flight_records();
    engine.shutdown();
    if !flights.is_empty() {
        eprintln!("flight recorder ({} records, slowest first):", flights.len());
        for r in flights.iter().take(8) {
            eprintln!("  {r}");
        }
    }
    Ok(())
}

/// Churn driver: load a snapshot into a live index, stream inserts and
/// deletes through the engine's ingest lane while a search workload
/// runs, then report mutation throughput, search latency under churn,
/// consolidation work, and live-set recall vs the exact flat oracle.
fn cmd_mutate(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args)?;
    let k = positive_usize(args, "k", 10)?;
    let n_queries = positive_usize(args, "queries", 2000)?;
    let insert_rate = rate_flag(args, "insert-rate", 0.2)?;
    let delete_rate = rate_flag(args, "delete-rate", 0.1)?;
    let threshold = rate_flag(args, "consolidate-threshold", 0.2)?;
    let path = args.opt_str("index").ok_or_else(|| {
        anyhow::anyhow!("repro mutate needs --index SNAPSHOT; run `repro` for usage")
    })?;

    let t0 = std::time::Instant::now();
    let (live, meta) = LiveIndex::load(std::path::Path::new(&path))?;
    println!(
        "loaded snapshot {path}: {} live vectors ({} slots), {} dims, in {:.3}s",
        live.live_len(),
        live.total_slots(),
        live.model().input_dim(),
        t0.elapsed().as_secs_f64()
    );
    let params = search_params_from(args, meta.search_defaults)?;
    let ds = dataset_for_snapshot(args, &ctx, &meta, None, live.model().input_dim())?;

    let n0 = live.live_len();
    let n_inserts = (insert_rate * n0 as f64).round() as usize;
    let n_deletes = ((delete_rate * n0 as f64).round() as usize).min(n0);
    let mut rng = leanvec::util::rng::Rng::new(ctx.seed ^ 0xC0FFEE);
    // distinct delete targets from the live set; fresh external ids for
    // inserts, above everything currently live (one scan serves both)
    let mut delete_ids = live.live_ids();
    let ext_base = delete_ids.iter().copied().max().unwrap_or(0) + 1;
    rng.shuffle(&mut delete_ids);
    delete_ids.truncate(n_deletes);
    // insert vectors: perturbed copies of corpus rows (same distribution)
    let dim = live.model().input_dim();
    let inserts: Vec<Vec<f32>> = (0..n_inserts)
        .map(|_| {
            let base = &ds.database[rng.below(ds.database.len())];
            base.iter()
                .map(|&x| x + 0.05 * rng.gaussian_f32())
                .collect()
        })
        .collect();
    anyhow::ensure!(
        inserts.iter().all(|v| v.len() == dim),
        "insert vectors must have {dim} dims"
    );

    let live = Arc::new(live);
    let cfg = EngineConfig {
        workers: checked_usize_flag(args, "workers", 0)?.max(1),
        batch: BatchPolicy::default(),
        search: params,
        projector: QueryProjectorKind::Native,
        consolidate_threshold: threshold,
    };
    let mut engine = Engine::start_live(Arc::clone(&live), cfg);

    // interleave the three streams: searches dominate, mutations drip
    // in alongside them (10% churn while serving is the target regime)
    let t_churn = std::time::Instant::now();
    let (mut ins, mut del) = (0usize, 0usize);
    let steps = n_queries.max(n_inserts).max(n_deletes);
    for i in 0..steps {
        if ins * steps <= i * n_inserts && ins < n_inserts {
            engine
                .submit_insert(ext_base + ins as u32, inserts[ins].clone())
                .map_err(anyhow::Error::new)?;
            ins += 1;
        }
        if del * steps <= i * n_deletes && del < n_deletes {
            engine
                .submit_delete(delete_ids[del])
                .map_err(anyhow::Error::new)?;
            del += 1;
        }
        if i < n_queries {
            engine
                .submit(ds.test_queries[i % ds.test_queries.len()].clone(), k)
                .map_err(anyhow::Error::new)?;
        }
    }
    while ins < n_inserts {
        engine
            .submit_insert(ext_base + ins as u32, inserts[ins].clone())
            .map_err(anyhow::Error::new)?;
        ins += 1;
    }
    while del < n_deletes {
        engine
            .submit_delete(delete_ids[del])
            .map_err(anyhow::Error::new)?;
        del += 1;
    }
    let responses = engine.drain(n_queries);
    engine.quiesce_mutations();
    let churn_wall = t_churn.elapsed().as_secs_f64();
    let stats = engine.ingest_stats();
    engine.shutdown();

    let metrics = Metrics::from_responses(&responses, churn_wall);
    println!("{metrics}");
    println!(
        "ingest: {} inserts + {} deletes in {churn_wall:.3}s -> {:.0} mutations/s \
         ({} rejected) | {} consolidations, {:.3}s total",
        stats.inserts,
        stats.deletes,
        (stats.inserts + stats.deletes) as f64 / churn_wall.max(1e-9),
        stats.errors,
        stats.consolidations,
        stats.consolidate_seconds
    );
    println!(
        "live set: {} vectors ({} slots, tombstone fraction {:.3})",
        live.live_len(),
        live.total_slots(),
        live.tombstone_fraction()
    );

    // live-set recall@k vs the exact flat oracle over the live corpus
    let corpus = live.export_live();
    let flat_rows: Vec<Vec<f32>> = corpus.iter().map(|(_, v)| v.clone()).collect();
    let flat = FlatIndex::new(&flat_rows, live.similarity());
    let probes = ds.test_queries.len().min(200);
    let mut hits = 0usize;
    let mut ctx = leanvec::graph::beam::SearchCtx::new(live.total_slots());
    for q in ds.test_queries.iter().take(probes) {
        let (truth_pos, _) = flat.search(q, k);
        let truth: Vec<u32> = truth_pos.iter().map(|&p| corpus[p as usize].0).collect();
        let got = live.search(
            &mut ctx,
            &Query::new(q)
                .k(k)
                .window(params.window)
                .rerank_window(params.rerank_window),
        );
        hits += got.ids.iter().filter(|id| truth.contains(id)).count();
    }
    println!(
        "live-set recall@{k}: {:.3} ({probes} probe queries vs flat oracle)",
        hits as f64 / (probes * k) as f64
    );
    Ok(())
}

fn cmd_artifacts(_args: &Args) -> anyhow::Result<()> {
    use leanvec::runtime::PjrtRuntime;
    let dir = leanvec::runtime::default_artifacts_dir();
    let mut rt = PjrtRuntime::open(&dir)?;
    println!(
        "manifest: {} artifacts in {dir:?}",
        rt.manifest().artifacts.len()
    );
    // smoke-execute the smallest project artifact
    let spec = rt
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.fn_name == "project")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("no project artifact"))?;
    let (d, dd, b) = (spec.small_d, spec.big_d, spec.batch.unwrap_or(1));
    let mut rng = leanvec::util::rng::Rng::new(1);
    let p = leanvec::linalg::Matrix::randn(d, dd, &mut rng);
    let x = leanvec::linalg::Matrix::randn(dd, b, &mut rng);
    let out = rt.execute(
        &spec.name,
        &[
            leanvec::runtime::client::lit_from_matrix(&p)?,
            leanvec::runtime::client::lit_from_matrix(&x)?,
        ],
    )?;
    let y = leanvec::runtime::client::matrix_from_lit(&out[0], d, b)?;
    let want = p.matmul(&x);
    let err = y.max_abs_diff(&want);
    println!(
        "executed {} -> output ({d} x {b}), max |err| vs native = {err:.2e}",
        spec.name
    );
    anyhow::ensure!(err < 1e-2, "artifact numerics mismatch");
    println!("artifacts OK");
    Ok(())
}
