//! `leanvec-lint` — the repo's CI-gated static-analysis pass.
//!
//! Walks `rust/src` with the [`leanvec::analysis`] scanner and prints
//! one `path:line: [rule] message` diagnostic per finding. Exit code 0
//! when the tree is clean, 1 when any non-allowlisted finding remains,
//! 2 on usage/IO errors. See `docs/CORRECTNESS.md` for the rule
//! catalog and suppression format.
//!
//! ```text
//! leanvec-lint [--root DIR] [--allowlist FILE] [--list-rules]
//! ```
//!
//! Defaults resolve against the crate manifest directory, so
//! `cargo run --bin leanvec-lint` works from any CWD.

use leanvec::analysis::{self, Allowlist, ALL_RULES};
use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> ! {
    eprintln!("usage: leanvec-lint [--root DIR] [--allowlist FILE] [--list-rules]");
    std::process::exit(2);
}

fn main() -> ExitCode {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut root = manifest.join("rust/src");
    let mut allow_path = manifest.join("rust/lint-allow.txt");

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => root = PathBuf::from(args.next().unwrap_or_else(|| usage())),
            "--allowlist" => allow_path = PathBuf::from(args.next().unwrap_or_else(|| usage())),
            "--list-rules" => {
                for r in ALL_RULES {
                    println!("{}", r.name());
                }
                return ExitCode::SUCCESS;
            }
            _ => usage(),
        }
    }

    let allow = if allow_path.is_file() {
        match std::fs::read_to_string(&allow_path) {
            Ok(text) => match Allowlist::parse(&text) {
                Ok(a) => a,
                Err(e) => {
                    eprintln!("leanvec-lint: bad allowlist {}: {e}", allow_path.display());
                    return ExitCode::from(2);
                }
            },
            Err(e) => {
                eprintln!("leanvec-lint: read {}: {e}", allow_path.display());
                return ExitCode::from(2);
            }
        }
    } else {
        Allowlist::empty()
    };

    let (n_files, diags) = match analysis::collect_sources(&root) {
        Ok(files) => {
            let mut diags = Vec::new();
            for (rel, abs) in &files {
                match std::fs::read_to_string(abs) {
                    Ok(src) => diags.extend(analysis::scan_file(rel, &src)),
                    Err(e) => {
                        eprintln!("leanvec-lint: read {}: {e}", abs.display());
                        return ExitCode::from(2);
                    }
                }
            }
            diags.sort_by(|a, b| a.path.cmp(&b.path).then(a.line.cmp(&b.line)));
            (files.len(), diags)
        }
        Err(e) => {
            eprintln!("leanvec-lint: walk {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    let (kept, suppressed) = analysis::apply_allowlist(diags, &allow);
    for d in &kept {
        println!("rust/src/{d}");
    }
    if kept.is_empty() {
        println!(
            "leanvec-lint: clean ({n_files} files scanned, {suppressed} allowlisted suppression{})",
            if suppressed == 1 { "" } else { "s" }
        );
        ExitCode::SUCCESS
    } else {
        println!(
            "leanvec-lint: {} finding{} ({suppressed} allowlisted)",
            kept.len(),
            if kept.len() == 1 { "" } else { "s" }
        );
        ExitCode::FAILURE
    }
}
