//! Live telemetry: a dependency-free, lock-free metrics layer plus a
//! slow-query flight recorder, giving the serving stack eyes while it
//! runs instead of only post-hoc summaries.
//!
//! - [`mod@registry`]: sharded atomic counters, gauges, and log-linear
//!   histograms behind one process [`Registry`]; labeled families for
//!   `{collection}` / `{shard}` with a hard cardinality cap.
//! - [`hist`]: the HDR-style bucket math (allocation-free `record()`,
//!   snapshot/merge, midpoint quantiles with a bounded relative error).
//! - [`expo`]: Prometheus text exposition v0.0.4 + JSON rendering and
//!   the strict text parser CI uses to validate every scrape.
//! - [`flight`]: a fixed-size non-blocking ring of the slowest (and
//!   periodically sampled) queries with per-stage breakdowns.
//! - [`metrics`]: the static handle catalog every subsystem records
//!   through (`obs::handles().engine_queries.with("default").inc()`).
//!
//! Set `LEANVEC_NO_TELEMETRY=1` to disable all recording; call sites
//! that pay for extra `Instant::now()` reads guard on [`enabled()`]
//! so the disabled path skips the clock reads too (the bench harness
//! A/Bs this to bound telemetry overhead).

pub mod expo;
pub mod flight;
pub mod hist;
pub mod metrics;
pub mod registry;

pub use flight::{CaptureKind, FlightRecord, FlightRecorder, Outcome};
pub use hist::HistSnapshot;
pub use metrics::{handles, Handles};
pub use registry::{
    enabled, registry, set_enabled, Counter, CounterFamily, FamilySnapshot, Gauge, GaugeFamily,
    Histogram, HistogramFamily, Kind, Registry, ValueSnap, MAX_CHILDREN, OVERFLOW_LABEL,
};
