//! Fixed-bucket log-linear histograms (HDR-style): `record()` is a
//! single relaxed `fetch_add` into a pre-sized atomic bucket array —
//! allocation-free, lock-free, wait-free on the hot path.
//!
//! Bucket layout: values below [`SUBBUCKETS`] land in exact unit-wide
//! buckets; above that, each power-of-two octave is split into
//! [`SUBBUCKETS`] equal sub-buckets, so the worst-case relative
//! quantization error is bounded by `1 / SUBBUCKETS` (3.125% at 32),
//! and in practice ~1.6% because quantiles report bucket midpoints.
//! The full `u64` range is trackable — no clamping, no saturation.
//!
//! Values are recorded as raw `u64`s (nanoseconds for time series,
//! plain counts/bytes for size series); a per-histogram `scale` is
//! applied only at snapshot/exposition time so the hot path never
//! touches floating point.

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-bucket resolution: 2^5 = 32 sub-buckets per octave.
pub const SUB_BITS: u32 = 5;
/// Sub-buckets per octave (and width of the exact linear region).
pub const SUBBUCKETS: usize = 1 << SUB_BITS;
/// Octaves above the linear region needed to span all of `u64`.
const OCTAVES: usize = 64 - SUB_BITS as usize;
/// Total bucket count (linear region + every octave).
pub const N_BUCKETS: usize = (OCTAVES + 1) * SUBBUCKETS;

/// Map a value to its bucket index. Total and monotone over `u64`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUBBUCKETS as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros(); // >= SUB_BITS here
    let octave = (msb - SUB_BITS) as usize;
    let offset = ((v >> (msb - SUB_BITS)) - SUBBUCKETS as u64) as usize;
    (octave + 1) * SUBBUCKETS + offset
}

/// Inclusive lower bound of bucket `idx`'s value range.
pub fn bucket_lower(idx: usize) -> u64 {
    if idx < SUBBUCKETS {
        return idx as u64;
    }
    let octave = idx / SUBBUCKETS - 1;
    let offset = (idx % SUBBUCKETS) as u64;
    (SUBBUCKETS as u64 + offset) << octave
}

/// Width (number of distinct values) of bucket `idx`.
pub fn bucket_width(idx: usize) -> u64 {
    if idx < SUBBUCKETS {
        1
    } else {
        1u64 << (idx / SUBBUCKETS - 1)
    }
}

/// Representative value reported for bucket `idx` (its midpoint; exact
/// for the unit-wide linear region).
pub fn bucket_mid(idx: usize) -> f64 {
    bucket_lower(idx) as f64 + (bucket_width(idx) - 1) as f64 / 2.0
}

/// The shared atomic core of one histogram. Handles (`obs::Histogram`)
/// wrap this in an `Arc`; detached cores back the post-hoc metrics
/// aggregation so live and offline reporting share one quantile path.
pub struct HistCore {
    counts: Vec<AtomicU64>,
    /// Sum of raw recorded values (wraps after ~584 years of nanos).
    sum: AtomicU64,
    count: AtomicU64,
    /// Multiplier raw -> exposed units (1e-9 for nanos -> seconds).
    scale: f64,
}

impl HistCore {
    pub fn new(scale: f64) -> HistCore {
        let mut counts = Vec::with_capacity(N_BUCKETS);
        for _ in 0..N_BUCKETS {
            counts.push(AtomicU64::new(0));
        }
        HistCore {
            counts,
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
            scale,
        }
    }

    /// Record one raw observation. Allocation-free; three relaxed RMWs.
    #[inline]
    pub fn record(&self, v: u64) {
        // ORDERING: Relaxed — independent statistical counters; readers
        // only ever see a slightly stale snapshot, never torn values.
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        // ORDERING: Relaxed — as above; sum/count may momentarily
        // disagree with the buckets, which snapshotting tolerates.
        self.sum.fetch_add(v, Ordering::Relaxed);
        // ORDERING: Relaxed — as above.
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Copy the current counts out. Concurrent `record()`s may land in
    /// buckets after their sum/count increment (or vice versa); the
    /// snapshot normalizes by recomputing count from the buckets.
    pub fn snapshot(&self) -> HistSnapshot {
        let counts: Vec<u64> = self
            .counts
            .iter()
            // ORDERING: Relaxed — statistical snapshot; tearing across
            // buckets only misplaces in-flight observations.
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let count = counts.iter().sum();
        HistSnapshot {
            counts,
            // ORDERING: Relaxed — reporting only.
            sum: self.sum.load(Ordering::Relaxed),
            count,
            scale: self.scale,
        }
    }
}

/// An owned point-in-time copy of a histogram, mergeable and queryable.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    counts: Vec<u64>,
    sum: u64,
    count: u64,
    scale: f64,
}

impl HistSnapshot {
    pub fn empty(scale: f64) -> HistSnapshot {
        HistSnapshot {
            counts: vec![0; N_BUCKETS],
            sum: 0,
            count: 0,
            scale,
        }
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of observations in exposed units (`raw_sum * scale`).
    pub fn sum(&self) -> f64 {
        self.sum as f64 * self.scale
    }

    /// Mean in exposed units; 0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum() / self.count as f64
        }
    }

    /// Fold another snapshot's buckets into this one (exposition-side
    /// aggregation across labeled children). Scales must match.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a = a.saturating_add(*b);
        }
        self.sum = self.sum.wrapping_add(other.sum);
        self.count = self.count.saturating_add(other.count);
    }

    /// Quantile `q` in [0, 1], in exposed units (bucket midpoint of the
    /// observation at ceil(q * count); 0 when empty).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let rank = target.clamp(1, self.count);
        let mut acc = 0u64;
        let mut last_nonempty = 0usize;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            acc += c;
            last_nonempty = i;
            if acc >= rank {
                return bucket_mid(i) * self.scale;
            }
        }
        bucket_mid(last_nonempty) * self.scale
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }

    /// Non-empty buckets as `(lower_bound_scaled, count)`, low to high
    /// (the JSON exposition emits these; text exposition uses
    /// quantiles).
    pub fn nonzero_buckets(&self) -> Vec<(f64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_lower(i) as f64 * self.scale, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_region_is_exact() {
        for v in 0..SUBBUCKETS as u64 {
            let idx = bucket_index(v);
            assert_eq!(idx, v as usize);
            assert_eq!(bucket_lower(idx), v);
            assert_eq!(bucket_width(idx), 1);
            assert_eq!(bucket_mid(idx), v as f64);
        }
    }

    #[test]
    fn bucket_index_is_monotone_and_total() {
        let mut prev = 0usize;
        let mut v = 0u64;
        while v < u64::MAX / 2 {
            let idx = bucket_index(v);
            assert!(idx >= prev, "monotonicity broke at {v}");
            assert!(idx < N_BUCKETS);
            let lo = bucket_lower(idx);
            let w = bucket_width(idx);
            assert!(lo <= v && v - lo < w, "v={v} idx={idx} lo={lo} w={w}");
            prev = idx;
            v = v * 2 + 1;
        }
        assert!(bucket_index(u64::MAX) < N_BUCKETS);
    }

    #[test]
    fn octave_boundaries_round_trip() {
        for msb in SUB_BITS..63 {
            for v in [1u64 << msb, (1u64 << msb) + 1, (1u64 << (msb + 1)) - 1] {
                let idx = bucket_index(v);
                let lo = bucket_lower(idx);
                let w = bucket_width(idx);
                assert!(lo <= v && v < lo + w, "v={v} lo={lo} w={w}");
            }
        }
    }

    #[test]
    fn relative_error_bound_holds() {
        // every value maps to a bucket whose midpoint is within
        // 1/SUBBUCKETS of the value
        let mut v = SUBBUCKETS as u64;
        while v < 1 << 50 {
            for probe in [v, v + v / 3, v * 2 - 1] {
                let mid = bucket_mid(bucket_index(probe));
                let rel = (mid - probe as f64).abs() / probe as f64;
                assert!(rel <= 1.0 / SUBBUCKETS as f64, "v={probe} rel={rel}");
            }
            v *= 2;
        }
    }

    #[test]
    fn quantiles_of_known_sample() {
        let h = HistCore::new(1.0);
        for v in 1..=100u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 100);
        // values <= 31 are exact; 50 lands in [48,50) bucket mid 48.5..
        let p50 = s.quantile(0.5);
        assert!((p50 - 50.0).abs() / 50.0 < 0.05, "p50={p50}");
        let p99 = s.quantile(0.99);
        assert!((p99 - 99.0).abs() / 99.0 < 0.05, "p99={p99}");
        assert!((s.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = HistCore::new(1e-9).snapshot();
        assert_eq!(s.count(), 0);
        assert_eq!(s.quantile(0.5), 0.0);
        assert_eq!(s.sum(), 0.0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn merge_adds_counts() {
        let a = HistCore::new(1.0);
        let b = HistCore::new(1.0);
        for v in 0..50u64 {
            a.record(v);
            b.record(v + 50);
        }
        let mut s = a.snapshot();
        s.merge(&b.snapshot());
        assert_eq!(s.count(), 100);
        let p50 = s.quantile(0.5);
        assert!((p50 - 49.0).abs() / 49.0 < 0.07, "p50={p50}");
    }

    #[test]
    fn scale_applies_to_outputs() {
        let h = HistCore::new(1e-9);
        h.record(1_000_000); // 1ms in nanos
        let s = h.snapshot();
        let p50 = s.quantile(0.5);
        assert!((p50 - 1e-3).abs() / 1e-3 < 0.05, "p50={p50}");
        assert!((s.sum() - 1e-3).abs() < 1e-12);
    }
}
