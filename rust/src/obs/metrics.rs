//! The crate's metric catalog: every series the serving stack records,
//! registered once into the process registry and reachable as static
//! handles via [`handles()`]. Names follow the
//! `leanvec_<subsystem>_<name>_<unit>` convention, enforced by the
//! `obs-metric-name` lint rule (units: `total`, `seconds`, `bytes`,
//! `ratio`, `count`, `info`) — see docs/OBSERVABILITY.md for the
//! catalog with semantics.

use super::registry::{
    registry, Counter, CounterFamily, Gauge, Histogram, HistogramFamily, Registry,
};

/// Scale for histograms recorded in nanoseconds, exposed in seconds.
pub const NANOS: f64 = 1e-9;

/// Every static metric handle the crate records through.
pub struct Handles {
    // -- engine / coordinator (labeled by collection) ------------------
    /// Queries answered, per collection.
    pub engine_queries: CounterFamily,
    /// Requests rejected at admission (quota / unknown collection).
    pub engine_rejected: CounterFamily,
    /// End-to-end latency (submit -> response), per collection.
    pub engine_e2e: HistogramFamily,
    /// Worker-side search time (scatter + merge + rerank), per
    /// collection.
    pub engine_search: HistogramFamily,
    /// Engine uptime, set at exposition time.
    pub engine_uptime: Gauge,
    /// Requests that missed their deadline (shed in queue or cancelled
    /// mid-search), per collection.
    pub engine_deadline_exceeded: CounterFamily,
    /// Requests shed at admission by overload protection, per
    /// collection.
    pub engine_shed: CounterFamily,
    /// Queries answered degraded (one or more shards failed to
    /// contribute), per collection.
    pub engine_degraded: CounterFamily,
    /// Serve-index hot-swaps completed.
    pub engine_swaps: Counter,

    // -- batcher -------------------------------------------------------
    /// Time each request waited in the batcher queue.
    pub batcher_queue_wait: Histogram,
    /// Formed batch sizes.
    pub batcher_batch_size: Histogram,
    /// Per-group query projection (matmul / PJRT) time.
    pub batcher_project: Histogram,

    // -- shard scatter-gather (labeled by shard index) -----------------
    /// Per-shard scatter search latency.
    pub shard_scatter: HistogramFamily,
    /// Top-k merge time across shards.
    pub shard_merge: Histogram,
    /// Shards that failed to contribute to a scatter (panic, poisoned
    /// lock, join failure); the query degrades instead of aborting.
    pub shard_failures: Counter,

    // -- index stage timers (unlabeled; inside one shard's search) -----
    /// Primary graph/scan traversal time.
    pub index_traversal: Histogram,
    /// Secondary-store rerank time.
    pub index_rerank: Histogram,

    // -- per-query traversal accounting (labeled by collection) --------
    /// Graph hops per query.
    pub query_hops: HistogramFamily,
    /// Bytes of vector data read per query.
    pub query_touched: HistogramFamily,
    /// Tombstoned ids routed through (never returned), total.
    pub query_deleted_skipped: CounterFamily,
    /// Ids excluded by filter predicates, total.
    pub query_filtered: CounterFamily,

    // -- ingest lane ---------------------------------------------------
    pub ingest_inserts: Counter,
    pub ingest_deletes: Counter,
    pub ingest_errors: Counter,
    pub ingest_consolidations: Counter,
    /// Wall time of each consolidation pass.
    pub ingest_consolidate: Histogram,
    /// Worst live-shard tombstone fraction, updated after mutations.
    pub ingest_tombstone: Gauge,

    // -- mmap health ---------------------------------------------------
    /// Misaligned mapped sections that fell back to owned copies.
    pub mmap_fallbacks: Counter,
    /// `evict_mapped` calls (page-cache DONTNEED advisories).
    pub mmap_evictions: Counter,
}

impl Handles {
    fn register(r: &Registry) -> Handles {
        Handles {
            engine_queries: r.register_counter_family(
                "leanvec_engine_queries_total",
                "Queries answered, per collection.",
                "collection",
            ),
            engine_rejected: r.register_counter_family(
                "leanvec_engine_rejected_total",
                "Requests rejected at admission (quota or unknown collection).",
                "collection",
            ),
            engine_e2e: r.register_histogram_family(
                "leanvec_engine_e2e_seconds",
                "End-to-end request latency: submit to response.",
                "collection",
                NANOS,
            ),
            engine_search: r.register_histogram_family(
                "leanvec_engine_search_seconds",
                "Worker-side search time: scatter, merge and rerank.",
                "collection",
                NANOS,
            ),
            engine_uptime: r.register_gauge(
                "leanvec_engine_uptime_seconds",
                "Engine uptime, set at exposition time.",
            ),
            engine_deadline_exceeded: r.register_counter_family(
                "leanvec_engine_deadline_exceeded_total",
                "Requests that missed their deadline (shed or cancelled mid-search).",
                "collection",
            ),
            engine_shed: r.register_counter_family(
                "leanvec_engine_shed_total",
                "Requests shed at admission by overload protection.",
                "collection",
            ),
            engine_degraded: r.register_counter_family(
                "leanvec_engine_degraded_total",
                "Queries answered degraded: one or more shards failed to contribute.",
                "collection",
            ),
            engine_swaps: r.register_counter(
                "leanvec_engine_swaps_total",
                "Serve-index hot-swaps completed.",
            ),
            batcher_queue_wait: r.register_histogram(
                "leanvec_batcher_queue_wait_seconds",
                "Time requests spent waiting in the batcher queue.",
                NANOS,
            ),
            batcher_batch_size: r.register_histogram(
                "leanvec_batcher_batch_size_count",
                "Formed batch sizes.",
                1.0,
            ),
            batcher_project: r.register_histogram(
                "leanvec_batcher_project_seconds",
                "Per-group query projection (matmul / PJRT) time.",
                NANOS,
            ),
            shard_scatter: r.register_histogram_family(
                "leanvec_shard_scatter_seconds",
                "Per-shard scatter search latency.",
                "shard",
                NANOS,
            ),
            shard_merge: r.register_histogram(
                "leanvec_shard_merge_seconds",
                "Top-k merge time across shard results.",
                NANOS,
            ),
            shard_failures: r.register_counter(
                "leanvec_shard_failures_total",
                "Shards that failed to contribute to a scatter (panic or join failure).",
            ),
            index_traversal: r.register_histogram(
                "leanvec_index_traversal_seconds",
                "Primary traversal (graph beam search / scan) time.",
                NANOS,
            ),
            index_rerank: r.register_histogram(
                "leanvec_index_rerank_seconds",
                "Secondary-store rerank time.",
                NANOS,
            ),
            query_hops: r.register_histogram_family(
                "leanvec_query_hops_count",
                "Graph hops (nodes expanded) per query.",
                "collection",
                1.0,
            ),
            query_touched: r.register_histogram_family(
                "leanvec_query_touched_bytes",
                "Bytes of vector data read per query.",
                "collection",
                1.0,
            ),
            query_deleted_skipped: r.register_counter_family(
                "leanvec_query_deleted_skipped_total",
                "Tombstoned ids traversals routed through without returning.",
                "collection",
            ),
            query_filtered: r.register_counter_family(
                "leanvec_query_filtered_total",
                "Ids excluded by query filter predicates.",
                "collection",
            ),
            ingest_inserts: r.register_counter(
                "leanvec_ingest_inserts_total",
                "Insert mutations applied by the ingest lane.",
            ),
            ingest_deletes: r.register_counter(
                "leanvec_ingest_deletes_total",
                "Delete mutations applied by the ingest lane.",
            ),
            ingest_errors: r.register_counter(
                "leanvec_ingest_errors_total",
                "Mutations the ingest lane rejected (bad input, unknown id).",
            ),
            ingest_consolidations: r.register_counter(
                "leanvec_ingest_consolidations_total",
                "Consolidation passes triggered on the ingest lane.",
            ),
            ingest_consolidate: r.register_histogram(
                "leanvec_ingest_consolidate_seconds",
                "Wall time of each consolidation pass.",
                NANOS,
            ),
            ingest_tombstone: r.register_gauge(
                "leanvec_ingest_tombstone_ratio",
                "Worst per-shard live tombstone fraction after mutations.",
            ),
            mmap_fallbacks: r.register_counter(
                "leanvec_mmap_fallbacks_total",
                "Mapped sections copied to owned memory due to misalignment.",
            ),
            mmap_evictions: r.register_counter(
                "leanvec_mmap_evictions_total",
                "evict_mapped calls advising the kernel to drop cached pages.",
            ),
        }
    }
}

/// The process-wide handle set (registers on first use).
pub fn handles() -> &'static Handles {
    use std::sync::OnceLock;
    static HANDLES: OnceLock<Handles> = OnceLock::new();
    HANDLES.get_or_init(|| Handles::register(registry()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::metric_name_ok;

    #[test]
    fn every_catalog_name_follows_the_convention() {
        // exercise handles() so the catalog is registered, then walk
        // the registry: every leanvec_* family must pass the same
        // validator the lint rule applies to obs/ source
        let _ = handles();
        let snap = registry().snapshot();
        let mut seen = 0;
        for fam in snap.iter().filter(|f| !f.name.contains("_test_")) {
            assert!(
                metric_name_ok(&fam.name),
                "catalog name breaks convention: {}",
                fam.name
            );
            seen += 1;
        }
        assert!(seen >= 20, "expected the full catalog, saw {seen}");
    }

    #[test]
    fn handles_are_usable_and_shared() {
        let h = handles();
        let before = h.mmap_evictions.get();
        h.mmap_evictions.inc();
        // same static instance from a second call
        assert!(handles().mmap_evictions.get() >= before + 1 || !registry().is_enabled());
    }
}
