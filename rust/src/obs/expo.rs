//! Exposition: render a registry snapshot as Prometheus text format
//! v0.0.4 or JSON, plus a **strict** text-format parser used by the
//! unit tests and the CI scrape smoke to prove every dump round-trips.
//!
//! Histograms are exposed as Prometheus `summary` families (pre-
//! computed `quantile` children + `_sum`/`_count`): a single process
//! has no cross-instance aggregation to preserve, and quantiles keep
//! the dump readable next to the log-linear bucket array (the JSON
//! exposition carries the raw non-zero buckets for tooling that wants
//! them).

use super::registry::{FamilySnapshot, Kind, ValueSnap};
use crate::util::json::Json;

/// Quantiles every histogram exposes.
pub const QUANTILES: [f64; 4] = [0.5, 0.9, 0.99, 0.999];

fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn label_str(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

fn base_labels(child: &Option<(String, String)>) -> Vec<(String, String)> {
    match child {
        Some((k, v)) => vec![(k.clone(), v.clone())],
        None => Vec::new(),
    }
}

/// Render snapshots as Prometheus text exposition format v0.0.4.
pub fn render_text(snaps: &[FamilySnapshot]) -> String {
    let mut out = String::new();
    for fam in snaps {
        out.push_str(&format!("# HELP {} {}\n", fam.name, escape_help(&fam.help)));
        out.push_str(&format!("# TYPE {} {}\n", fam.name, fam.kind.as_str()));
        for (labels, value) in &fam.children {
            let base = base_labels(labels);
            match value {
                ValueSnap::Counter(v) => {
                    out.push_str(&format!("{}{} {}\n", fam.name, label_str(&base), v));
                }
                ValueSnap::Gauge(v) => {
                    out.push_str(&format!("{}{} {}\n", fam.name, label_str(&base), v));
                }
                ValueSnap::Hist(h) => {
                    for q in QUANTILES {
                        let mut ls = base.clone();
                        ls.push(("quantile".to_string(), format!("{q}")));
                        out.push_str(&format!(
                            "{}{} {}\n",
                            fam.name,
                            label_str(&ls),
                            h.quantile(q)
                        ));
                    }
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        fam.name,
                        label_str(&base),
                        h.sum()
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        fam.name,
                        label_str(&base),
                        h.count()
                    ));
                }
            }
        }
    }
    out
}

/// Render snapshots as a JSON document (`util::json`), carrying the
/// same quantiles plus the raw non-zero buckets.
pub fn render_json(snaps: &[FamilySnapshot]) -> Json {
    let families: Vec<Json> = snaps
        .iter()
        .map(|fam| {
            let samples: Vec<Json> = fam
                .children
                .iter()
                .map(|(labels, value)| {
                    let label_obj = Json::obj(
                        base_labels(labels)
                            .iter()
                            .map(|(k, v)| (k.as_str(), Json::str(v)))
                            .collect::<Vec<_>>(),
                    );
                    let mut fields = vec![("labels", label_obj)];
                    match value {
                        ValueSnap::Counter(v) => fields.push(("value", Json::num(*v as f64))),
                        ValueSnap::Gauge(v) => fields.push(("value", Json::num(*v))),
                        ValueSnap::Hist(h) => {
                            fields.push(("count", Json::num(h.count() as f64)));
                            fields.push(("sum", Json::num(h.sum())));
                            fields.push(("mean", Json::num(h.mean())));
                            fields.push(("p50", Json::num(h.quantile(0.5))));
                            fields.push(("p90", Json::num(h.quantile(0.9))));
                            fields.push(("p99", Json::num(h.quantile(0.99))));
                            fields.push(("p999", Json::num(h.quantile(0.999))));
                            let buckets: Vec<Json> = h
                                .nonzero_buckets()
                                .iter()
                                .map(|(lo, c)| {
                                    Json::arr(vec![Json::num(*lo), Json::num(*c as f64)])
                                })
                                .collect();
                            fields.push(("buckets", Json::arr(buckets)));
                        }
                    }
                    Json::obj(fields)
                })
                .collect();
            Json::obj(vec![
                ("name", Json::str(&fam.name)),
                ("type", Json::str(fam.kind.as_str())),
                ("help", Json::str(&fam.help)),
                ("samples", Json::arr(samples)),
            ])
        })
        .collect();
    Json::obj(vec![("families", Json::arr(families))])
}

// ---------------------------------------------------------------------
// strict text-format parser
// ---------------------------------------------------------------------

/// One parsed sample line.
#[derive(Clone, Debug, PartialEq)]
pub struct ParsedSample {
    /// Full sample name as written (`foo_seconds_count` etc.).
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// One parsed metric family with its samples.
#[derive(Clone, Debug)]
pub struct ParsedFamily {
    pub name: String,
    pub kind: String,
    pub help: Option<String>,
    pub samples: Vec<ParsedSample>,
}

fn is_name_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_' || c == ':'
}

fn is_name_char(c: char) -> bool {
    is_name_start(c) || c.is_ascii_digit()
}

fn valid_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if is_name_start(c) => {}
        _ => return false,
    }
    chars.all(is_name_char)
}

fn is_label_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if is_label_start(c) => {}
        _ => return false,
    }
    chars.all(|c| is_label_start(c) || c.is_ascii_digit())
}

/// Parse `{k="v",...}`; `rest` starts at `{`. Returns labels and the
/// remainder after the closing `}`.
fn parse_labels(rest: &str, lno: usize) -> Result<(Vec<(String, String)>, &str), String> {
    let body = &rest[1..];
    let mut labels = Vec::new();
    let mut chars = body.char_indices().peekable();
    loop {
        // closing brace (also accepts `{}` and a trailing comma)
        if let Some(&(i, c)) = chars.peek() {
            if c == '}' {
                return Ok((labels, &body[i + 1..]));
            }
        } else {
            return Err(format!("line {lno}: unterminated label set"));
        }
        // label name
        let start = match chars.peek() {
            Some(&(i, _)) => i,
            None => return Err(format!("line {lno}: unterminated label set")),
        };
        let mut eq = None;
        for (i, c) in chars.by_ref() {
            if c == '=' {
                eq = Some(i);
                break;
            }
            if !(is_label_start(c) || c.is_ascii_digit()) {
                return Err(format!("line {lno}: bad character {c:?} in label name"));
            }
        }
        let eq = eq.ok_or_else(|| format!("line {lno}: label without '='"))?;
        let name = &body[start..eq];
        if !valid_label_name(name) {
            return Err(format!("line {lno}: invalid label name {name:?}"));
        }
        // opening quote
        match chars.next() {
            Some((_, '"')) => {}
            _ => return Err(format!("line {lno}: label value must be quoted")),
        }
        // value with escapes
        let mut value = String::new();
        let mut closed = false;
        while let Some((_, c)) = chars.next() {
            match c {
                '"' => {
                    closed = true;
                    break;
                }
                '\\' => match chars.next() {
                    Some((_, '\\')) => value.push('\\'),
                    Some((_, '"')) => value.push('"'),
                    Some((_, 'n')) => value.push('\n'),
                    other => {
                        return Err(format!(
                            "line {lno}: bad escape {:?} in label value",
                            other.map(|(_, c)| c)
                        ))
                    }
                },
                '\n' => return Err(format!("line {lno}: raw newline in label value")),
                c => value.push(c),
            }
        }
        if !closed {
            return Err(format!("line {lno}: unterminated label value"));
        }
        labels.push((name.to_string(), value));
        // separator: ',' or '}'
        match chars.peek() {
            Some(&(_, ',')) => {
                chars.next();
            }
            Some(&(_, '}')) => {}
            _ => return Err(format!("line {lno}: expected ',' or '}}' after label")),
        }
    }
}

fn parse_value(s: &str, lno: usize) -> Result<f64, String> {
    match s {
        "+Inf" => return Ok(f64::INFINITY),
        "-Inf" => return Ok(f64::NEG_INFINITY),
        "NaN" => return Ok(f64::NAN),
        _ => {}
    }
    s.parse::<f64>()
        .map_err(|_| format!("line {lno}: invalid sample value {s:?}"))
}

/// Strictly parse Prometheus text exposition format v0.0.4 as this
/// crate emits it. Enforces: final newline; `# HELP`/`# TYPE` at most
/// once per family, TYPE before any of its samples; known TYPE values;
/// valid metric/label names; quoted+escaped label values; no
/// timestamps; every sample belongs to a declared family (`_sum`/
/// `_count` suffixes only on summary/histogram families, `quantile`
/// labels in [0,1], counter values finite and non-negative); no
/// duplicate sample (name + label set).
pub fn parse_text(text: &str) -> Result<Vec<ParsedFamily>, String> {
    if text.is_empty() {
        return Ok(Vec::new());
    }
    if !text.ends_with('\n') {
        return Err("exposition must end with a newline".to_string());
    }
    let mut families: Vec<ParsedFamily> = Vec::new();
    let mut seen: Vec<(String, Vec<(String, String)>)> = Vec::new();

    for (idx, line) in text.lines().enumerate() {
        let lno = idx + 1;
        if line.is_empty() {
            return Err(format!("line {lno}: empty line"));
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let (tag, rest) = match rest.split_once(' ') {
                Some(parts) => parts,
                None => return Err(format!("line {lno}: malformed comment line")),
            };
            let (name, payload) = match rest.split_once(' ') {
                Some((n, p)) => (n, Some(p)),
                None => (rest, None),
            };
            if !valid_name(name) {
                return Err(format!("line {lno}: invalid metric name {name:?}"));
            }
            match tag {
                "HELP" => {
                    if families.iter().any(|f| f.name == name) {
                        return Err(format!(
                            "line {lno}: HELP for {name} after TYPE or duplicate"
                        ));
                    }
                    families.push(ParsedFamily {
                        name: name.to_string(),
                        kind: String::new(),
                        help: Some(payload.unwrap_or("").to_string()),
                        samples: Vec::new(),
                    });
                }
                "TYPE" => {
                    let kind = payload
                        .ok_or_else(|| format!("line {lno}: TYPE without a value"))?;
                    if !matches!(kind, "counter" | "gauge" | "summary" | "histogram" | "untyped")
                    {
                        return Err(format!("line {lno}: unknown TYPE {kind:?}"));
                    }
                    if let Some(f) = families.iter_mut().find(|f| f.name == name) {
                        if !f.kind.is_empty() {
                            return Err(format!("line {lno}: duplicate TYPE for {name}"));
                        }
                        if !f.samples.is_empty() {
                            return Err(format!("line {lno}: TYPE for {name} after samples"));
                        }
                        f.kind = kind.to_string();
                    } else {
                        families.push(ParsedFamily {
                            name: name.to_string(),
                            kind: kind.to_string(),
                            help: None,
                            samples: Vec::new(),
                        });
                    }
                }
                other => return Err(format!("line {lno}: unknown comment tag {other:?}")),
            }
            continue;
        }
        if line.starts_with('#') {
            return Err(format!("line {lno}: bare comment lines are not emitted"));
        }

        // sample line: name[{labels}] value
        let name_end = line
            .char_indices()
            .find(|(_, c)| !is_name_char(*c))
            .map(|(i, _)| i)
            .unwrap_or(line.len());
        let name = &line[..name_end];
        if !valid_name(name) {
            return Err(format!("line {lno}: invalid sample name {name:?}"));
        }
        let rest = &line[name_end..];
        let (labels, rest) = if rest.starts_with('{') {
            parse_labels(rest, lno)?
        } else {
            (Vec::new(), rest)
        };
        let rest = rest
            .strip_prefix(' ')
            .ok_or_else(|| format!("line {lno}: expected single space before value"))?;
        if rest.contains(' ') {
            return Err(format!(
                "line {lno}: timestamps / trailing fields are not emitted"
            ));
        }
        let value = parse_value(rest, lno)?;

        // resolve the owning family
        let owner = families
            .iter_mut()
            .rev()
            .find(|f| {
                name == f.name
                    || ((name == format!("{}_sum", f.name) || name == format!("{}_count", f.name))
                        && matches!(f.kind.as_str(), "summary" | "histogram"))
            })
            .ok_or_else(|| format!("line {lno}: sample {name} has no declared family"))?;
        if owner.kind.is_empty() {
            return Err(format!("line {lno}: sample {name} before its TYPE line"));
        }
        match owner.kind.as_str() {
            "counter" => {
                if !(value.is_finite() && value >= 0.0) {
                    return Err(format!("line {lno}: counter value must be finite and >= 0"));
                }
                if labels.iter().any(|(k, _)| k == "quantile") {
                    return Err(format!("line {lno}: counter with quantile label"));
                }
            }
            "summary" => {
                if name == owner.name {
                    let q = labels
                        .iter()
                        .find(|(k, _)| k == "quantile")
                        .ok_or_else(|| {
                            format!("line {lno}: summary sample without quantile label")
                        })?;
                    let qv = q.1.parse::<f64>().map_err(|_| {
                        format!("line {lno}: quantile {:?} is not a number", q.1)
                    })?;
                    if !(0.0..=1.0).contains(&qv) {
                        return Err(format!("line {lno}: quantile {qv} outside [0, 1]"));
                    }
                } else if name.ends_with("_count") && !(value.is_finite() && value >= 0.0) {
                    return Err(format!("line {lno}: _count must be finite and >= 0"));
                }
            }
            _ => {}
        }
        let key = (name.to_string(), {
            let mut l = labels.clone();
            l.sort();
            l
        });
        if seen.contains(&key) {
            return Err(format!("line {lno}: duplicate sample {name} {labels:?}"));
        }
        seen.push(key);
        owner.samples.push(ParsedSample {
            name: name.to_string(),
            labels,
            value,
        });
    }

    for f in &families {
        if f.kind.is_empty() {
            return Err(format!("family {} has HELP but no TYPE", f.name));
        }
    }
    Ok(families)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;

    fn demo_registry() -> Registry {
        let r = Registry::new(true);
        let c = r.register_counter_family("leanvec_test_queries_total", "Queries answered.", "collection");
        c.with("default").add(42);
        c.with("tenant\"x\\y").inc();
        let g = r.register_gauge("leanvec_test_tombstone_ratio", "Live tombstone fraction.");
        g.set(0.125);
        let h = r.register_histogram("leanvec_test_e2e_seconds", "End-to-end latency.", 1e-9);
        for i in 1..=1000u64 {
            h.record(i * 1_000); // 1..=1000 µs
        }
        r
    }

    #[test]
    fn text_round_trips_through_parser() {
        let r = demo_registry();
        let text = render_text(&r.snapshot());
        let families = parse_text(&text).expect("round trip");
        assert_eq!(families.len(), 3);
        let q = &families[0];
        assert_eq!(q.name, "leanvec_test_queries_total");
        assert_eq!(q.kind, "counter");
        assert_eq!(q.help.as_deref(), Some("Queries answered."));
        assert_eq!(q.samples.len(), 2);
        assert_eq!(q.samples[0].labels[0].1, "default");
        assert_eq!(q.samples[0].value, 42.0);
        // escaped label value survives the round trip
        assert_eq!(q.samples[1].labels[0].1, "tenant\"x\\y");
        let h = &families[2];
        assert_eq!(h.kind, "summary");
        // 4 quantiles + sum + count
        assert_eq!(h.samples.len(), 6);
        let p50 = h
            .samples
            .iter()
            .find(|s| s.labels.iter().any(|(k, v)| k == "quantile" && v == "0.5"))
            .expect("p50 present");
        assert!((p50.value - 0.0005).abs() / 0.0005 < 0.05, "p50={}", p50.value);
        let count = h
            .samples
            .iter()
            .find(|s| s.name.ends_with("_count"))
            .expect("count present");
        assert_eq!(count.value, 1000.0);
    }

    #[test]
    fn json_exposition_carries_quantiles() {
        let r = demo_registry();
        let json = render_json(&r.snapshot());
        let fams = json.get("families").and_then(|f| f.as_arr()).expect("families");
        assert_eq!(fams.len(), 3);
        let hist = &fams[2];
        let sample = hist
            .get("samples")
            .and_then(|s| s.as_arr())
            .and_then(|s| s.first())
            .expect("sample");
        assert_eq!(sample.get("count").and_then(|c| c.as_f64()), Some(1000.0));
        assert!(sample.get("p999").and_then(|p| p.as_f64()).expect("p999") > 0.0);
        assert!(!sample
            .get("buckets")
            .and_then(|b| b.as_arr())
            .expect("buckets")
            .is_empty());
    }

    #[test]
    fn parser_rejects_malformed_input() {
        // no trailing newline
        assert!(parse_text("# TYPE a counter\na 1").is_err());
        // sample with no declared family
        assert!(parse_text("a 1\n").is_err());
        // sample before TYPE
        assert!(parse_text("# HELP a h\na 1\n# TYPE a counter\n").is_err());
        // unknown type
        assert!(parse_text("# TYPE a widget\na 1\n").is_err());
        // duplicate TYPE
        assert!(parse_text("# TYPE a counter\n# TYPE a counter\na 1\n").is_err());
        // negative counter
        assert!(parse_text("# TYPE a counter\na -1\n").is_err());
        // timestamp / trailing field
        assert!(parse_text("# TYPE a counter\na 1 123456\n").is_err());
        // bad label syntax
        assert!(parse_text("# TYPE a counter\na{x=1} 1\n").is_err());
        // unterminated label value
        assert!(parse_text("# TYPE a counter\na{x=\"y} 1\n").is_err());
        // duplicate sample
        assert!(parse_text("# TYPE a counter\na 1\na 1\n").is_err());
        // quantile outside [0,1]
        assert!(parse_text("# TYPE s summary\ns{quantile=\"1.5\"} 1\n").is_err());
        // summary sample missing quantile
        assert!(parse_text("# TYPE s summary\ns 1\n").is_err());
        // _sum on a counter family
        assert!(parse_text("# TYPE a counter\na_sum 1\n").is_err());
        // HELP without TYPE
        assert!(parse_text("# HELP a h\n").is_err());
        // bad metric name
        assert!(parse_text("# TYPE 9a counter\n9a 1\n").is_err());
        // empty line
        assert!(parse_text("# TYPE a counter\n\na 1\n").is_err());
    }

    #[test]
    fn parser_accepts_edge_values() {
        let ok = parse_text("# TYPE g gauge\ng{s=\"0\"} +Inf\ng NaN\ng{s=\"1\"} -0.5\n")
            .expect("gauges accept any float");
        assert_eq!(ok[0].samples.len(), 3);
        assert!(ok[0].samples[0].value.is_infinite());
        assert!(ok[0].samples[1].value.is_nan());
    }

    #[test]
    fn empty_input_parses_to_nothing() {
        assert!(parse_text("").expect("empty ok").is_empty());
    }
}
