//! The process-wide metric [`Registry`]: registration is idempotent and
//! mutex-guarded (startup only); the handles it returns — [`Counter`],
//! [`Gauge`], [`Histogram`] — are cheap clones around shared atomics,
//! and recording through them is lock-free. Labeled families
//! ([`CounterFamily`], [`GaugeFamily`], [`HistogramFamily`]) resolve a
//! `{label="value"}` child once (read-write lock, startup) into the
//! same lock-free handle types; child cardinality is capped at
//! [`MAX_CHILDREN`], beyond which every new label value collapses into
//! a shared `"_overflow"` child so a label-cardinality bug can never
//! OOM the registry.
//!
//! The whole layer is disabled by `LEANVEC_NO_TELEMETRY=1` (checked
//! once at registry construction, overridable via [`set_enabled`] for
//! A/B overhead benches): disabled handles no-op on a single relaxed
//! boolean load.

use super::hist::{HistCore, HistSnapshot};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError, RwLock};

/// Stripes for sharded counters: spreads hot counters across cache
/// lines so concurrent workers don't serialize on one `fetch_add`.
const STRIPES: usize = 8;

/// Per-family child cap; the next distinct label value after this maps
/// to the shared `"_overflow"` child.
pub const MAX_CHILDREN: usize = 32;

/// Label value that absorbs children past [`MAX_CHILDREN`].
pub const OVERFLOW_LABEL: &str = "_overflow";

#[repr(align(64))]
struct PaddedU64(AtomicU64);

fn stripe_id() -> usize {
    use std::cell::Cell;
    static NEXT_STRIPE: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static STRIPE: Cell<usize> = Cell::new(usize::MAX);
    }
    STRIPE.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            // ORDERING: Relaxed — ticket dispenser assigning each thread
            // a stripe; no ordering with any other memory required.
            v = NEXT_STRIPE.fetch_add(1, Ordering::Relaxed) % STRIPES;
            s.set(v);
        }
        v
    })
}

/// Shared core of a sharded monotonic counter.
pub struct CounterCore {
    stripes: [PaddedU64; STRIPES],
}

impl CounterCore {
    fn new() -> CounterCore {
        CounterCore {
            stripes: std::array::from_fn(|_| PaddedU64(AtomicU64::new(0))),
        }
    }

    #[inline]
    fn add(&self, n: u64) {
        // ORDERING: Relaxed — monotonic stat counter; exposition sums
        // the stripes and tolerates momentarily missing increments.
        self.stripes[stripe_id()].0.fetch_add(n, Ordering::Relaxed);
    }

    fn get(&self) -> u64 {
        self.stripes
            .iter()
            // ORDERING: Relaxed — reporting-only read of each stripe.
            .map(|s| s.0.load(Ordering::Relaxed))
            .fold(0u64, |a, b| a.wrapping_add(b))
    }
}

/// Shared core of a gauge: an `f64` stored as bits so `set` stays a
/// single atomic store.
pub struct GaugeCore {
    bits: AtomicU64,
}

impl GaugeCore {
    fn new() -> GaugeCore {
        GaugeCore {
            bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    #[inline]
    fn set(&self, v: f64) {
        // ORDERING: Relaxed — last-writer-wins instantaneous reading;
        // no other memory is published alongside it.
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    fn add(&self, delta: f64) {
        // ORDERING: Relaxed — lone CAS loop over the gauge's own bits;
        // statistical value, no cross-location ordering needed.
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + delta).to_bits();
            // ORDERING: Relaxed — see above; retry supplies the fresh value.
            match self
                .bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    fn get(&self) -> f64 {
        // ORDERING: Relaxed — reporting-only read.
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Lock-free monotonic counter handle.
#[derive(Clone)]
pub struct Counter {
    core: Arc<CounterCore>,
    enabled: Arc<AtomicBool>,
}

impl Counter {
    /// A counter attached to nothing — records are kept (always
    /// enabled) but never exported. For tests and detached aggregation.
    pub fn detached() -> Counter {
        Counter {
            core: Arc::new(CounterCore::new()),
            enabled: Arc::new(AtomicBool::new(true)),
        }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        // ORDERING: Relaxed — gate flag only suppresses stat recording;
        // nothing is ordered against it.
        if self.enabled.load(Ordering::Relaxed) {
            self.core.add(n);
        }
    }

    /// Current total (sum over stripes).
    pub fn get(&self) -> u64 {
        self.core.get()
    }
}

/// Lock-free gauge handle (f64; `set` for levels, `add`/`sub` for
/// up-down counts).
#[derive(Clone)]
pub struct Gauge {
    core: Arc<GaugeCore>,
    enabled: Arc<AtomicBool>,
}

impl Gauge {
    pub fn detached() -> Gauge {
        Gauge {
            core: Arc::new(GaugeCore::new()),
            enabled: Arc::new(AtomicBool::new(true)),
        }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        // ORDERING: Relaxed — gate flag, see Counter::add.
        if self.enabled.load(Ordering::Relaxed) {
            self.core.set(v);
        }
    }

    #[inline]
    pub fn add(&self, delta: f64) {
        // ORDERING: Relaxed — gate flag, see Counter::add.
        if self.enabled.load(Ordering::Relaxed) {
            self.core.add(delta);
        }
    }

    #[inline]
    pub fn sub(&self, delta: f64) {
        self.add(-delta);
    }

    pub fn get(&self) -> f64 {
        self.core.get()
    }
}

/// Lock-free histogram handle; see [`super::hist`] for bucket math.
#[derive(Clone)]
pub struct Histogram {
    core: Arc<HistCore>,
    enabled: Arc<AtomicBool>,
}

impl Histogram {
    /// A histogram attached to no registry — always records. The
    /// post-hoc metrics aggregation uses these so offline summaries run
    /// through the exact same bucket/quantile code as live exposition.
    pub fn detached(scale: f64) -> Histogram {
        Histogram {
            core: Arc::new(HistCore::new(scale)),
            enabled: Arc::new(AtomicBool::new(true)),
        }
    }

    /// Record a raw observation (nanos for `*_seconds` series).
    #[inline]
    pub fn record(&self, v: u64) {
        // ORDERING: Relaxed — gate flag, see Counter::add.
        if self.enabled.load(Ordering::Relaxed) {
            self.core.record(v);
        }
    }

    /// Record a duration in seconds into a nanosecond-based series.
    #[inline]
    pub fn record_seconds(&self, s: f64) {
        if s.is_finite() && s >= 0.0 {
            self.record((s * 1e9) as u64);
        }
    }

    pub fn snapshot(&self) -> HistSnapshot {
        self.core.snapshot()
    }
}

/// What kind of instrument a family holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    pub fn as_str(&self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            // histograms expose as quantile summaries — see expo.rs
            Kind::Histogram => "summary",
        }
    }
}

enum Child {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// One named metric family: either a single unlabeled instrument or a
/// set of children keyed by the value of `label_key`.
struct Family {
    name: String,
    help: String,
    kind: Kind,
    /// Histogram raw-value multiplier at exposition (1e-9: nanos->s).
    scale: f64,
    /// `None` = unlabeled singleton; `Some(key)` = one label dimension.
    label_key: Option<String>,
    children: RwLock<Vec<(String, Child)>>,
    enabled: Arc<AtomicBool>,
}

impl Family {
    fn make_child(&self) -> Child {
        match self.kind {
            Kind::Counter => Child::Counter(Counter {
                core: Arc::new(CounterCore::new()),
                enabled: Arc::clone(&self.enabled),
            }),
            Kind::Gauge => Child::Gauge(Gauge {
                core: Arc::new(GaugeCore::new()),
                enabled: Arc::clone(&self.enabled),
            }),
            Kind::Histogram => Child::Histogram(Histogram {
                core: Arc::new(HistCore::new(self.scale)),
                enabled: Arc::clone(&self.enabled),
            }),
        }
    }

    /// Get or create the child for `value`, applying the cardinality
    /// cap. The singleton (unlabeled) child uses `value = ""`.
    fn child(&self, value: &str) -> Child {
        {
            let kids = self
                .children
                .read()
                .unwrap_or_else(PoisonError::into_inner);
            if let Some((_, c)) = kids.iter().find(|(v, _)| v == value) {
                return clone_child(c);
            }
        }
        let mut kids = self
            .children
            .write()
            .unwrap_or_else(PoisonError::into_inner);
        // racing creator may have won between the locks
        if let Some((_, c)) = kids.iter().find(|(v, _)| v == value) {
            return clone_child(c);
        }
        let effective = if self.label_key.is_some() && kids.len() >= MAX_CHILDREN {
            OVERFLOW_LABEL
        } else {
            value
        };
        if let Some((_, c)) = kids.iter().find(|(v, _)| v == effective) {
            return clone_child(c);
        }
        let child = self.make_child();
        let out = clone_child(&child);
        kids.push((effective.to_string(), child));
        out
    }
}

fn clone_child(c: &Child) -> Child {
    match c {
        Child::Counter(h) => Child::Counter(h.clone()),
        Child::Gauge(h) => Child::Gauge(h.clone()),
        Child::Histogram(h) => Child::Histogram(h.clone()),
    }
}

/// A labeled counter family; resolve children with [`CounterFamily::with`].
#[derive(Clone)]
pub struct CounterFamily {
    family: Arc<Family>,
}

impl CounterFamily {
    /// The child counter for `{label_key="value"}` (resolve once,
    /// record lock-free forever after).
    pub fn with(&self, value: &str) -> Counter {
        match self.family.child(value) {
            Child::Counter(c) => c,
            // registration guarantees kind; unreachable by construction
            _ => Counter::detached(),
        }
    }
}

/// A labeled gauge family.
#[derive(Clone)]
pub struct GaugeFamily {
    family: Arc<Family>,
}

impl GaugeFamily {
    pub fn with(&self, value: &str) -> Gauge {
        match self.family.child(value) {
            Child::Gauge(g) => g,
            _ => Gauge::detached(),
        }
    }
}

/// A labeled histogram family.
#[derive(Clone)]
pub struct HistogramFamily {
    family: Arc<Family>,
}

impl HistogramFamily {
    pub fn with(&self, value: &str) -> Histogram {
        match self.family.child(value) {
            Child::Histogram(h) => h,
            _ => Histogram::detached(1.0),
        }
    }
}

/// Point-in-time value of one family child.
#[derive(Clone, Debug)]
pub enum ValueSnap {
    Counter(u64),
    Gauge(f64),
    Hist(HistSnapshot),
}

/// Point-in-time copy of one family for exposition.
#[derive(Clone)]
pub struct FamilySnapshot {
    pub name: String,
    pub help: String,
    pub kind: Kind,
    /// `(Some((label_key, label_value)) | None, value)` per child,
    /// label values sorted.
    pub children: Vec<(Option<(String, String)>, ValueSnap)>,
}

/// The metric registry. One global instance serves the process (see
/// [`registry`]); tests may build private ones.
pub struct Registry {
    enabled: Arc<AtomicBool>,
    families: Mutex<Vec<Arc<Family>>>,
}

impl Registry {
    pub fn new(enabled: bool) -> Registry {
        Registry {
            enabled: Arc::new(AtomicBool::new(enabled)),
            families: Mutex::new(Vec::new()),
        }
    }

    pub fn is_enabled(&self) -> bool {
        // ORDERING: Relaxed — gate flag read, nothing ordered on it.
        self.enabled.load(Ordering::Relaxed)
    }

    /// Flip recording on/off at runtime (bench A/B harness).
    pub fn set_enabled(&self, on: bool) {
        // ORDERING: Relaxed — gate flag write, takes effect eventually.
        self.enabled.store(on, Ordering::Relaxed);
    }

    fn family(
        &self,
        name: &str,
        help: &str,
        kind: Kind,
        scale: f64,
        label_key: Option<&str>,
    ) -> Arc<Family> {
        let mut fams = self
            .families
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        if let Some(f) = fams.iter().find(|f| f.name == name) {
            return Arc::clone(f);
        }
        let f = Arc::new(Family {
            name: name.to_string(),
            help: help.to_string(),
            kind,
            scale,
            label_key: label_key.map(str::to_string),
            children: RwLock::new(Vec::new()),
            enabled: Arc::clone(&self.enabled),
        });
        fams.push(Arc::clone(&f));
        f
    }

    /// Register (idempotently) an unlabeled counter.
    pub fn register_counter(&self, name: &str, help: &str) -> Counter {
        let f = self.family(name, help, Kind::Counter, 1.0, None);
        match f.child("") {
            Child::Counter(c) => c,
            _ => Counter::detached(),
        }
    }

    /// Register an unlabeled gauge.
    pub fn register_gauge(&self, name: &str, help: &str) -> Gauge {
        let f = self.family(name, help, Kind::Gauge, 1.0, None);
        match f.child("") {
            Child::Gauge(g) => g,
            _ => Gauge::detached(),
        }
    }

    /// Register an unlabeled histogram; `scale` converts raw recorded
    /// values to exposed units (1e-9 for nanosecond recordings exposed
    /// as seconds).
    pub fn register_histogram(&self, name: &str, help: &str, scale: f64) -> Histogram {
        let f = self.family(name, help, Kind::Histogram, scale, None);
        match f.child("") {
            Child::Histogram(h) => h,
            _ => Histogram::detached(scale),
        }
    }

    /// Register a counter family labeled by `label`.
    pub fn register_counter_family(&self, name: &str, help: &str, label: &str) -> CounterFamily {
        CounterFamily {
            family: self.family(name, help, Kind::Counter, 1.0, Some(label)),
        }
    }

    /// Register a gauge family labeled by `label`.
    pub fn register_gauge_family(&self, name: &str, help: &str, label: &str) -> GaugeFamily {
        GaugeFamily {
            family: self.family(name, help, Kind::Gauge, 1.0, Some(label)),
        }
    }

    /// Register a histogram family labeled by `label`.
    pub fn register_histogram_family(
        &self,
        name: &str,
        help: &str,
        label: &str,
        scale: f64,
    ) -> HistogramFamily {
        HistogramFamily {
            family: self.family(name, help, Kind::Histogram, scale, Some(label)),
        }
    }

    /// Snapshot every family for exposition, registration order, label
    /// values sorted within a family.
    pub fn snapshot(&self) -> Vec<FamilySnapshot> {
        let fams: Vec<Arc<Family>> = {
            let guard = self
                .families
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            guard.iter().map(Arc::clone).collect()
        };
        fams.iter()
            .map(|f| {
                let kids = f.children.read().unwrap_or_else(PoisonError::into_inner);
                let mut children: Vec<(Option<(String, String)>, ValueSnap)> = kids
                    .iter()
                    .map(|(value, child)| {
                        let labels = f
                            .label_key
                            .as_ref()
                            .map(|k| (k.clone(), value.clone()));
                        let snap = match child {
                            Child::Counter(c) => ValueSnap::Counter(c.get()),
                            Child::Gauge(g) => ValueSnap::Gauge(g.get()),
                            Child::Histogram(h) => ValueSnap::Hist(h.snapshot()),
                        };
                        (labels, snap)
                    })
                    .collect();
                children.sort_by(|a, b| a.0.cmp(&b.0));
                FamilySnapshot {
                    name: f.name.clone(),
                    help: f.help.clone(),
                    kind: f.kind,
                    children,
                }
            })
            .collect()
    }

    /// Number of children currently held by family `name` (tests).
    pub fn child_count(&self, name: &str) -> usize {
        let fams = self
            .families
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        fams.iter()
            .find(|f| f.name == name)
            .map(|f| {
                f.children
                    .read()
                    .unwrap_or_else(PoisonError::into_inner)
                    .len()
            })
            .unwrap_or(0)
    }
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry every static handle registers into.
/// Telemetry starts disabled when `LEANVEC_NO_TELEMETRY=1`.
pub fn registry() -> &'static Registry {
    GLOBAL.get_or_init(|| {
        let off = std::env::var("LEANVEC_NO_TELEMETRY").map(|v| v == "1") == Ok(true);
        Registry::new(!off)
    })
}

/// Is process-wide telemetry recording on? Instrumented call sites use
/// this to skip `Instant::now()` pairs entirely when it's off.
#[inline]
pub fn enabled() -> bool {
    registry().is_enabled()
}

/// Flip process-wide telemetry (bench overhead A/B).
pub fn set_enabled(on: bool) {
    registry().set_enabled(on);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts_across_threads() {
        let r = Registry::new(true);
        let c = r.register_counter("leanvec_test_items_total", "test");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 40_000);
    }

    #[test]
    fn gauge_set_add_sub() {
        let g = Gauge::detached();
        g.set(5.5);
        assert_eq!(g.get(), 5.5);
        g.add(1.5);
        g.sub(3.0);
        assert!((g.get() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn disabled_registry_drops_records() {
        let r = Registry::new(false);
        let c = r.register_counter("leanvec_test_off_total", "test");
        let h = r.register_histogram("leanvec_test_off_seconds", "test", 1e-9);
        c.inc();
        h.record(123);
        assert_eq!(c.get(), 0);
        assert_eq!(h.snapshot().count(), 0);
        r.set_enabled(true);
        c.inc();
        assert_eq!(c.get(), 1);
    }

    #[test]
    fn registration_is_idempotent() {
        let r = Registry::new(true);
        let a = r.register_counter("leanvec_test_same_total", "test");
        let b = r.register_counter("leanvec_test_same_total", "test");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "same name must share one core");
        assert_eq!(r.snapshot().iter().filter(|f| f.name == "leanvec_test_same_total").count(), 1);
    }

    #[test]
    fn family_children_are_distinct_and_shared() {
        let r = Registry::new(true);
        let fam = r.register_counter_family("leanvec_test_fam_total", "test", "collection");
        fam.with("a").inc();
        fam.with("a").inc();
        fam.with("b").inc();
        assert_eq!(fam.with("a").get(), 2);
        assert_eq!(fam.with("b").get(), 1);
    }

    #[test]
    fn cardinality_cap_folds_into_overflow() {
        let r = Registry::new(true);
        let fam = r.register_counter_family("leanvec_test_cap_total", "test", "collection");
        for i in 0..(MAX_CHILDREN + 10) {
            fam.with(&format!("tenant-{i}")).inc();
        }
        // cap + the shared overflow child
        assert_eq!(r.child_count("leanvec_test_cap_total"), MAX_CHILDREN + 1);
        // the 10 overflowing tenants all landed on one child
        assert_eq!(fam.with(OVERFLOW_LABEL).get(), 10);
        // existing children still resolve to themselves
        fam.with("tenant-0").inc();
        assert_eq!(fam.with("tenant-0").get(), 2);
    }

    #[test]
    fn snapshot_orders_label_values() {
        let r = Registry::new(true);
        let fam = r.register_gauge_family("leanvec_test_order_ratio", "test", "shard");
        fam.with("2").set(2.0);
        fam.with("0").set(0.5);
        fam.with("1").set(1.0);
        let snap = r.snapshot();
        let f = snap.iter().find(|f| f.name == "leanvec_test_order_ratio");
        let f = f.expect("family present");
        let vals: Vec<&str> = f
            .children
            .iter()
            .filter_map(|(l, _)| l.as_ref().map(|(_, v)| v.as_str()))
            .collect();
        assert_eq!(vals, ["0", "1", "2"]);
    }

    #[test]
    fn histogram_record_snapshot_race_soak() {
        // TSan target: concurrent record() against snapshot()
        let r = Registry::new(true);
        let h = r.register_histogram("leanvec_test_race_seconds", "test", 1.0);
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..25_000u64 {
                        h.record(i % 1_000 + t);
                    }
                });
            }
            let h2 = h.clone();
            s.spawn(move || {
                for _ in 0..50 {
                    let _ = h2.snapshot();
                }
            });
        });
        assert_eq!(h.snapshot().count(), 100_000);
    }
}
