//! The slow-query flight recorder: a fixed-size ring that keeps the
//! slowest queries seen so far (plus a small round-robin sample of
//! ordinary ones) with their full per-stage breakdown, so "why was
//! that query slow?" is answerable after the fact without tracing.
//!
//! Capture is non-blocking: every slot pairs an atomic latency tag
//! with a `try_lock`-only mutex, so a worker thread never waits — if
//! two workers race for the same victim slot, one record is dropped
//! (latency observations still land in the histograms; the recorder is
//! a forensic sample, not an accounting source). Deciding *whether* to
//! capture costs one atomic scan of the ring; building the record (a
//! few small allocations) happens only for queries that qualify.

use crate::index::leanvec_index::SearchParams;
use crate::index::query::QueryStats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Why a record was kept.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaptureKind {
    /// Among the slowest queries seen so far.
    Slow,
    /// Periodic sample of ordinary traffic (every Nth query).
    Sampled,
    /// The request did not complete normally (shed, deadline miss,
    /// degraded answer): kept in the failure ring regardless of
    /// latency.
    Failure,
}

/// How the request resolved. Every admitted request resolves to exactly
/// one outcome; anything except [`Outcome::Ok`] also lands in the
/// recorder's failure ring via [`FlightRecorder::capture_failure`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Outcome {
    /// Full answer from every shard, inside the deadline.
    #[default]
    Ok,
    /// Answer returned, but one or more shards failed to contribute.
    Degraded,
    /// The request ran out of deadline (shed expired in queue, or
    /// cancelled mid-search without `allow_partial`).
    DeadlineExceeded,
    /// Partial results returned after a deadline miss (`allow_partial`).
    Partial,
    /// Rejected at admission by overload protection.
    Shed,
}

impl std::fmt::Display for Outcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Outcome::Ok => "ok",
            Outcome::Degraded => "degraded",
            Outcome::DeadlineExceeded => "deadline-exceeded",
            Outcome::Partial => "partial",
            Outcome::Shed => "shed",
        };
        f.write_str(s)
    }
}

/// Everything the worker knew about one recorded query.
#[derive(Clone, Debug)]
pub struct FlightRecord {
    /// Request id from the serving protocol.
    pub id: u64,
    pub collection: String,
    pub kind: CaptureKind,
    /// End-to-end latency (submit -> response), seconds.
    pub e2e_seconds: f64,
    /// Time spent waiting in the batcher queue.
    pub queue_seconds: f64,
    /// This request's share of its batch's projection matmul.
    pub project_seconds: f64,
    /// Worker-side search (scatter + merge + rerank), seconds.
    pub search_seconds: f64,
    /// Merge step of the scatter-gather, seconds (0 for single shard).
    pub merge_seconds: f64,
    /// Per-shard scatter latency, indexed by shard (empty when the
    /// index is unsharded or telemetry timing was off).
    pub shard_seconds: Vec<f64>,
    /// Traversal accounting from the search itself.
    pub stats: QueryStats,
    /// The resolved (post-default) search knobs this query ran with.
    pub params: SearchParams,
    pub k: usize,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    /// How the request resolved (ok / degraded / deadline / shed).
    pub outcome: Outcome,
}

impl std::fmt::Display for FlightRecord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "req {} [{}] {:?} {} e2e {:.3}ms = queue {:.3} + project {:.3} + search {:.3} \
             (merge {:.3}) ms | window {} rerank {} k {} batch {} | hops {} bytes {}",
            self.id,
            self.collection,
            self.kind,
            self.outcome,
            self.e2e_seconds * 1e3,
            self.queue_seconds * 1e3,
            self.project_seconds * 1e3,
            self.search_seconds * 1e3,
            self.merge_seconds * 1e3,
            self.params.window,
            self.params.rerank_window,
            self.k,
            self.batch_size,
            self.stats.hops,
            self.stats.bytes_touched,
        )?;
        if !self.shard_seconds.is_empty() {
            let per: Vec<String> = self
                .shard_seconds
                .iter()
                .map(|s| format!("{:.3}", s * 1e3))
                .collect();
            write!(f, " | shards ms [{}]", per.join(", "))?;
        }
        Ok(())
    }
}

struct Slot {
    /// Latency tag of the record held (nanos; 0 = empty). Read without
    /// the lock to pick a victim cheaply.
    e2e_nanos: AtomicU64,
    data: Mutex<Option<FlightRecord>>,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            e2e_nanos: AtomicU64::new(0),
            data: Mutex::new(None),
        }
    }
}

/// Default capacity of the slowest-queries ring.
pub const DEFAULT_SLOW_SLOTS: usize = 48;
/// Default capacity of the periodic-sample ring.
pub const DEFAULT_SAMPLED_SLOTS: usize = 16;
/// Default sampling period (every Nth query lands in the sample ring).
pub const DEFAULT_SAMPLE_EVERY: u64 = 256;
/// Capacity of the failure ring (shed / deadline-exceeded / degraded
/// requests, kept round-robin regardless of latency).
pub const FAILURE_SLOTS: usize = 16;

/// The recorder itself; one per [`Engine`].
///
/// [`Engine`]: crate::coordinator::Engine
pub struct FlightRecorder {
    slow: Vec<Slot>,
    sampled: Vec<Slot>,
    /// Round-robin ring of abnormal outcomes: unlike the slow ring,
    /// admission here is by outcome, not latency — a 50µs shed request
    /// is forensic evidence, however fast it failed.
    failures: Vec<Slot>,
    seq: AtomicU64,
    fail_seq: AtomicU64,
    sample_every: u64,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder::new(
            DEFAULT_SLOW_SLOTS,
            DEFAULT_SAMPLED_SLOTS,
            DEFAULT_SAMPLE_EVERY,
        )
    }
}

impl FlightRecorder {
    pub fn new(slow_slots: usize, sampled_slots: usize, sample_every: u64) -> FlightRecorder {
        FlightRecorder {
            slow: (0..slow_slots.max(1)).map(|_| Slot::new()).collect(),
            sampled: (0..sampled_slots).map(|_| Slot::new()).collect(),
            failures: (0..FAILURE_SLOTS).map(|_| Slot::new()).collect(),
            seq: AtomicU64::new(0),
            fail_seq: AtomicU64::new(0),
            sample_every,
        }
    }

    /// Record an abnormal outcome (shed / deadline-exceeded / degraded)
    /// into the failure ring, round-robin, regardless of how fast the
    /// request failed. Non-blocking like every other capture: a
    /// contended slot drops the record.
    pub fn capture_failure(&self, mut record: FlightRecord) {
        if !crate::obs::enabled() || self.failures.is_empty() {
            return;
        }
        record.kind = CaptureKind::Failure;
        // ORDERING: Relaxed — ring cursor only; the slot lock owns the
        // data it points at.
        let n = self.fail_seq.fetch_add(1, Ordering::Relaxed);
        let idx = (n % self.failures.len() as u64) as usize;
        let nanos = if record.e2e_seconds.is_finite() && record.e2e_seconds > 0.0 {
            ((record.e2e_seconds * 1e9) as u64).max(1)
        } else {
            1
        };
        if let Ok(mut guard) = self.failures[idx].data.try_lock() {
            *guard = Some(record);
            // ORDERING: Relaxed — advisory tag, see above.
            self.failures[idx].e2e_nanos.store(nanos, Ordering::Relaxed);
        }
    }

    /// Total abnormal outcomes offered to the failure ring.
    pub fn failures_seen(&self) -> u64 {
        // ORDERING: Relaxed — reporting only.
        self.fail_seq.load(Ordering::Relaxed)
    }

    /// Offer one finished query. `build` runs only when the query
    /// qualifies for the slow ring (slower than the current fastest
    /// kept record, or the ring has room) or for the periodic sample.
    pub fn capture_with<F: FnOnce() -> FlightRecord>(&self, e2e_seconds: f64, build: F) {
        if !crate::obs::enabled() {
            return;
        }
        // tag 0 means "empty", so clamp real latencies to >= 1ns
        let nanos = if e2e_seconds.is_finite() && e2e_seconds > 0.0 {
            ((e2e_seconds * 1e9) as u64).max(1)
        } else {
            1
        };
        // ORDERING: Relaxed — sequence number only drives sampling
        // cadence; no memory is published through it.
        let n = self.seq.fetch_add(1, Ordering::Relaxed);
        let sample_due =
            !self.sampled.is_empty() && self.sample_every > 0 && n % self.sample_every == 0;

        // cheapest-victim scan of the slow ring
        let mut victim = 0usize;
        let mut victim_nanos = u64::MAX;
        for (i, slot) in self.slow.iter().enumerate() {
            // ORDERING: Relaxed — advisory victim pick; the slot lock
            // re-checks before replacing.
            let v = slot.e2e_nanos.load(Ordering::Relaxed);
            if v < victim_nanos {
                victim_nanos = v;
                victim = i;
            }
        }
        let slow_due = nanos > victim_nanos;
        if !slow_due && !sample_due {
            return;
        }

        let mut record = build();
        if slow_due {
            record.kind = CaptureKind::Slow;
            if let Ok(mut guard) = self.slow[victim].data.try_lock() {
                // re-check under the lock: a racing writer may have
                // installed something slower in this slot already
                // ORDERING: Relaxed — tag re-read; lock owns the data.
                if nanos > self.slow[victim].e2e_nanos.load(Ordering::Relaxed) {
                    *guard = Some(record.clone());
                    // ORDERING: Relaxed — tag write while holding the
                    // slot lock; readers treat it as advisory only.
                    self.slow[victim].e2e_nanos.store(nanos, Ordering::Relaxed);
                }
            }
            // contended or out-raced: drop the record, by design
        }
        if sample_due {
            record.kind = CaptureKind::Sampled;
            let idx = ((n / self.sample_every.max(1)) % self.sampled.len() as u64) as usize;
            if let Ok(mut guard) = self.sampled[idx].data.try_lock() {
                *guard = Some(record);
                // ORDERING: Relaxed — advisory tag, see above.
                self.sampled[idx].e2e_nanos.store(nanos, Ordering::Relaxed);
            }
        }
    }

    /// Every record currently held, slowest first (sampled records
    /// follow their latency order like any other).
    pub fn records(&self) -> Vec<FlightRecord> {
        let mut out = Vec::new();
        for slot in self
            .slow
            .iter()
            .chain(self.sampled.iter())
            .chain(self.failures.iter())
        {
            if let Ok(guard) = slot.data.try_lock() {
                if let Some(r) = guard.as_ref() {
                    out.push(r.clone());
                }
            }
        }
        out.sort_by(|a, b| b.e2e_seconds.total_cmp(&a.e2e_seconds));
        out
    }

    /// Total queries offered to the recorder.
    pub fn seen(&self) -> u64 {
        // ORDERING: Relaxed — reporting only.
        self.seq.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, e2e: f64) -> FlightRecord {
        FlightRecord {
            id,
            collection: "default".to_string(),
            kind: CaptureKind::Slow,
            e2e_seconds: e2e,
            queue_seconds: 0.0,
            project_seconds: 0.0,
            search_seconds: e2e,
            merge_seconds: 0.0,
            shard_seconds: Vec::new(),
            stats: QueryStats::default(),
            params: SearchParams::default(),
            k: 10,
            batch_size: 1,
            outcome: Outcome::Ok,
        }
    }

    #[test]
    fn failure_ring_keeps_fast_failures() {
        crate::obs::set_enabled(true);
        let fr = FlightRecorder::new(2, 0, 0);
        // saturate the slow ring with genuinely slow queries
        fr.capture_with(1.0, || rec(0, 1.0));
        fr.capture_with(0.9, || rec(1, 0.9));
        // a 50µs shed request would never qualify as slow...
        let mut shed = rec(2, 50e-6);
        shed.outcome = Outcome::Shed;
        fr.capture_failure(shed);
        let records = fr.records();
        let failure: Vec<_> = records
            .iter()
            .filter(|r| r.kind == CaptureKind::Failure)
            .collect();
        assert_eq!(failure.len(), 1, "...but the failure ring keeps it");
        assert_eq!(failure[0].id, 2);
        assert_eq!(failure[0].outcome, Outcome::Shed);
        assert_eq!(fr.failures_seen(), 1);
        // the Display line carries the outcome tag
        let line = format!("{}", failure[0]);
        assert!(line.contains("shed"), "{line}");
    }

    #[test]
    fn failure_ring_is_round_robin() {
        crate::obs::set_enabled(true);
        let fr = FlightRecorder::new(1, 0, 0);
        for i in 0..(FAILURE_SLOTS as u64 * 2) {
            let mut r = rec(i, 1e-5);
            r.outcome = Outcome::DeadlineExceeded;
            fr.capture_failure(r);
        }
        let kept: Vec<u64> = fr
            .records()
            .iter()
            .filter(|r| r.kind == CaptureKind::Failure)
            .map(|r| r.id)
            .collect();
        assert_eq!(kept.len(), FAILURE_SLOTS);
        // the second lap overwrote the first: only recent ids remain
        assert!(
            kept.iter().all(|&id| id >= FAILURE_SLOTS as u64),
            "{kept:?}"
        );
    }

    #[test]
    fn keeps_the_slowest() {
        crate::obs::set_enabled(true);
        let fr = FlightRecorder::new(4, 0, 0);
        for i in 0..100u64 {
            let e2e = (i + 1) as f64 * 1e-4;
            fr.capture_with(e2e, || rec(i, e2e));
        }
        let records = fr.records();
        assert_eq!(records.len(), 4);
        let ids: Vec<u64> = records.iter().map(|r| r.id).collect();
        assert_eq!(ids, [99, 98, 97, 96], "slowest four, slowest first");
    }

    #[test]
    fn sampling_captures_ordinary_queries() {
        crate::obs::set_enabled(true);
        let fr = FlightRecorder::new(2, 4, 10);
        // all queries identical latency: never "slow" after the ring
        // fills, but every 10th lands in the sample ring
        for i in 0..100u64 {
            fr.capture_with(1e-3, || rec(i, 1e-3));
        }
        let sampled: Vec<u64> = fr
            .records()
            .iter()
            .filter(|r| r.kind == CaptureKind::Sampled)
            .map(|r| r.id)
            .collect();
        assert!(!sampled.is_empty());
        for id in &sampled {
            assert_eq!(id % 10, 0, "only every 10th query is sampled");
        }
        assert_eq!(fr.seen(), 100);
    }

    #[test]
    fn build_skipped_for_boring_queries() {
        crate::obs::set_enabled(true);
        let fr = FlightRecorder::new(2, 0, 0);
        fr.capture_with(1.0, || rec(0, 1.0));
        fr.capture_with(0.9, || rec(1, 0.9));
        let mut built = false;
        // ring holds 1.0 and 0.9; a 0.5s query is not slow enough
        fr.capture_with(0.5, || {
            built = true;
            rec(2, 0.5)
        });
        assert!(!built, "builder must not run for non-qualifying queries");
        assert_eq!(fr.records().len(), 2);
    }

    #[test]
    fn concurrent_capture_soak() {
        crate::obs::set_enabled(true);
        let fr = FlightRecorder::new(8, 4, 32);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let fr = &fr;
                s.spawn(move || {
                    for i in 0..5_000u64 {
                        let e2e = ((t * 5_000 + i) % 997 + 1) as f64 * 1e-6;
                        fr.capture_with(e2e, || rec(i, e2e));
                    }
                });
            }
        });
        let records = fr.records();
        assert!(!records.is_empty());
        // slow-ring records are all near the top of the latency range
        for r in records.iter().filter(|r| r.kind == CaptureKind::Slow) {
            assert!(r.e2e_seconds > 900e-6, "kept {}s", r.e2e_seconds);
        }
        assert_eq!(fr.seen(), 20_000);
    }

    #[test]
    fn display_is_compact_and_total() {
        let mut r = rec(7, 0.0123);
        r.shard_seconds = vec![0.001, 0.002];
        let s = format!("{r}");
        assert!(s.contains("req 7"));
        assert!(s.contains("shards ms"));
    }
}
