//! The LeanVec-OOD loss (Eq. 8) in its second-moment trace form, plus
//! the Proposition-1 PCA upper bound used by tests and experiments.

use crate::linalg::Matrix;

/// `f(A, B) = Tr(A Kq A^T B Kx B^T) + Tr(Kq Kx) - 2 Tr(Kq A^T B Kx)`.
///
/// `kq`/`kx` are the (D, D) second moments `Q Q^T / m` and `X X^T / n`
/// (any consistent scaling works — the optimizers are scale-invariant).
/// Cost is O(d D^2): only (d, D) intermediates are formed.
pub fn ood_loss(a: &Matrix, b: &Matrix, kq: &Matrix, kx: &Matrix) -> f64 {
    let (t1, t3, constant) = ood_loss_parts(a, b, kq, kx);
    t1 + constant - 2.0 * t3
}

/// The three trace terms of Eq. (8): `(Tr(AKqA^T BKxB^T), Tr(Kq A^T B Kx),
/// Tr(Kq Kx))`. Exposed so the FW driver can reuse intermediates.
pub fn ood_loss_parts(a: &Matrix, b: &Matrix, kq: &Matrix, kx: &Matrix) -> (f64, f64, f64) {
    let akq = a.matmul(kq); // (d, D)
    let bkx = b.matmul(kx); // (d, D)
    let m1 = akq.matmul_nt(a); // (d, d) = A Kq A^T
    let m2 = bkx.matmul_nt(b); // (d, d) = B Kx B^T
    // Tr(M1 M2) = sum(M1 .* M2^T); both symmetric so plain elementwise
    let t1: f64 = m1
        .data
        .iter()
        .zip(m2.transpose().data.iter())
        .map(|(x, y)| *x as f64 * *y as f64)
        .sum();
    // Tr(Kq A^T B Kx) = sum((A Kq) .* (B Kx))
    let t3: f64 = akq
        .data
        .iter()
        .zip(bkx.data.iter())
        .map(|(x, y)| *x as f64 * *y as f64)
        .sum();
    let constant: f64 = kq
        .data
        .iter()
        .zip(kx.transpose().data.iter())
        .map(|(x, y)| *x as f64 * *y as f64)
        .sum();
    (t1, t3, constant)
}

/// Gradient wrt A (Eq. 13): `2 B Kx B^T A Kq - 2 B Kx Kq`.
pub fn grad_a(a: &Matrix, b: &Matrix, kq: &Matrix, kx: &Matrix) -> Matrix {
    let bkx = b.matmul(kx); // (d, D)
    let bkxbt = bkx.matmul_nt(b); // (d, d)
    let mut g = bkxbt.matmul(&a.matmul(kq)); // (d, D)
    let rhs = bkx.matmul(kq);
    g.lerp(&rhs, 2.0, -2.0);
    g
}

/// Gradient wrt B (Eq. 13): `2 A Kq A^T B Kx - 2 A Kq Kx`.
pub fn grad_b(a: &Matrix, b: &Matrix, kq: &Matrix, kx: &Matrix) -> Matrix {
    let akq = a.matmul(kq);
    let akqat = akq.matmul_nt(a);
    let mut g = akqat.matmul(&b.matmul(kx));
    let rhs = akq.matmul(kx);
    g.lerp(&rhs, 2.0, -2.0);
    g
}

/// Proposition 1 upper bound: the PCA solution's loss, computed as
/// `Tr(Kq) * Tr((I - P^T P) Kx (I - P^T P))`-free direct evaluation —
/// i.e. just `ood_loss(P, P, ...)` for the PCA `P`. Provided for the
/// prop1 experiment/test to compare learner outputs against.
pub fn pca_bound(p: &Matrix, kq: &Matrix, kx: &Matrix) -> f64 {
    ood_loss(p, p, kq, kx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::random_orthonormal;
    use crate::util::rng::Rng;

    fn setup(seed: u64, dd: usize, d: usize, n: usize, m: usize) -> (Matrix, Matrix, Matrix, Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::randn(n, dd, &mut rng); // rows = vectors
        let q = Matrix::randn(m, dd, &mut rng);
        let kx = x.second_moment();
        let kq = q.second_moment();
        let a = random_orthonormal(d, dd, &mut rng);
        let b = random_orthonormal(d, dd, &mut rng);
        (x, q, kx, kq, a, b)
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn loss_matches_direct_frobenius() {
        let (x, q, kx, kq, a, b) = setup(1, 24, 6, 200, 100);
        // direct: ||Q^T A^T B X - Q^T X||_F^2 / (n*m)
        let ab = a.matmul_tn(&b); // wait: A^T B is (D, D); a is (d,D) so A^T B = a^T b
        let atb = a.transpose().matmul(&b); // (D, D)
        let xt = x.transpose(); // (D, n)
        let proj = atb.matmul(&xt); // (D, n)
        let qproj = q.matmul(&proj); // (m, n) = Q^T A^T B X (rows of q are queries)
        let qx = q.matmul(&xt); // (m, n)
        let mut acc = 0.0f64;
        for (u, v) in qproj.data.iter().zip(qx.data.iter()) {
            let e = (*u - *v) as f64;
            acc += e * e;
        }
        let direct = acc / (200.0 * 100.0);
        let got = ood_loss(&a, &b, &kq, &kx);
        let _ = ab;
        assert!(
            (got - direct).abs() < 1e-3 * direct.abs().max(1e-9),
            "{got} vs {direct}"
        );
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn loss_zero_for_identity_at_full_rank() {
        let mut rng = Rng::new(2);
        let dd = 16;
        let x = Matrix::randn(100, dd, &mut rng);
        let q = Matrix::randn(60, dd, &mut rng);
        let eye = Matrix::eye(dd);
        let l = ood_loss(&eye, &eye, &q.second_moment(), &x.second_moment());
        let scale = ood_loss(
            &Matrix::zeros(dd, dd),
            &Matrix::zeros(dd, dd),
            &q.second_moment(),
            &x.second_moment(),
        );
        assert!(l.abs() < 1e-5 * scale.abs().max(1e-9), "{l} vs {scale}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn gradient_matches_finite_differences() {
        let (_, _, kx, kq, a, b) = setup(3, 12, 4, 100, 80);
        let ga = grad_a(&a, &b, &kq, &kx);
        let gb = grad_b(&a, &b, &kq, &kx);
        let eps = 1e-3f32;
        let mut worst = 0.0f64;
        for idx in [0usize, 5, 17, 33] {
            // A direction
            let mut ap = a.clone();
            ap.data[idx] += eps;
            let mut am = a.clone();
            am.data[idx] -= eps;
            let fd = (ood_loss(&ap, &b, &kq, &kx) - ood_loss(&am, &b, &kq, &kx))
                / (2.0 * eps as f64);
            worst = worst.max((fd - ga.data[idx] as f64).abs() / fd.abs().max(1e-6));
            // B direction
            let mut bp = b.clone();
            bp.data[idx] += eps;
            let mut bm = b.clone();
            bm.data[idx] -= eps;
            let fd = (ood_loss(&a, &bp, &kq, &kx) - ood_loss(&a, &bm, &kq, &kx))
                / (2.0 * eps as f64);
            worst = worst.max((fd - gb.data[idx] as f64).abs() / fd.abs().max(1e-6));
        }
        assert!(worst < 0.05, "finite-difference mismatch {worst}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn proposition1_holds_for_learned_pairs() {
        // any (A, B) in the ball evaluated by the learners must respect
        // the *existence* of the PCA bound: loss(PCA) <= loss(random)
        // in the ID case where Kq ~ Kx.
        let mut rng = Rng::new(4);
        let dd = 20;
        let basis = Matrix::randn(dd, dd, &mut rng);
        let x = Matrix::randn(300, dd, &mut rng).matmul(&basis);
        let q = Matrix::randn(200, dd, &mut rng).matmul(&basis);
        let (kx, kq) = (x.second_moment(), q.second_moment());
        let p = crate::linalg::eigen::top_eigvecs(&kx, 5);
        let r = random_orthonormal(5, dd, &mut rng);
        assert!(pca_bound(&p, &kq, &kx) <= ood_loss(&r, &r, &kq, &kx));
    }
}
