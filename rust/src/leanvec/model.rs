//! The learned LeanVec projection pair `(A, B)` and the training
//! front-end that dispatches across learners/backends.

use crate::config::ProjectionKind;
use crate::leanvec::eigsearch::{eigsearch, NativeTopd, TopdBackend};
use crate::leanvec::fw::{frank_wolfe, FwParams, FwStepper, NativeStepper};
use crate::leanvec::loss::ood_loss;
use crate::leanvec::pca::pca;
use crate::linalg::qr::random_orthonormal;
use crate::linalg::Matrix;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// A trained LeanVec model: `x -> B x` for database vectors,
/// `q -> A q` for queries (Eq. 1). For ID/eigsearch learners `A == B`.
#[derive(Clone, Debug)]
pub struct LeanVecModel {
    /// query projection (d, D)
    pub a: Matrix,
    /// database projection (d, D)
    pub b: Matrix,
    pub kind: ProjectionKind,
    /// training diagnostics: final OOD loss (Eq. 8 form)
    pub train_loss: f64,
}

impl LeanVecModel {
    pub fn input_dim(&self) -> usize {
        self.a.cols
    }

    pub fn target_dim(&self) -> usize {
        self.a.rows
    }

    /// Project one query: `A q`.
    pub fn project_query(&self, q: &[f32]) -> Vec<f32> {
        self.a.matvec(q)
    }

    /// Project one database vector: `B x`.
    pub fn project_database_vector(&self, x: &[f32]) -> Vec<f32> {
        self.b.matvec(x)
    }

    /// Project a batch of database rows.
    pub fn project_database(&self, rows: &[Vec<f32>]) -> Vec<Vec<f32>> {
        rows.iter()
            .map(|r| self.project_database_vector(r))
            .collect()
    }

    /// Project a batch of database rows across `threads` workers
    /// (0 = all cores), in chunks so the per-item synchronization cost
    /// stays negligible next to each matvec. Each row's projection is
    /// independent, so the result is bit-identical to
    /// [`LeanVecModel::project_database`].
    pub fn project_database_threads(&self, rows: &[Vec<f32>], threads: usize) -> Vec<Vec<f32>> {
        let threads = crate::util::threadpool::resolve_threads(threads);
        if threads <= 1 {
            return self.project_database(rows);
        }
        let parts = crate::util::threadpool::parallel_chunked(rows.len(), threads, |start, end| {
            self.project_database(&rows[start..end])
        });
        parts.into_iter().flatten().collect()
    }

    /// Identity model (no reduction) for the `ProjectionKind::None` path.
    pub fn identity(dim: usize) -> LeanVecModel {
        LeanVecModel {
            a: Matrix::eye(dim),
            b: Matrix::eye(dim),
            kind: ProjectionKind::None,
            train_loss: 0.0,
        }
    }

    // ------------------------------------------------------------ persistence

    /// Serialize the model (both projection matrices, bit-exact) as the
    /// snapshot MODEL section. Byte layout: `docs/SNAPSHOT_FORMAT.md`.
    pub fn write_bytes(&self, out: &mut Vec<u8>) {
        use crate::data::io::bin;
        let mat = |out: &mut Vec<u8>, m: &Matrix| {
            bin::put_u32(out, m.rows as u32);
            bin::put_u32(out, m.cols as u32);
            bin::put_f32s(out, &m.data);
        };
        bin::put_u8(out, self.kind.code());
        bin::put_f64(out, self.train_loss);
        mat(out, &self.a);
        mat(out, &self.b);
    }

    /// Inverse of [`LeanVecModel::write_bytes`]. Unlike the JSON path
    /// ([`LeanVecModel::from_json`]) this round-trips the matrices
    /// bit-exactly, which the snapshot's bit-identical-search guarantee
    /// relies on.
    pub fn read_bytes(cur: &mut crate::data::io::bin::Cursor) -> std::io::Result<LeanVecModel> {
        let bad = |what: &str| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("inconsistent model section: {what}"),
            )
        };
        let kind = ProjectionKind::from_code(cur.get_u8()?)
            .ok_or_else(|| bad("unknown projection kind"))?;
        let train_loss = cur.get_f64()?;
        let mat = |cur: &mut crate::data::io::bin::Cursor| -> std::io::Result<Matrix> {
            let rows = cur.get_u32()? as usize;
            let cols = cur.get_u32()? as usize;
            let data = cur.get_f32s()?;
            if data.len() != rows * cols {
                return Err(bad("matrix shape disagrees with data length"));
            }
            Ok(Matrix::from_vec(rows, cols, data))
        };
        let a = mat(cur)?;
        let b = mat(cur)?;
        if a.rows != b.rows || a.cols != b.cols {
            return Err(bad("A and B shapes differ"));
        }
        Ok(LeanVecModel {
            a,
            b,
            kind,
            train_loss,
        })
    }

    pub fn to_json(&self) -> Json {
        let mat = |m: &Matrix| {
            Json::obj(vec![
                ("rows", Json::num(m.rows as f64)),
                ("cols", Json::num(m.cols as f64)),
                (
                    "data",
                    Json::arr(m.data.iter().map(|&v| Json::num(v as f64))),
                ),
            ])
        };
        Json::obj(vec![
            ("kind", Json::str(self.kind.name())),
            ("train_loss", Json::num(self.train_loss)),
            ("a", mat(&self.a)),
            ("b", mat(&self.b)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<LeanVecModel> {
        let mat = |j: &Json| -> Option<Matrix> {
            let rows = j.get("rows")?.as_usize()?;
            let cols = j.get("cols")?.as_usize()?;
            let data: Vec<f32> = j
                .get("data")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
                .collect();
            if data.len() != rows * cols {
                return None;
            }
            Some(Matrix::from_vec(rows, cols, data))
        };
        Some(LeanVecModel {
            a: mat(j.get("a")?)?,
            b: mat(j.get("b")?)?,
            kind: ProjectionKind::parse(j.get("kind")?.as_str()?)?,
            train_loss: j.get("train_loss")?.as_f64()?,
        })
    }
}

/// Backends for the two heavy training computations; the defaults are
/// the native implementations, the runtime swaps in PJRT executors.
pub struct TrainBackends {
    pub fw: Box<dyn FwStepper>,
    pub topd: Box<dyn TopdBackend>,
}

impl Default for TrainBackends {
    fn default() -> Self {
        TrainBackends {
            fw: Box::new(NativeStepper),
            topd: Box::new(NativeTopd),
        }
    }
}

/// Train a projection of the requested kind.
///
/// `x_rows` are database vectors (the learn split), `q_rows` a
/// representative query learn set (ignored by ID/Random). Second moments
/// are computed here; subsample upstream if the sets are large (the
/// covariance concentrates at a sqrt(n) rate — Fig. 15/16).
pub fn train_projection(
    kind: ProjectionKind,
    x_rows: &[Vec<f32>],
    q_rows: Option<&[Vec<f32>]>,
    d: usize,
    backends: &mut TrainBackends,
    seed: u64,
) -> LeanVecModel {
    let dd = x_rows.first().map(|r| r.len()).unwrap_or(0);
    assert!(d <= dd, "target dim {d} exceeds input dim {dd}");
    let x = rows_to_matrix(x_rows);
    let kx = x.second_moment();

    match kind {
        ProjectionKind::None => LeanVecModel::identity(dd),
        ProjectionKind::Random => {
            let mut rng = Rng::new(seed);
            let p = random_orthonormal(d, dd, &mut rng);
            let kq = q_rows
                .map(|q| rows_to_matrix(q).second_moment())
                .unwrap_or_else(|| kx.clone());
            let train_loss = ood_loss(&p, &p, &kq, &kx);
            LeanVecModel {
                a: p.clone(),
                b: p,
                kind,
                train_loss,
            }
        }
        ProjectionKind::Id => {
            let p = pca(&kx, d);
            let kq = q_rows
                .map(|q| rows_to_matrix(q).second_moment())
                .unwrap_or_else(|| kx.clone());
            let train_loss = ood_loss(&p, &p, &kq, &kx);
            LeanVecModel {
                a: p.clone(),
                b: p,
                kind,
                train_loss,
            }
        }
        ProjectionKind::OodEigSearch => {
            let q = q_rows.expect("LeanVec-OOD requires a query learn set");
            let kq = rows_to_matrix(q).second_moment();
            let res = eigsearch(&kq, &kx, d, backends.topd.as_mut());
            LeanVecModel {
                a: res.p.clone(),
                b: res.p,
                kind,
                train_loss: res.loss,
            }
        }
        ProjectionKind::OodFrankWolfe => {
            let q = q_rows.expect("LeanVec-OOD requires a query learn set");
            let kq = rows_to_matrix(q).second_moment();
            // Init from the eigsearch solution (the paper's ES+FW variant,
            // Fig. 18): it is never worse than either method alone and
            // avoids the zero-gradient degeneracy of the NS oracle.
            let init = eigsearch(&kq, &kx, d, backends.topd.as_mut());
            let res = frank_wolfe(
                backends.fw.as_mut(),
                init.p.clone(),
                init.p.clone(),
                &kq,
                &kx,
                FwParams::default(),
            );
            let (a, b, loss) = if res.best_loss <= init.loss {
                (res.a, res.b, res.best_loss)
            } else {
                (init.p.clone(), init.p, init.loss)
            };
            LeanVecModel {
                a,
                b,
                kind,
                train_loss: loss,
            }
        }
    }
}

/// Rows (n x D) into a Matrix.
pub fn rows_to_matrix(rows: &[Vec<f32>]) -> Matrix {
    let dd = rows.first().map(|r| r.len()).unwrap_or(0);
    let mut m = Matrix::zeros(rows.len(), dd);
    for (i, r) in rows.iter().enumerate() {
        m.row_mut(i).copy_from_slice(r);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn projection_shapes() {
        let x = gaussian_rows(200, 16, 1);
        let mut b = TrainBackends::default();
        let m = train_projection(ProjectionKind::Id, &x, None, 6, &mut b, 0);
        assert_eq!(m.target_dim(), 6);
        assert_eq!(m.input_dim(), 16);
        assert_eq!(m.project_query(&x[0]).len(), 6);
        assert_eq!(m.project_database(&x[..3]).len(), 3);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn all_kinds_train() {
        let x = gaussian_rows(150, 12, 2);
        let q = gaussian_rows(100, 12, 3);
        let mut b = TrainBackends::default();
        for kind in [
            ProjectionKind::Id,
            ProjectionKind::Random,
            ProjectionKind::OodEigSearch,
            ProjectionKind::OodFrankWolfe,
        ] {
            let m = train_projection(kind, &x, Some(&q), 4, &mut b, 7);
            assert_eq!(m.kind, kind);
            // FW iterates live in the *convex hull* of St(D, d) (Eq. 2),
            // not on the manifold itself — check the spectral ball there
            // and exact orthonormality for the manifold-valued learners.
            if kind == ProjectionKind::OodFrankWolfe {
                assert!(
                    crate::linalg::svd::spectral_norm(&m.a) <= 1.01,
                    "{kind:?}"
                );
            } else {
                assert!(m.a.row_orthonormality_defect() < 0.05, "{kind:?}");
            }
            assert!(m.train_loss.is_finite());
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn ood_learners_not_worse_than_pca_by_loss() {
        let x = gaussian_rows(300, 16, 4);
        let q = gaussian_rows(200, 16, 5);
        let mut b = TrainBackends::default();
        let id = train_projection(ProjectionKind::Id, &x, Some(&q), 6, &mut b, 0);
        let es = train_projection(ProjectionKind::OodEigSearch, &x, Some(&q), 6, &mut b, 0);
        let fw = train_projection(ProjectionKind::OodFrankWolfe, &x, Some(&q), 6, &mut b, 0);
        assert!(es.train_loss <= id.train_loss * 1.001);
        assert!(fw.train_loss <= es.train_loss * 1.001);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn threaded_projection_matches_serial() {
        let x = gaussian_rows(300, 16, 9);
        let mut b = TrainBackends::default();
        let m = train_projection(ProjectionKind::Id, &x, None, 6, &mut b, 0);
        assert_eq!(m.project_database(&x), m.project_database_threads(&x, 4));
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn identity_model_is_identity() {
        let m = LeanVecModel::identity(8);
        let v: Vec<f32> = (0..8).map(|i| i as f32).collect();
        assert_eq!(m.project_query(&v), v);
        assert_eq!(m.project_database_vector(&v), v);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn binary_roundtrip_bit_exact() {
        let x = gaussian_rows(120, 10, 7);
        let q = gaussian_rows(80, 10, 8);
        let mut b = TrainBackends::default();
        for kind in [ProjectionKind::Id, ProjectionKind::OodEigSearch] {
            let m = train_projection(kind, &x, Some(&q), 4, &mut b, 0);
            let mut buf = Vec::new();
            m.write_bytes(&mut buf);
            let mut cur = crate::data::io::bin::Cursor::new(&buf);
            let m2 = LeanVecModel::read_bytes(&mut cur).expect("read back");
            assert_eq!(cur.remaining(), 0);
            assert_eq!(m2.kind, m.kind);
            assert_eq!(m2.train_loss.to_bits(), m.train_loss.to_bits());
            assert_eq!(m2.a, m.a, "{kind:?}");
            assert_eq!(m2.b, m.b, "{kind:?}");
        }
        // truncation errors instead of panicking
        let m = LeanVecModel::identity(6);
        let mut buf = Vec::new();
        m.write_bytes(&mut buf);
        let mut cur = crate::data::io::bin::Cursor::new(&buf[..buf.len() - 3]);
        assert!(LeanVecModel::read_bytes(&mut cur).is_err());
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn json_roundtrip() {
        let x = gaussian_rows(100, 10, 6);
        let mut b = TrainBackends::default();
        let m = train_projection(ProjectionKind::Id, &x, None, 4, &mut b, 0);
        let j = m.to_json();
        let m2 = LeanVecModel::from_json(&j).expect("parse back");
        assert_eq!(m.kind, m2.kind);
        assert!(m.a.max_abs_diff(&m2.a) < 1e-5);
        assert!(m.b.max_abs_diff(&m2.b) < 1e-5);
    }
}
