//! LeanVec-ID (Section 2.1): PCA on the database second moment.

use crate::leanvec::eigsearch::{NativeTopd, TopdBackend};
use crate::linalg::Matrix;

/// Top-d principal directions of the database as a row-orthonormal
/// (d, D) projection `M` with `A = B = M` (Eq. 4). `kx` is `X X^T / n`.
/// Uses the adaptive eigensolver (Jacobi for small D, orthogonal
/// iteration for d << D) shared with Algorithm 2.
pub fn pca(kx: &Matrix, d: usize) -> Matrix {
    NativeTopd.topd(kx, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn pca_recovers_planted_subspace() {
        // data concentrated in a planted 3-dim subspace + small noise
        let mut rng = Rng::new(1);
        let dd = 16;
        let basis = crate::linalg::qr::random_orthonormal(3, dd, &mut rng); // (3, D)
        let coeff = Matrix::randn(500, 3, &mut rng);
        let mut x = coeff.matmul(&basis); // (n, D) in the subspace
        for v in x.data.iter_mut() {
            *v += 0.01 * rng.gaussian_f32();
        }
        let p = pca(&x.second_moment(), 3);
        // planted basis must lie in span(P): || basis - basis P^T P || small
        let proj = basis.matmul_nt(&p).matmul(&p);
        assert!(basis.max_abs_diff(&proj) < 0.05);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn pca_projection_is_orthonormal() {
        let mut rng = Rng::new(2);
        let x = Matrix::randn(200, 24, &mut rng);
        let p = pca(&x.second_moment(), 8);
        assert_eq!((p.rows, p.cols), (8, 24));
        assert!(p.row_orthonormality_defect() < 1e-4);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn more_dims_capture_more_energy() {
        let mut rng = Rng::new(3);
        let x = Matrix::randn(300, 20, &mut rng);
        let kx = x.second_moment();
        let energy = |d: usize| {
            let p = pca(&kx, d);
            p.matmul(&kx).matmul_nt(&p).trace()
        };
        assert!(energy(4) < energy(8));
        assert!(energy(8) < energy(16));
    }
}
