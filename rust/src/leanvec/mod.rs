//! The paper's core contribution: LeanVec projection learning.
//!
//! * [`loss`] — the LeanVec-OOD objective (Eq. 7/8) and Prop. 1 bound.
//! * [`pca`] — LeanVec-ID (Section 2.1).
//! * [`fw`] — Algorithm 1: Frank-Wolfe block-coordinate descent over the
//!   spectral-norm ball, with a pluggable step backend (native linalg or
//!   the AOT-compiled PJRT artifact).
//! * [`eigsearch`] — Algorithm 2: Brent search over the `K_beta` blend.
//! * [`model`] — the learned `(A, B)` pair + apply/save/load.

pub mod eigsearch;
pub mod fw;
pub mod loss;
pub mod model;
pub mod pca;

pub use eigsearch::eigsearch;
pub use fw::{FwParams, FwStepper, NativeStepper};
pub use loss::{ood_loss, ood_loss_parts};
pub use model::LeanVecModel;
pub use pca::pca;
