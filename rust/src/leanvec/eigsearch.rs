//! Algorithm 2: eigenvector-search optimization of the LeanVec-OOD loss.
//!
//! With `A = B = P`, the loss becomes a function of the blend
//! `K_beta = (1 - beta) Kq + beta Kx` (the 1/m, 1/n normalizations are
//! already inside our second-moment matrices): `P(beta)` = top-d
//! eigenvectors of `K_beta`, and `beta` is found by a derivative-free
//! scalar minimization (Brent 2013). `beta = 1` recovers PCA on the
//! database, `beta = 0` PCA on the queries; in the ID case the loss is
//! flat in `beta` and any value falls back to Eq. (4) — Prop. 1 seamless
//! fallback.

use crate::leanvec::loss::ood_loss;
use crate::linalg::{top_eigvecs, Matrix};

/// Pluggable top-d eigenbasis backend (native Jacobi or the PJRT
/// `eig_topd` artifact).
pub trait TopdBackend {
    fn topd(&mut self, k: &Matrix, d: usize) -> Matrix;
    fn name(&self) -> &'static str;
}

/// Native backend. Full Jacobi eigendecomposition is O(D^3) per sweep —
/// fine for small D but dominates eigsearch at D >= 512, so for
/// d << D this switches to orthogonal (subspace) iteration, the same
/// matmul-only algorithm the PJRT `eig_topd` artifact runs.
pub struct NativeTopd;

/// Orthogonal iteration: V <- orth(K V) with QR orthonormalization.
fn subspace_topd(k: &Matrix, d: usize, iters: usize) -> Matrix {
    let dd = k.rows;
    let mut rng = crate::util::rng::Rng::new(0x70BD ^ (dd as u64) << 8 ^ d as u64);
    let mut v = Matrix::randn(dd, d, &mut rng); // (D, d) columns = basis
    for _ in 0..iters {
        let kv = k.matmul(&v);
        v = crate::linalg::qr::qr_orthonormal_columns(&kv);
    }
    v.transpose() // rows = eigenvectors
}

impl TopdBackend for NativeTopd {
    fn topd(&mut self, k: &Matrix, d: usize) -> Matrix {
        // QR-orthonormalized subspace iteration is robust up to
        // moderate d/D ratios; full Jacobi remains the fallback for
        // small problems and aggressive ratios.
        if d * 2 <= k.rows && k.rows >= 192 {
            subspace_topd(k, d, 30)
        } else {
            top_eigvecs(k, d)
        }
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Result of the eigenvector search.
pub struct EigSearchResult {
    pub p: Matrix,
    pub beta: f64,
    pub loss: f64,
    /// (beta, loss) pairs evaluated during the search (Fig. 3 data)
    pub trace: Vec<(f64, f64)>,
}

fn blend(kq: &Matrix, kx: &Matrix, beta: f64) -> Matrix {
    let mut k = kq.clone();
    k.scale((1.0 - beta) as f32);
    let mut kx2 = kx.clone();
    kx2.scale(beta as f32);
    k.add_assign(&kx2);
    k
}

/// Algorithm 2 with a golden-section (Brent-style derivative-free)
/// search over `beta in [0, 1]`.
pub fn eigsearch(kq: &Matrix, kx: &Matrix, d: usize, backend: &mut dyn TopdBackend) -> EigSearchResult {
    // The beta curve is smooth with one interior minimum (Fig. 3): a
    // 0.03 bracket is far below the sampling noise of the moments, and
    // golden section reaches it in <= 14 evaluations.
    eigsearch_with_tol(kq, kx, d, backend, 0.03, 14)
}

/// The search evaluates `loss(P(beta))`; `tol` is the bracket width at
/// which to stop, `max_evals` bounds eigendecompositions.
pub fn eigsearch_with_tol(
    kq: &Matrix,
    kx: &Matrix,
    d: usize,
    backend: &mut dyn TopdBackend,
    tol: f64,
    max_evals: usize,
) -> EigSearchResult {
    let mut trace: Vec<(f64, f64)> = Vec::new();
    let mut evals = 0usize;
    let mut best: Option<(f64, f64, Matrix)> = None;

    let mut eval = |beta: f64,
                    trace: &mut Vec<(f64, f64)>,
                    best: &mut Option<(f64, f64, Matrix)>,
                    evals: &mut usize|
     -> f64 {
        // reuse any previously evaluated beta (golden-section revisits)
        if let Some(&(_, l)) = trace.iter().find(|(b, _)| (b - beta).abs() < 1e-12) {
            return l;
        }
        *evals += 1;
        let p = backend.topd(&blend(kq, kx, beta), d);
        let l = ood_loss(&p, &p, kq, kx);
        trace.push((beta, l));
        if best.as_ref().map(|(_, bl, _)| l < *bl).unwrap_or(true) {
            *best = Some((beta, l, p));
        }
        l
    };

    // golden-section search on [0, 1]
    let phi = (5.0f64.sqrt() - 1.0) / 2.0; // 0.618...
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    // include the endpoints (beta=0: query PCA; beta=1: database PCA)
    eval(0.0, &mut trace, &mut best, &mut evals);
    eval(1.0, &mut trace, &mut best, &mut evals);
    let mut x1 = hi - phi * (hi - lo);
    let mut x2 = lo + phi * (hi - lo);
    let mut f1 = eval(x1, &mut trace, &mut best, &mut evals);
    let mut f2 = eval(x2, &mut trace, &mut best, &mut evals);
    while hi - lo > tol && evals < max_evals {
        if f1 <= f2 {
            hi = x2;
            x2 = x1;
            f2 = f1;
            x1 = hi - phi * (hi - lo);
            f1 = eval(x1, &mut trace, &mut best, &mut evals);
        } else {
            lo = x1;
            x1 = x2;
            f1 = f2;
            x2 = lo + phi * (hi - lo);
            f2 = eval(x2, &mut trace, &mut best, &mut evals);
        }
    }

    let (beta, loss, p) = best.expect("at least one evaluation");
    EigSearchResult {
        p,
        beta,
        loss,
        trace,
    }
}

/// Dense beta sweep — regenerates the Fig. 3 / Fig. 17 loss-vs-beta
/// curves.
pub fn beta_sweep(
    kq: &Matrix,
    kx: &Matrix,
    d: usize,
    betas: &[f64],
    backend: &mut dyn TopdBackend,
) -> Vec<(f64, f64)> {
    betas
        .iter()
        .map(|&beta| {
            let p = backend.topd(&blend(kq, kx, beta), d);
            (beta, ood_loss(&p, &p, kq, kx))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::random_orthonormal;
    use crate::util::rng::Rng;

    fn ood_problem(seed: u64, dd: usize) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let ub = random_orthonormal(dd, dd, &mut rng);
        let uq = random_orthonormal(dd, dd, &mut rng);
        let shape = |m: &mut Matrix, decay: f32| {
            for row in m.data.chunks_mut(dd) {
                for (c, v) in row.iter_mut().enumerate() {
                    *v *= 1.0 / (1.0 + c as f32 * decay);
                }
            }
        };
        let mut xc = Matrix::randn(500, dd, &mut rng);
        shape(&mut xc, 0.4);
        let x = xc.matmul(&ub);
        let mut qc = Matrix::randn(300, dd, &mut rng);
        shape(&mut qc, 0.4);
        let q = qc.matmul(&uq);
        (q.second_moment(), x.second_moment())
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn result_is_orthonormal_and_not_worse_than_endpoints() {
        let (kq, kx) = ood_problem(1, 20);
        let res = eigsearch(&kq, &kx, 6, &mut NativeTopd);
        assert!(res.p.row_orthonormality_defect() < 1e-4);
        let ends: Vec<f64> = res
            .trace
            .iter()
            .filter(|(b, _)| *b == 0.0 || *b == 1.0)
            .map(|(_, l)| *l)
            .collect();
        assert_eq!(ends.len(), 2);
        assert!(res.loss <= ends[0] + 1e-9 && res.loss <= ends[1] + 1e-9);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn id_case_is_flat_in_beta() {
        // same distribution for X and Q -> loss(beta) ~ constant (Fig 3
        // discussion: eigenvectors invariant to beta in expectation)
        let mut rng = Rng::new(2);
        let dd = 16;
        let basis = random_orthonormal(dd, dd, &mut rng);
        let x = Matrix::randn(2000, dd, &mut rng).matmul(&basis);
        let q = Matrix::randn(2000, dd, &mut rng).matmul(&basis);
        let (kq, kx) = (q.second_moment(), x.second_moment());
        let sweep = beta_sweep(&kq, &kx, 6, &[0.1, 0.5, 0.9], &mut NativeTopd);
        let losses: Vec<f64> = sweep.iter().map(|(_, l)| *l).collect();
        let max = losses.iter().cloned().fold(f64::MIN, f64::max);
        let min = losses.iter().cloned().fold(f64::MAX, f64::min);
        assert!(
            (max - min) / max.abs().max(1e-12) < 0.25,
            "ID beta curve should be nearly flat: {losses:?}"
        );
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn beta_interior_wins_on_ood() {
        let (kq, kx) = ood_problem(3, 24);
        let res = eigsearch(&kq, &kx, 8, &mut NativeTopd);
        // the optimum must strictly beat pure database PCA (beta = 1)
        let pca_loss = res
            .trace
            .iter()
            .find(|(b, _)| *b == 1.0)
            .map(|(_, l)| *l)
            .unwrap();
        assert!(res.loss <= pca_loss, "{} vs {pca_loss}", res.loss);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn trace_records_unique_betas() {
        let (kq, kx) = ood_problem(4, 12);
        let res = eigsearch(&kq, &kx, 4, &mut NativeTopd);
        for i in 0..res.trace.len() {
            for j in i + 1..res.trace.len() {
                assert!((res.trace[i].0 - res.trace[j].0).abs() > 1e-12);
            }
        }
    }
}
