//! Algorithm 1: Frank-Wolfe block-coordinate descent for LeanVec-OOD.
//!
//! One BCD iteration updates `A` with a Frank-Wolfe step (linear oracle
//! = orthogonal polar factor of the negated gradient; Jaggi 2013), then
//! `B` against the fresh `A`. Step size `gamma_t = 1/(t+1)^alpha`
//! (Wai et al. 2017); early termination on relative loss change
//! (paper default: 1e-3).
//!
//! The per-iteration compute is pluggable ([`FwStepper`]): the native
//! implementation mirrors the L1 Pallas kernel with `linalg` matmuls;
//! the PJRT stepper in [`crate::runtime`] executes the AOT artifact so
//! training runs through the same HLO the tests validate.

use crate::leanvec::loss::{grad_a, grad_b, ood_loss};
use crate::linalg::polar::{polar, NEWTON_SCHULZ_ITERS};
use crate::linalg::Matrix;

/// One BCD iteration: `(A, B, gamma) -> (A', B', loss(A', B'))`.
/// Loss is reported in the Eq.-8 trace form *including* the constant.
pub trait FwStepper {
    fn step(
        &mut self,
        a: &Matrix,
        b: &Matrix,
        kq: &Matrix,
        kx: &Matrix,
        gamma: f32,
    ) -> (Matrix, Matrix, f64);
    /// Human-readable backend name for logs/experiments.
    fn name(&self) -> &'static str;
}

/// Pure-rust stepper (linalg matmuls + Newton-Schulz polar).
pub struct NativeStepper;

impl FwStepper for NativeStepper {
    fn step(
        &mut self,
        a: &Matrix,
        b: &Matrix,
        kq: &Matrix,
        kx: &Matrix,
        gamma: f32,
    ) -> (Matrix, Matrix, f64) {
        let mut ga = grad_a(a, b, kq, kx);
        ga.scale(-1.0);
        let sa = polar(&ga, NEWTON_SCHULZ_ITERS);
        let mut a1 = a.clone();
        a1.lerp(&sa, 1.0 - gamma, gamma);

        let mut gb = grad_b(&a1, b, kq, kx);
        gb.scale(-1.0);
        let sb = polar(&gb, NEWTON_SCHULZ_ITERS);
        let mut b1 = b.clone();
        b1.lerp(&sb, 1.0 - gamma, gamma);

        let l = ood_loss(&a1, &b1, kq, kx);
        (a1, b1, l)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Frank-Wolfe driver parameters.
#[derive(Clone, Copy, Debug)]
pub struct FwParams {
    /// max BCD iterations T
    pub max_iters: usize,
    /// step-size exponent alpha in (0, 1)
    pub alpha: f64,
    /// early-termination threshold on |Δf| / f (paper: 1e-3)
    pub tol: f64,
}

impl Default for FwParams {
    fn default() -> Self {
        FwParams {
            max_iters: 60,
            alpha: 0.7,
            tol: 1e-3,
        }
    }
}

/// Result of a Frank-Wolfe run. `a`/`b` are the **best** iterates seen
/// (by loss), not necessarily the last — the early FW steps take large
/// `gamma` and can overshoot a good initialization.
pub struct FwResult {
    pub a: Matrix,
    pub b: Matrix,
    /// loss after every iteration (index 0 = after first step)
    pub losses: Vec<f64>,
    pub best_loss: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Run Algorithm 1 from the given initialization.
///
/// NOTE: unlike the paper's exact-SVD oracle, the Newton-Schulz oracle
/// cannot leave a zero iterate (polar(0) = 0), so `a0`/`b0` must be
/// non-degenerate — PCA or a random orthonormal matrix (the drivers in
/// [`crate::leanvec::model`] handle this).
pub fn frank_wolfe(
    stepper: &mut dyn FwStepper,
    a0: Matrix,
    b0: Matrix,
    kq: &Matrix,
    kx: &Matrix,
    params: FwParams,
) -> FwResult {
    assert!(
        a0.frobenius_norm() > 1e-6 && b0.frobenius_norm() > 1e-6,
        "zero init is a fixed point of the Newton-Schulz oracle"
    );
    let mut a = a0;
    let mut b = b0;
    let mut losses = Vec::with_capacity(params.max_iters);
    let mut prev = ood_loss(&a, &b, kq, kx);
    let mut best = (prev, a.clone(), b.clone());
    let mut converged = false;
    let mut iterations = 0;
    for t in 0..params.max_iters {
        let gamma = 1.0 / ((t + 1) as f64).powf(params.alpha);
        let (a1, b1, l) = stepper.step(&a, &b, kq, kx, gamma as f32);
        a = a1;
        b = b1;
        losses.push(l);
        iterations = t + 1;
        if l < best.0 {
            best = (l, a.clone(), b.clone());
        }
        if (prev - l).abs() / prev.abs().max(1e-30) <= params.tol {
            converged = true;
            break;
        }
        prev = l;
    }
    FwResult {
        a: best.1,
        b: best.2,
        losses,
        best_loss: best.0,
        iterations,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::random_orthonormal;
    use crate::linalg::svd::spectral_norm;
    use crate::util::rng::Rng;

    fn ood_problem(seed: u64, dd: usize, d: usize) -> (Matrix, Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        // database in one decaying-spectrum basis, queries in another
        let ub = random_orthonormal(dd, dd, &mut rng);
        let uq = random_orthonormal(dd, dd, &mut rng);
        let mut x = Matrix::randn(600, dd, &mut rng).matmul(&ub);
        let mut q = Matrix::randn(300, dd, &mut rng).matmul(&uq);
        for (j, row) in x.data.chunks_mut(dd).enumerate() {
            let _ = j;
            for (c, v) in row.iter_mut().enumerate() {
                *v *= 1.0 / (1.0 + c as f32 * 0.3);
            }
        }
        for row in q.data.chunks_mut(dd) {
            for (c, v) in row.iter_mut().enumerate() {
                *v *= 1.0 / (1.0 + c as f32 * 0.3);
            }
        }
        let kx = x.second_moment();
        let kq = q.second_moment();
        let a0 = random_orthonormal(d, dd, &mut rng);
        let b0 = random_orthonormal(d, dd, &mut rng);
        (kq, kx, a0, b0)
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn loss_decreases_monotonically_enough() {
        let (kq, kx, a0, b0) = ood_problem(1, 24, 8);
        let init = ood_loss(&a0, &b0, &kq, &kx);
        let res = frank_wolfe(
            &mut NativeStepper,
            a0,
            b0,
            &kq,
            &kx,
            FwParams {
                max_iters: 30,
                tol: 0.0,
                ..FwParams::default()
            },
        );
        assert!(res.losses.last().unwrap() < &init);
        // overall trend must be downward: last < half of max
        let max = res.losses.iter().cloned().fold(f64::MIN, f64::max);
        assert!(*res.losses.last().unwrap() < 0.8 * max);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn early_termination_fires() {
        let (kq, kx, a0, b0) = ood_problem(2, 16, 6);
        let res = frank_wolfe(
            &mut NativeStepper,
            a0,
            b0,
            &kq,
            &kx,
            FwParams {
                max_iters: 200,
                tol: 1e-3,
                ..FwParams::default()
            },
        );
        assert!(res.converged, "should terminate before 200 iterations");
        assert!(res.iterations < 200);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn iterates_stay_in_spectral_ball() {
        let (kq, kx, a0, b0) = ood_problem(3, 16, 6);
        let res = frank_wolfe(
            &mut NativeStepper,
            a0,
            b0,
            &kq,
            &kx,
            FwParams {
                max_iters: 10,
                tol: 0.0,
                ..FwParams::default()
            },
        );
        assert!(spectral_norm(&res.a) <= 1.01);
        assert!(spectral_norm(&res.b) <= 1.01);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    #[should_panic(expected = "zero init")]
    fn zero_init_rejected() {
        let (kq, kx, _, _) = ood_problem(4, 12, 4);
        frank_wolfe(
            &mut NativeStepper,
            Matrix::zeros(4, 12),
            Matrix::zeros(4, 12),
            &kq,
            &kx,
            FwParams::default(),
        );
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn fw_beats_pca_on_ood_data() {
        let (kq, kx, _, _) = ood_problem(5, 24, 8);
        let p = crate::leanvec::pca::pca(&kx, 8);
        let lp = ood_loss(&p, &p, &kq, &kx);
        // init FW *from PCA* — the production default
        let res = frank_wolfe(
            &mut NativeStepper,
            p.clone(),
            p.clone(),
            &kq,
            &kx,
            FwParams {
                max_iters: 40,
                tol: 0.0,
                ..FwParams::default()
            },
        );
        assert!(res.best_loss <= lp * 1.02, "fw {} vs pca {lp}", res.best_loss);
    }
}
