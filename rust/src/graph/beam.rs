//! Greedy best-first graph traversal with backtracking ("beam search"
//! with a bounded search buffer), shared by Vamana and HNSW.
//!
//! This is the request-path hot loop. All state lives in a reusable
//! [`SearchCtx`] so steady-state searches allocate nothing: the search
//! buffer is a fixed-capacity sorted array (insertion into a ~100-entry
//! window is cheaper than heap churn at these sizes — the same call the
//! SVS library makes), and the visited set is an epoch-stamped array.

/// One search-buffer entry.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub id: u32,
    pub score: f32,
    pub expanded: bool,
}

/// Reusable search state.
pub struct SearchCtx {
    /// sorted by score descending; capacity = window
    buffer: Vec<Candidate>,
    /// epoch-stamped visited marks, one per node
    visited: Vec<u32>,
    epoch: u32,
    pub stats: SearchStats,
}

/// Per-search counters (hops, score evaluations) — these drive the
/// bytes/query memory-traffic model of Fig. 1.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchStats {
    pub hops: usize,
    pub scored: usize,
}

impl SearchCtx {
    pub fn new(n: usize) -> SearchCtx {
        SearchCtx {
            buffer: Vec::new(),
            visited: vec![0; n],
            epoch: 0,
            stats: SearchStats::default(),
        }
    }

    /// Grow the visited array if the graph grew.
    pub fn ensure(&mut self, n: usize) {
        if self.visited.len() < n {
            self.visited.resize(n, 0);
        }
    }

    fn begin(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // epoch wrapped: clear stamps and restart at 1
            self.visited.iter_mut().for_each(|v| *v = 0);
            self.epoch = 1;
        }
        self.buffer.clear();
        self.stats = SearchStats::default();
    }

    #[inline]
    fn mark_visited(&mut self, id: u32) -> bool {
        let slot = &mut self.visited[id as usize];
        if *slot == self.epoch {
            false
        } else {
            *slot = self.epoch;
            true
        }
    }

    /// Insert into the sorted buffer, keeping at most `window` entries.
    /// Returns true if inserted.
    #[inline]
    fn insert(&mut self, c: Candidate, window: usize) -> bool {
        // find insertion point (descending by score)
        let pos = self
            .buffer
            .partition_point(|e| e.score >= c.score);
        if pos >= window {
            return false;
        }
        if self.buffer.len() == window {
            self.buffer.pop();
        }
        self.buffer.insert(pos, c);
        true
    }

    /// index of the best unexpanded candidate
    #[inline]
    fn next_unexpanded(&self) -> Option<usize> {
        self.buffer.iter().position(|c| !c.expanded)
    }

    /// The final candidates, best first.
    pub fn results(&self) -> &[Candidate] {
        &self.buffer
    }
}

/// A pool of reusable [`SearchCtx`] for parallel sections (the parallel
/// graph builder and the batch-search path). Sized to the worker count:
/// as long as at most `workers` closures run concurrently, `acquire`
/// always finds a free context without blocking on a held lock.
pub struct CtxPool {
    ctxs: Vec<std::sync::Mutex<SearchCtx>>,
}

impl CtxPool {
    pub fn new(workers: usize, n: usize) -> CtxPool {
        CtxPool {
            ctxs: (0..workers.max(1))
                .map(|_| std::sync::Mutex::new(SearchCtx::new(n)))
                .collect(),
        }
    }

    /// Borrow any free context (spins across the pool; never deadlocks
    /// when concurrent borrowers <= pool size).
    pub fn acquire(&self) -> std::sync::MutexGuard<'_, SearchCtx> {
        loop {
            for c in &self.ctxs {
                if let Ok(guard) = c.try_lock() {
                    return guard;
                }
            }
            std::thread::yield_now();
        }
    }
}

/// Greedy traversal: start from `entries`, repeatedly expand the best
/// unexpanded candidate, scoring its out-neighbors with `score_fn` and
/// fetching them with `neighbors_fn`.
///
/// `window` is the search-buffer width L; the returned slice holds up to
/// `window` candidates, best first.
pub fn greedy_search<'a, S, N>(
    ctx: &'a mut SearchCtx,
    entries: &[u32],
    window: usize,
    mut score_fn: S,
    mut neighbors_fn: N,
) -> &'a [Candidate]
where
    S: FnMut(u32) -> f32,
    N: FnMut(u32, &mut Vec<u32>),
{
    ctx.begin();
    let mut nbuf: Vec<u32> = Vec::with_capacity(64);
    for &e in entries {
        if ctx.mark_visited(e) {
            let s = score_fn(e);
            ctx.stats.scored += 1;
            ctx.insert(
                Candidate {
                    id: e,
                    score: s,
                    expanded: false,
                },
                window,
            );
        }
    }
    while let Some(pos) = ctx.next_unexpanded() {
        ctx.buffer[pos].expanded = true;
        let node = ctx.buffer[pos].id;
        ctx.stats.hops += 1;
        neighbors_fn(node, &mut nbuf);
        for &nb in nbuf.iter() {
            if ctx.mark_visited(nb) {
                let s = score_fn(nb);
                ctx.stats.scored += 1;
                ctx.insert(
                    Candidate {
                        id: nb,
                        score: s,
                        expanded: false,
                    },
                    window,
                );
            }
        }
    }
    ctx.results()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Path graph 0-1-2-...-9 with scores peaking at node 7.
    fn path_graph() -> (Vec<Vec<u32>>, Vec<f32>) {
        let n = 10;
        let adj: Vec<Vec<u32>> = (0..n)
            .map(|i| {
                let mut v = Vec::new();
                if i > 0 {
                    v.push((i - 1) as u32);
                }
                if i + 1 < n {
                    v.push((i + 1) as u32);
                }
                v
            })
            .collect();
        let scores: Vec<f32> = (0..n).map(|i| -((i as f32) - 7.0).abs()).collect();
        (adj, scores)
    }

    #[test]
    fn finds_global_best_on_path() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        let res = greedy_search(
            &mut ctx,
            &[0],
            4,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert_eq!(res[0].id, 7);
    }

    #[test]
    fn window_one_greedy_can_get_stuck_but_wider_does_not() {
        // two-peak score over a path: local max at 1, global at 8
        let n = 10;
        let adj: Vec<Vec<u32>> = (0..n)
            .map(|i| {
                let mut v = Vec::new();
                if i > 0 {
                    v.push((i - 1) as u32);
                }
                if i + 1 < n {
                    v.push((i + 1) as u32);
                }
                v
            })
            .collect();
        let scores = [0.5f32, 0.9, 0.1, 0.0, 0.0, 0.2, 0.4, 0.6, 1.0, 0.3];
        let run = |window: usize| {
            let mut ctx = SearchCtx::new(n);
            let res = greedy_search(
                &mut ctx,
                &[0],
                window,
                |id| scores[id as usize],
                |id, out| {
                    out.clear();
                    out.extend_from_slice(&adj[id as usize]);
                },
            );
            res[0].id
        };
        assert_eq!(run(10), 8, "wide window explores past the dip");
    }

    #[test]
    fn never_scores_a_node_twice() {
        let (adj, scores) = path_graph();
        let mut count = vec![0usize; 10];
        let mut ctx = SearchCtx::new(10);
        let counter = std::cell::RefCell::new(&mut count);
        greedy_search(
            &mut ctx,
            &[5, 5, 5],
            10,
            |id| {
                counter.borrow_mut()[id as usize] += 1;
                scores[id as usize]
            },
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert!(count.iter().all(|&c| c <= 1), "{count:?}");
    }

    #[test]
    fn results_sorted_descending() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        let res = greedy_search(
            &mut ctx,
            &[0],
            8,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn ctx_reuse_across_epochs() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        for _ in 0..5 {
            let res = greedy_search(
                &mut ctx,
                &[0],
                4,
                |id| scores[id as usize],
                |id, out| {
                    out.clear();
                    out.extend_from_slice(&adj[id as usize]);
                },
            );
            assert_eq!(res[0].id, 7);
        }
        assert!(ctx.stats.hops > 0);
    }

    #[test]
    fn ctx_pool_hands_out_distinct_contexts() {
        let pool = CtxPool::new(2, 10);
        let a = pool.acquire();
        let b = pool.acquire();
        // two concurrent borrows at pool size 2 must both succeed
        drop(a);
        drop(b);
        let (adj, scores) = path_graph();
        let mut guard = pool.acquire();
        let res = greedy_search(
            &mut *guard,
            &[0],
            4,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert_eq!(res[0].id, 7);
    }

    #[test]
    fn buffer_respects_window() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        let res = greedy_search(
            &mut ctx,
            &[0],
            3,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert!(res.len() <= 3);
    }
}
