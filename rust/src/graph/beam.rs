//! Greedy best-first graph traversal with backtracking ("beam search"
//! with a bounded search buffer), shared by Vamana and HNSW.
//!
//! This is the request-path hot loop. All state lives in a reusable
//! [`SearchCtx`] so steady-state searches allocate nothing: the search
//! buffer is a fixed-capacity sorted array (insertion into a ~100-entry
//! window is cheaper than heap churn at these sizes — the same call the
//! SVS library makes), and the visited set is an epoch-stamped array.
//!
//! Scoring is **blocked**: each expansion gathers the expanded node's
//! unvisited neighbors into one batch and hands the whole batch to the
//! score callback (`ScoreStore::score_block` on the request path, which
//! runs the dispatched SIMD kernels with software prefetch of upcoming
//! code rows), then bulk-inserts the results in neighbor order — the
//! visit order, dedup, and buffer semantics are identical to scoring
//! one id at a time.

use crate::util::cancel::CancelToken;
use std::sync::Arc;

/// One search-buffer entry.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub id: u32,
    pub score: f32,
    pub expanded: bool,
}

/// Expansions between cancellation polls: the traversal loop checks the
/// installed [`CancelToken`] every this-many hops, so a deadline is
/// honored within ~32 expansions (tens of microseconds) while the
/// fault-free path pays one branch per hop and at most one relaxed
/// atomic load (plus a clock read while a deadline is armed) per poll.
pub const CANCEL_POLL_HOPS: usize = 32;

/// Reusable search state.
pub struct SearchCtx {
    /// sorted by score descending; capacity = the traversal capacity
    /// (window, or the larger split-buffer retention size)
    buffer: Vec<Candidate>,
    /// filtered-search result buffer: passing candidates only, sorted
    /// by score descending (the navigation `buffer` keeps every node so
    /// traversal can route *through* filtered-out ids)
    passing: Vec<Candidate>,
    /// epoch-stamped visited marks, one per node
    visited: Vec<u32>,
    epoch: u32,
    pub stats: SearchStats,
    /// reusable scratch for the blocked traversal (neighbor gather,
    /// unvisited batch, batch scores) — kept on the ctx so steady-state
    /// searches allocate nothing
    scratch_nbuf: Vec<u32>,
    scratch_batch: Vec<u32>,
    scratch_scores: Vec<f32>,
    /// cooperative cancellation: when installed, the traversal loop
    /// polls this every [`CANCEL_POLL_HOPS`] expansions and stops
    /// early, leaving the buffers holding a valid partial result
    cancel: Option<Arc<CancelToken>>,
}

/// Per-search counters (hops, score evaluations) — these drive the
/// bytes/query memory-traffic model of Fig. 1.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchStats {
    pub hops: usize,
    pub scored: usize,
    /// nodes encountered but excluded by the query's filter predicate
    pub filtered: usize,
}

impl SearchCtx {
    pub fn new(n: usize) -> SearchCtx {
        SearchCtx {
            buffer: Vec::new(),
            passing: Vec::new(),
            visited: vec![0; n],
            epoch: 0,
            stats: SearchStats::default(),
            scratch_nbuf: Vec::new(),
            scratch_batch: Vec::new(),
            scratch_scores: Vec::new(),
            cancel: None,
        }
    }

    /// Install (or clear, with `None`) the cancellation token the next
    /// traversal polls. The scatter path installs the request's token
    /// into each per-shard context before searching and clears it after
    /// — pooled contexts also drop it when returned to their pool, so a
    /// stale token can never cut a later request short.
    pub fn set_cancel(&mut self, token: Option<Arc<CancelToken>>) {
        self.cancel = token;
    }

    /// True once the installed token (if any) reports cancelled.
    #[inline]
    fn cancel_tripped(&self) -> bool {
        self.cancel.as_ref().is_some_and(|t| t.is_cancelled())
    }

    /// Grow the visited array if the graph grew.
    pub fn ensure(&mut self, n: usize) {
        if self.visited.len() < n {
            self.visited.resize(n, 0);
        }
    }

    fn begin(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // epoch wrapped: clear stamps and restart at 1
            self.visited.iter_mut().for_each(|v| *v = 0);
            self.epoch = 1;
        }
        self.buffer.clear();
        self.passing.clear();
        self.stats = SearchStats::default();
    }

    #[inline]
    fn mark_visited(&mut self, id: u32) -> bool {
        let slot = &mut self.visited[id as usize];
        if *slot == self.epoch {
            false
        } else {
            *slot = self.epoch;
            true
        }
    }

    /// Insert into the sorted navigation buffer, keeping at most `cap`
    /// entries. Returns true if inserted.
    #[inline]
    fn insert(&mut self, c: Candidate, cap: usize) -> bool {
        bounded_insert(&mut self.buffer, c, cap)
    }

    /// Insert into the passing-results buffer (filtered search only),
    /// keeping at most `cap` entries.
    #[inline]
    fn insert_passing(&mut self, c: Candidate, cap: usize) {
        bounded_insert(&mut self.passing, c, cap);
    }

    /// Index of the best unexpanded candidate within the first
    /// `window` buffer slots. Split-buffer semantics: candidates past
    /// the window are retained (for re-ranking) but never expanded.
    #[inline]
    fn next_unexpanded(&self, window: usize) -> Option<usize> {
        self.buffer
            .iter()
            .take(window)
            .position(|c| !c.expanded)
    }

    /// The final candidates, best first.
    pub fn results(&self) -> &[Candidate] {
        &self.buffer
    }

    /// The final *passing* candidates of a filtered search, best first.
    pub fn passing_results(&self) -> &[Candidate] {
        &self.passing
    }
}

/// Bounded sorted insert, descending by score: the one copy of the
/// ordering/capacity invariant shared by the navigation and
/// passing-results buffers (so filtered and unfiltered ordering can
/// never drift apart). Returns true if inserted.
#[inline]
fn bounded_insert(buf: &mut Vec<Candidate>, c: Candidate, cap: usize) -> bool {
    // find insertion point (descending by score)
    let pos = buf.partition_point(|e| e.score >= c.score);
    if pos >= cap {
        return false;
    }
    if buf.len() == cap {
        buf.pop();
    }
    buf.insert(pos, c);
    true
}

/// A pool of reusable [`SearchCtx`] for parallel sections (the parallel
/// graph builder and the batch-search path): a condvar-guarded free
/// list. Sized to the worker count, so when at most `workers` closures
/// run concurrently `acquire` always pops without waiting; an
/// oversubscribed borrower *blocks* on the condvar until a context is
/// returned instead of burning a core in a `try_lock` spin.
pub struct CtxPool {
    free: std::sync::Mutex<Vec<SearchCtx>>,
    returned: std::sync::Condvar,
}

/// A [`SearchCtx`] borrowed from a [`CtxPool`]; derefs to the context
/// and returns it to the pool's free list (waking one waiter) on drop.
pub struct PooledCtx<'a> {
    pool: &'a CtxPool,
    ctx: Option<SearchCtx>,
}

impl std::ops::Deref for PooledCtx<'_> {
    type Target = SearchCtx;
    fn deref(&self) -> &SearchCtx {
        // lint:allow(serve-path-panic): the Option is only ever None
        // inside Drop, after which no Deref can run — the standard
        // Option-for-Drop idiom; this branch is unreachable.
        self.ctx.as_ref().expect("pooled ctx present until drop")
    }
}

impl std::ops::DerefMut for PooledCtx<'_> {
    fn deref_mut(&mut self) -> &mut SearchCtx {
        // lint:allow(serve-path-panic): unreachable — see Deref above.
        self.ctx.as_mut().expect("pooled ctx present until drop")
    }
}

impl Drop for PooledCtx<'_> {
    fn drop(&mut self) {
        if let Some(mut ctx) = self.ctx.take() {
            // never return a context with a live cancel token: the next
            // borrower is a different request (and this drop may be
            // running on a panic-unwind path after an injected fault)
            ctx.set_cancel(None);
            // a poisoned lock means another searcher panicked while
            // pushing/popping; the Vec inside is still a valid free
            // list, and returning the ctx keeps the pool from leaking
            let mut free = self
                .pool
                .free
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            free.push(ctx);
            drop(free);
            self.pool.returned.notify_one();
        }
    }
}

impl CtxPool {
    pub fn new(workers: usize, n: usize) -> CtxPool {
        let ctxs: Vec<SearchCtx> = (0..workers.max(1)).map(|_| SearchCtx::new(n)).collect();
        CtxPool {
            free: std::sync::Mutex::new(ctxs),
            returned: std::sync::Condvar::new(),
        }
    }

    /// Borrow a free context, blocking (not spinning) until one is
    /// available. Never deadlocks: every borrow is returned on drop.
    pub fn acquire(&self) -> PooledCtx<'_> {
        let mut free = self
            .free
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        loop {
            if let Some(ctx) = free.pop() {
                return PooledCtx {
                    pool: self,
                    ctx: Some(ctx),
                };
            }
            free = self
                .returned
                .wait(free)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// Greedy traversal with a *per-id* score callback: start from
/// `entries`, repeatedly expand the best unexpanded candidate, scoring
/// its out-neighbors with `score_fn` and fetching them with
/// `neighbors_fn`.
///
/// `window` is the search-buffer width L; the returned slice holds up to
/// `window` candidates, best first. Equivalent to
/// [`greedy_search_ext`] with `capacity == window`, no filter, and the
/// per-id scorer lifted over each batch. Kept for call sites whose
/// scorer is a plain closure (tests, toy graphs); store-backed callers
/// should pass `ScoreStore::score_block` to [`greedy_search_ext`]
/// instead so batches hit the SIMD kernels.
pub fn greedy_search<'a, S, N>(
    ctx: &'a mut SearchCtx,
    entries: &[u32],
    window: usize,
    mut score_fn: S,
    neighbors_fn: N,
) -> &'a [Candidate]
where
    S: FnMut(u32) -> f32,
    N: FnMut(u32, &mut Vec<u32>),
{
    greedy_search_ext(
        ctx,
        entries,
        window,
        window,
        None,
        move |ids: &[u32], out: &mut Vec<f32>| {
            out.clear();
            out.extend(ids.iter().map(|&id| score_fn(id)));
        },
        neighbors_fn,
    )
}

/// Insert one scored batch into the buffers, in batch order — the one
/// copy of the filter/insert bookkeeping shared by the entry seeding
/// and the expansion loop.
#[inline]
fn insert_batch(
    ctx: &mut SearchCtx,
    ids: &[u32],
    scores: &[f32],
    filter: Option<&(dyn Fn(u32) -> bool + Sync)>,
    nav_cap: usize,
    capacity: usize,
) {
    debug_assert_eq!(ids.len(), scores.len());
    ctx.stats.scored += ids.len();
    for (&id, &score) in ids.iter().zip(scores.iter()) {
        let c = Candidate {
            id,
            score,
            expanded: false,
        };
        if let Some(f) = filter {
            if f(id) {
                ctx.insert_passing(c, capacity);
            } else {
                ctx.stats.filtered += 1;
            }
        }
        ctx.insert(c, nav_cap);
    }
}

/// [`greedy_search`] with blocked scoring plus the split-buffer and
/// filtered-search extensions the [`Query`] API exposes:
///
/// * `score_block_fn(ids, out)` — score a whole batch of ids at once
///   (the unvisited neighbors of one expanded node), writing one score
///   per id into `out`. The request path passes
///   [`ScoreStore::score_block`], which runs the dispatched SIMD
///   kernels and prefetches upcoming code rows. Visit order, dedup,
///   and buffer semantics are identical to per-id scoring.
/// * `capacity >= window` — how many candidates to *retain* (the
///   re-rank buffer). Only the best `window` drive expansion, so
///   traversal cost is unchanged; the extra slots merely keep more
///   unexpanded candidates for downstream re-ranking.
/// * `filter` — when present, every scored node still enters the
///   navigation buffer (traversal routes through filtered-out ids),
///   but the returned slice holds only *passing* candidates, collected
///   into a separate buffer of size `capacity`.
///   `ctx.stats.filtered` counts the excluded nodes.
///
/// [`Query`]: crate::index::query::Query
/// [`ScoreStore::score_block`]: crate::quant::ScoreStore::score_block
pub fn greedy_search_ext<'a, S, N>(
    ctx: &'a mut SearchCtx,
    entries: &[u32],
    window: usize,
    capacity: usize,
    filter: Option<&(dyn Fn(u32) -> bool + Sync)>,
    score_block_fn: S,
    neighbors_fn: N,
) -> &'a [Candidate]
where
    S: FnMut(&[u32], &mut Vec<f32>),
    N: FnMut(u32, &mut Vec<u32>),
{
    greedy_search_prefetch(
        ctx,
        entries,
        window,
        capacity,
        filter,
        score_block_fn,
        neighbors_fn,
        |_| {},
    )
}

/// [`greedy_search_ext`] plus a *next-hop prefetch hook*: right before
/// each hop's neighbor block is scored, `prefetch_fn(next)` is called
/// with the id of the best still-unexpanded candidate — the node most
/// likely to be expanded next. The serving path passes a hook that
/// issues software prefetch for that node's adjacency row and its
/// neighbors' code rows, so the memory traffic of hop `h+1` (cache
/// lines, and on an mmap-served index resident page-cache fills)
/// overlaps the scoring kernels of hop `h`. The hook is purely a hint:
/// traversal order, scores, and stats are bit-identical to
/// [`greedy_search_ext`] for every hook, including the no-op.
#[allow(clippy::too_many_arguments)]
pub fn greedy_search_prefetch<'a, S, N, P>(
    ctx: &'a mut SearchCtx,
    entries: &[u32],
    window: usize,
    capacity: usize,
    filter: Option<&(dyn Fn(u32) -> bool + Sync)>,
    mut score_block_fn: S,
    mut neighbors_fn: N,
    mut prefetch_fn: P,
) -> &'a [Candidate]
where
    S: FnMut(&[u32], &mut Vec<f32>),
    N: FnMut(u32, &mut Vec<u32>),
    P: FnMut(u32),
{
    ctx.begin();
    let capacity = capacity.max(window);
    // Without a filter the single buffer both navigates and retains
    // (capacity slots, expansion over the window prefix). With a
    // filter, navigation stays window-bounded — identical traversal to
    // the unfiltered case — and passing results accumulate separately.
    let nav_cap = if filter.is_some() { window } else { capacity };
    // scratch buffers live on the ctx (taken for the duration of the
    // traversal, put back before returning) so steady-state searches
    // allocate nothing
    let mut nbuf = std::mem::take(&mut ctx.scratch_nbuf);
    let mut batch = std::mem::take(&mut ctx.scratch_batch);
    let mut scores = std::mem::take(&mut ctx.scratch_scores);

    // seed: the entry points are one batch (dedup preserves order).
    // `scores` is pre-cleared before every scorer call so a callback
    // that only appends cannot misalign ids and scores.
    batch.clear();
    for &e in entries {
        if ctx.mark_visited(e) {
            batch.push(e);
        }
    }
    scores.clear();
    score_block_fn(&batch, &mut scores);
    insert_batch(ctx, &batch, &scores, filter, nav_cap, capacity);

    while let Some(pos) = ctx.next_unexpanded(window) {
        ctx.buffer[pos].expanded = true;
        let node = ctx.buffer[pos].id;
        ctx.stats.hops += 1;
        // cancellation checkpoint: bounded staleness (the deadline is
        // honored within CANCEL_POLL_HOPS expansions), near-zero cost
        // when no token is installed. Breaking here leaves the buffers
        // sorted and consistent — the caller reads a partial result.
        if ctx.stats.hops % CANCEL_POLL_HOPS == 0 && ctx.cancel_tripped() {
            break;
        }
        neighbors_fn(node, &mut nbuf);
        // gather the unvisited neighbors (marking them visited, in
        // neighbor order), block-score them, bulk-insert
        batch.clear();
        for &nb in nbuf.iter() {
            if ctx.mark_visited(nb) {
                batch.push(nb);
            }
        }
        // the current node is already marked expanded, so this names
        // the best remaining candidate — the likely next hop. Start
        // pulling its rows in while the current block's kernels run.
        if let Some(next) = ctx.next_unexpanded(window) {
            prefetch_fn(ctx.buffer[next].id);
        }
        scores.clear();
        score_block_fn(&batch, &mut scores);
        insert_batch(ctx, &batch, &scores, filter, nav_cap, capacity);
    }
    ctx.scratch_nbuf = nbuf;
    ctx.scratch_batch = batch;
    ctx.scratch_scores = scores;
    if filter.is_some() {
        ctx.passing_results()
    } else {
        ctx.results()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Path graph 0-1-2-...-9 with scores peaking at node 7.
    fn path_graph() -> (Vec<Vec<u32>>, Vec<f32>) {
        let n = 10;
        let adj: Vec<Vec<u32>> = (0..n)
            .map(|i| {
                let mut v = Vec::new();
                if i > 0 {
                    v.push((i - 1) as u32);
                }
                if i + 1 < n {
                    v.push((i + 1) as u32);
                }
                v
            })
            .collect();
        let scores: Vec<f32> = (0..n).map(|i| -((i as f32) - 7.0).abs()).collect();
        (adj, scores)
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn finds_global_best_on_path() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        let res = greedy_search(
            &mut ctx,
            &[0],
            4,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert_eq!(res[0].id, 7);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn window_one_greedy_can_get_stuck_but_wider_does_not() {
        // two-peak score over a path: local max at 1, global at 8
        let n = 10;
        let adj: Vec<Vec<u32>> = (0..n)
            .map(|i| {
                let mut v = Vec::new();
                if i > 0 {
                    v.push((i - 1) as u32);
                }
                if i + 1 < n {
                    v.push((i + 1) as u32);
                }
                v
            })
            .collect();
        let scores = [0.5f32, 0.9, 0.1, 0.0, 0.0, 0.2, 0.4, 0.6, 1.0, 0.3];
        let run = |window: usize| {
            let mut ctx = SearchCtx::new(n);
            let res = greedy_search(
                &mut ctx,
                &[0],
                window,
                |id| scores[id as usize],
                |id, out| {
                    out.clear();
                    out.extend_from_slice(&adj[id as usize]);
                },
            );
            res[0].id
        };
        assert_eq!(run(10), 8, "wide window explores past the dip");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn never_scores_a_node_twice() {
        let (adj, scores) = path_graph();
        let mut count = vec![0usize; 10];
        let mut ctx = SearchCtx::new(10);
        let counter = std::cell::RefCell::new(&mut count);
        greedy_search(
            &mut ctx,
            &[5, 5, 5],
            10,
            |id| {
                counter.borrow_mut()[id as usize] += 1;
                scores[id as usize]
            },
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert!(count.iter().all(|&c| c <= 1), "{count:?}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn results_sorted_descending() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        let res = greedy_search(
            &mut ctx,
            &[0],
            8,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn ctx_reuse_across_epochs() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        for _ in 0..5 {
            let res = greedy_search(
                &mut ctx,
                &[0],
                4,
                |id| scores[id as usize],
                |id, out| {
                    out.clear();
                    out.extend_from_slice(&adj[id as usize]);
                },
            );
            assert_eq!(res[0].id, 7);
        }
        assert!(ctx.stats.hops > 0);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn ctx_pool_hands_out_distinct_contexts() {
        let pool = CtxPool::new(2, 10);
        let a = pool.acquire();
        let b = pool.acquire();
        // two concurrent borrows at pool size 2 must both succeed
        drop(a);
        drop(b);
        let (adj, scores) = path_graph();
        let mut guard = pool.acquire();
        let res = greedy_search(
            &mut *guard,
            &[0],
            4,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert_eq!(res[0].id, 7);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn filtered_search_returns_only_passing_but_navigates_through() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        // filter out the odd nodes — including parts of the only path
        // from 0 to the score peak at 7
        let even = |id: u32| id % 2 == 0;
        let res = greedy_search_ext(
            &mut ctx,
            &[0],
            10,
            10,
            Some(&even),
            |ids: &[u32], out: &mut Vec<f32>| {
                out.clear();
                out.extend(ids.iter().map(|&id| scores[id as usize]));
            },
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert!(res.iter().all(|c| c.id % 2 == 0), "{res:?}");
        // traversal routed through odd nodes to reach the peak region:
        // best passing node is 6 or 8 (score -1), neighbors of peak 7
        assert_eq!(scores[res[0].id as usize], -1.0, "{res:?}");
        assert_eq!(ctx.stats.filtered, 5, "all five odd nodes counted");
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn split_buffer_retains_beyond_window_without_extra_expansion() {
        let (adj, scores) = path_graph();
        let run = |capacity: usize| {
            let mut ctx = SearchCtx::new(10);
            let n = greedy_search_ext(
                &mut ctx,
                &[0],
                3,
                capacity,
                None,
                |ids: &[u32], out: &mut Vec<f32>| {
                    out.clear();
                    out.extend(ids.iter().map(|&id| scores[id as usize]));
                },
                |id, out| {
                    out.clear();
                    out.extend_from_slice(&adj[id as usize]);
                },
            )
            .len();
            (n, ctx.stats.hops, ctx.stats.scored)
        };
        let (n_narrow, hops_narrow, scored_narrow) = run(3);
        let (n_wide, hops_wide, scored_wide) = run(8);
        assert!(n_wide > n_narrow, "capacity retained nothing extra");
        // identical traversal: the split buffer widens retention only
        assert_eq!(hops_wide, hops_narrow);
        assert_eq!(scored_wide, scored_narrow);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn blocked_scoring_identical_to_per_id() {
        // the block-scored path must reproduce per-id traversal exactly:
        // same ids, same scores, same hop/score counters
        let (adj, scores) = path_graph();
        let neighbors = |id: u32, out: &mut Vec<u32>| {
            out.clear();
            out.extend_from_slice(&adj[id as usize]);
        };
        let mut ctx_a = SearchCtx::new(10);
        let res_a = greedy_search(&mut ctx_a, &[0], 4, |id| scores[id as usize], neighbors);
        let a: Vec<Candidate> = res_a.to_vec();
        let mut ctx_b = SearchCtx::new(10);
        let b: Vec<Candidate> = greedy_search_ext(
            &mut ctx_b,
            &[0],
            4,
            4,
            None,
            |ids: &[u32], out: &mut Vec<f32>| {
                out.clear();
                out.extend(ids.iter().map(|&id| scores[id as usize]));
            },
            neighbors,
        )
        .to_vec();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.score.to_bits(), y.score.to_bits());
        }
        assert_eq!(ctx_a.stats.hops, ctx_b.stats.hops);
        assert_eq!(ctx_a.stats.scored, ctx_b.stats.scored);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn ctx_pool_blocks_oversubscribed_acquire_until_return() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let pool = Arc::new(CtxPool::new(1, 4));
        let held = pool.acquire();
        let released = Arc::new(AtomicBool::new(false));
        let waiter = {
            let (pool, released) = (Arc::clone(&pool), Arc::clone(&released));
            std::thread::spawn(move || {
                let _ctx = pool.acquire(); // must block until the holder drops
                assert!(
                    released.load(Ordering::SeqCst),
                    "acquire returned while the only context was held"
                );
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(50));
        released.store(true, Ordering::SeqCst);
        drop(held);
        waiter.join().unwrap();
    }

    #[test]
    fn cancelled_token_stops_traversal_within_poll_interval() {
        // long path so an uncancelled traversal needs hundreds of hops
        let n = 400usize;
        let adj: Vec<Vec<u32>> = (0..n)
            .map(|i| {
                let mut v = Vec::new();
                if i > 0 {
                    v.push((i - 1) as u32);
                }
                if i + 1 < n {
                    v.push((i + 1) as u32);
                }
                v
            })
            .collect();
        // monotone scores pull the beam down the whole path
        let run = |cancel: Option<Arc<CancelToken>>| {
            let mut ctx = SearchCtx::new(n);
            ctx.set_cancel(cancel);
            let first = greedy_search(
                &mut ctx,
                &[0],
                4,
                |id| id as f32,
                |id, out| {
                    out.clear();
                    out.extend_from_slice(&adj[id as usize]);
                },
            )
            .first()
            .copied();
            (ctx.stats.hops, first)
        };
        let (full_hops, full_best) = run(None);
        assert!(full_hops > 2 * CANCEL_POLL_HOPS, "graph too small to test");
        assert_eq!(full_best.unwrap().id, (n - 1) as u32);

        let token = Arc::new(CancelToken::new());
        token.cancel();
        let (cut_hops, cut_best) = run(Some(token));
        assert!(
            cut_hops <= CANCEL_POLL_HOPS,
            "cancelled traversal ran {cut_hops} hops"
        );
        // partial results are still valid, sorted candidates
        assert!(cut_best.is_some(), "partial result retained");
    }

    #[test]
    fn pooled_ctx_drop_clears_cancel_token() {
        let pool = CtxPool::new(1, 8);
        {
            let mut ctx = pool.acquire();
            let token = Arc::new(CancelToken::new());
            token.cancel();
            ctx.set_cancel(Some(token));
        } // returned to the pool here
        let ctx = pool.acquire();
        assert!(
            !ctx.cancel_tripped(),
            "stale token survived the pool round-trip"
        );
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn buffer_respects_window() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        let res = greedy_search(
            &mut ctx,
            &[0],
            3,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert!(res.len() <= 3);
    }
}
