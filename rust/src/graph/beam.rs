//! Greedy best-first graph traversal with backtracking ("beam search"
//! with a bounded search buffer), shared by Vamana and HNSW.
//!
//! This is the request-path hot loop. All state lives in a reusable
//! [`SearchCtx`] so steady-state searches allocate nothing: the search
//! buffer is a fixed-capacity sorted array (insertion into a ~100-entry
//! window is cheaper than heap churn at these sizes — the same call the
//! SVS library makes), and the visited set is an epoch-stamped array.

/// One search-buffer entry.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub id: u32,
    pub score: f32,
    pub expanded: bool,
}

/// Reusable search state.
pub struct SearchCtx {
    /// sorted by score descending; capacity = the traversal capacity
    /// (window, or the larger split-buffer retention size)
    buffer: Vec<Candidate>,
    /// filtered-search result buffer: passing candidates only, sorted
    /// by score descending (the navigation `buffer` keeps every node so
    /// traversal can route *through* filtered-out ids)
    passing: Vec<Candidate>,
    /// epoch-stamped visited marks, one per node
    visited: Vec<u32>,
    epoch: u32,
    pub stats: SearchStats,
}

/// Per-search counters (hops, score evaluations) — these drive the
/// bytes/query memory-traffic model of Fig. 1.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchStats {
    pub hops: usize,
    pub scored: usize,
    /// nodes encountered but excluded by the query's filter predicate
    pub filtered: usize,
}

impl SearchCtx {
    pub fn new(n: usize) -> SearchCtx {
        SearchCtx {
            buffer: Vec::new(),
            passing: Vec::new(),
            visited: vec![0; n],
            epoch: 0,
            stats: SearchStats::default(),
        }
    }

    /// Grow the visited array if the graph grew.
    pub fn ensure(&mut self, n: usize) {
        if self.visited.len() < n {
            self.visited.resize(n, 0);
        }
    }

    fn begin(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // epoch wrapped: clear stamps and restart at 1
            self.visited.iter_mut().for_each(|v| *v = 0);
            self.epoch = 1;
        }
        self.buffer.clear();
        self.passing.clear();
        self.stats = SearchStats::default();
    }

    #[inline]
    fn mark_visited(&mut self, id: u32) -> bool {
        let slot = &mut self.visited[id as usize];
        if *slot == self.epoch {
            false
        } else {
            *slot = self.epoch;
            true
        }
    }

    /// Insert into the sorted navigation buffer, keeping at most `cap`
    /// entries. Returns true if inserted.
    #[inline]
    fn insert(&mut self, c: Candidate, cap: usize) -> bool {
        bounded_insert(&mut self.buffer, c, cap)
    }

    /// Insert into the passing-results buffer (filtered search only),
    /// keeping at most `cap` entries.
    #[inline]
    fn insert_passing(&mut self, c: Candidate, cap: usize) {
        bounded_insert(&mut self.passing, c, cap);
    }

    /// Index of the best unexpanded candidate within the first
    /// `window` buffer slots. Split-buffer semantics: candidates past
    /// the window are retained (for re-ranking) but never expanded.
    #[inline]
    fn next_unexpanded(&self, window: usize) -> Option<usize> {
        self.buffer
            .iter()
            .take(window)
            .position(|c| !c.expanded)
    }

    /// The final candidates, best first.
    pub fn results(&self) -> &[Candidate] {
        &self.buffer
    }

    /// The final *passing* candidates of a filtered search, best first.
    pub fn passing_results(&self) -> &[Candidate] {
        &self.passing
    }
}

/// Bounded sorted insert, descending by score: the one copy of the
/// ordering/capacity invariant shared by the navigation and
/// passing-results buffers (so filtered and unfiltered ordering can
/// never drift apart). Returns true if inserted.
#[inline]
fn bounded_insert(buf: &mut Vec<Candidate>, c: Candidate, cap: usize) -> bool {
    // find insertion point (descending by score)
    let pos = buf.partition_point(|e| e.score >= c.score);
    if pos >= cap {
        return false;
    }
    if buf.len() == cap {
        buf.pop();
    }
    buf.insert(pos, c);
    true
}

/// A pool of reusable [`SearchCtx`] for parallel sections (the parallel
/// graph builder and the batch-search path). Sized to the worker count:
/// as long as at most `workers` closures run concurrently, `acquire`
/// always finds a free context without blocking on a held lock.
pub struct CtxPool {
    ctxs: Vec<std::sync::Mutex<SearchCtx>>,
}

impl CtxPool {
    pub fn new(workers: usize, n: usize) -> CtxPool {
        CtxPool {
            ctxs: (0..workers.max(1))
                .map(|_| std::sync::Mutex::new(SearchCtx::new(n)))
                .collect(),
        }
    }

    /// Borrow any free context (spins across the pool; never deadlocks
    /// when concurrent borrowers <= pool size).
    pub fn acquire(&self) -> std::sync::MutexGuard<'_, SearchCtx> {
        loop {
            for c in &self.ctxs {
                if let Ok(guard) = c.try_lock() {
                    return guard;
                }
            }
            std::thread::yield_now();
        }
    }
}

/// Greedy traversal: start from `entries`, repeatedly expand the best
/// unexpanded candidate, scoring its out-neighbors with `score_fn` and
/// fetching them with `neighbors_fn`.
///
/// `window` is the search-buffer width L; the returned slice holds up to
/// `window` candidates, best first. Equivalent to
/// [`greedy_search_ext`] with `capacity == window` and no filter.
pub fn greedy_search<'a, S, N>(
    ctx: &'a mut SearchCtx,
    entries: &[u32],
    window: usize,
    score_fn: S,
    neighbors_fn: N,
) -> &'a [Candidate]
where
    S: FnMut(u32) -> f32,
    N: FnMut(u32, &mut Vec<u32>),
{
    greedy_search_ext(ctx, entries, window, window, None, score_fn, neighbors_fn)
}

/// [`greedy_search`] with the split-buffer and filtered-search
/// extensions the [`Query`] API exposes:
///
/// * `capacity >= window` — how many candidates to *retain* (the
///   re-rank buffer). Only the best `window` drive expansion, so
///   traversal cost is unchanged; the extra slots merely keep more
///   unexpanded candidates for downstream re-ranking.
/// * `filter` — when present, every scored node still enters the
///   navigation buffer (traversal routes through filtered-out ids),
///   but the returned slice holds only *passing* candidates, collected
///   into a separate buffer of size `capacity`.
///   `ctx.stats.filtered` counts the excluded nodes.
///
/// [`Query`]: crate::index::query::Query
pub fn greedy_search_ext<'a, S, N>(
    ctx: &'a mut SearchCtx,
    entries: &[u32],
    window: usize,
    capacity: usize,
    filter: Option<&(dyn Fn(u32) -> bool + Sync)>,
    mut score_fn: S,
    mut neighbors_fn: N,
) -> &'a [Candidate]
where
    S: FnMut(u32) -> f32,
    N: FnMut(u32, &mut Vec<u32>),
{
    ctx.begin();
    let capacity = capacity.max(window);
    // Without a filter the single buffer both navigates and retains
    // (capacity slots, expansion over the window prefix). With a
    // filter, navigation stays window-bounded — identical traversal to
    // the unfiltered case — and passing results accumulate separately.
    let nav_cap = if filter.is_some() { window } else { capacity };
    let mut nbuf: Vec<u32> = Vec::with_capacity(64);
    macro_rules! visit {
        ($id:expr) => {{
            let id = $id;
            if ctx.mark_visited(id) {
                let s = score_fn(id);
                ctx.stats.scored += 1;
                let c = Candidate {
                    id,
                    score: s,
                    expanded: false,
                };
                if let Some(f) = filter {
                    if f(id) {
                        ctx.insert_passing(c, capacity);
                    } else {
                        ctx.stats.filtered += 1;
                    }
                }
                ctx.insert(c, nav_cap);
            }
        }};
    }
    for &e in entries {
        visit!(e);
    }
    while let Some(pos) = ctx.next_unexpanded(window) {
        ctx.buffer[pos].expanded = true;
        let node = ctx.buffer[pos].id;
        ctx.stats.hops += 1;
        neighbors_fn(node, &mut nbuf);
        for &nb in nbuf.iter() {
            visit!(nb);
        }
    }
    if filter.is_some() {
        ctx.passing_results()
    } else {
        ctx.results()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Path graph 0-1-2-...-9 with scores peaking at node 7.
    fn path_graph() -> (Vec<Vec<u32>>, Vec<f32>) {
        let n = 10;
        let adj: Vec<Vec<u32>> = (0..n)
            .map(|i| {
                let mut v = Vec::new();
                if i > 0 {
                    v.push((i - 1) as u32);
                }
                if i + 1 < n {
                    v.push((i + 1) as u32);
                }
                v
            })
            .collect();
        let scores: Vec<f32> = (0..n).map(|i| -((i as f32) - 7.0).abs()).collect();
        (adj, scores)
    }

    #[test]
    fn finds_global_best_on_path() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        let res = greedy_search(
            &mut ctx,
            &[0],
            4,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert_eq!(res[0].id, 7);
    }

    #[test]
    fn window_one_greedy_can_get_stuck_but_wider_does_not() {
        // two-peak score over a path: local max at 1, global at 8
        let n = 10;
        let adj: Vec<Vec<u32>> = (0..n)
            .map(|i| {
                let mut v = Vec::new();
                if i > 0 {
                    v.push((i - 1) as u32);
                }
                if i + 1 < n {
                    v.push((i + 1) as u32);
                }
                v
            })
            .collect();
        let scores = [0.5f32, 0.9, 0.1, 0.0, 0.0, 0.2, 0.4, 0.6, 1.0, 0.3];
        let run = |window: usize| {
            let mut ctx = SearchCtx::new(n);
            let res = greedy_search(
                &mut ctx,
                &[0],
                window,
                |id| scores[id as usize],
                |id, out| {
                    out.clear();
                    out.extend_from_slice(&adj[id as usize]);
                },
            );
            res[0].id
        };
        assert_eq!(run(10), 8, "wide window explores past the dip");
    }

    #[test]
    fn never_scores_a_node_twice() {
        let (adj, scores) = path_graph();
        let mut count = vec![0usize; 10];
        let mut ctx = SearchCtx::new(10);
        let counter = std::cell::RefCell::new(&mut count);
        greedy_search(
            &mut ctx,
            &[5, 5, 5],
            10,
            |id| {
                counter.borrow_mut()[id as usize] += 1;
                scores[id as usize]
            },
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert!(count.iter().all(|&c| c <= 1), "{count:?}");
    }

    #[test]
    fn results_sorted_descending() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        let res = greedy_search(
            &mut ctx,
            &[0],
            8,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn ctx_reuse_across_epochs() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        for _ in 0..5 {
            let res = greedy_search(
                &mut ctx,
                &[0],
                4,
                |id| scores[id as usize],
                |id, out| {
                    out.clear();
                    out.extend_from_slice(&adj[id as usize]);
                },
            );
            assert_eq!(res[0].id, 7);
        }
        assert!(ctx.stats.hops > 0);
    }

    #[test]
    fn ctx_pool_hands_out_distinct_contexts() {
        let pool = CtxPool::new(2, 10);
        let a = pool.acquire();
        let b = pool.acquire();
        // two concurrent borrows at pool size 2 must both succeed
        drop(a);
        drop(b);
        let (adj, scores) = path_graph();
        let mut guard = pool.acquire();
        let res = greedy_search(
            &mut *guard,
            &[0],
            4,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert_eq!(res[0].id, 7);
    }

    #[test]
    fn filtered_search_returns_only_passing_but_navigates_through() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        // filter out the odd nodes — including parts of the only path
        // from 0 to the score peak at 7
        let even = |id: u32| id % 2 == 0;
        let res = greedy_search_ext(
            &mut ctx,
            &[0],
            10,
            10,
            Some(&even),
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert!(res.iter().all(|c| c.id % 2 == 0), "{res:?}");
        // traversal routed through odd nodes to reach the peak region:
        // best passing node is 6 or 8 (score -1), neighbors of peak 7
        assert_eq!(scores[res[0].id as usize], -1.0, "{res:?}");
        assert_eq!(ctx.stats.filtered, 5, "all five odd nodes counted");
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn split_buffer_retains_beyond_window_without_extra_expansion() {
        let (adj, scores) = path_graph();
        let run = |capacity: usize| {
            let mut ctx = SearchCtx::new(10);
            let n = greedy_search_ext(
                &mut ctx,
                &[0],
                3,
                capacity,
                None,
                |id| scores[id as usize],
                |id, out| {
                    out.clear();
                    out.extend_from_slice(&adj[id as usize]);
                },
            )
            .len();
            (n, ctx.stats.hops, ctx.stats.scored)
        };
        let (n_narrow, hops_narrow, scored_narrow) = run(3);
        let (n_wide, hops_wide, scored_wide) = run(8);
        assert!(n_wide > n_narrow, "capacity retained nothing extra");
        // identical traversal: the split buffer widens retention only
        assert_eq!(hops_wide, hops_narrow);
        assert_eq!(scored_wide, scored_narrow);
    }

    #[test]
    fn buffer_respects_window() {
        let (adj, scores) = path_graph();
        let mut ctx = SearchCtx::new(10);
        let res = greedy_search(
            &mut ctx,
            &[0],
            3,
            |id| scores[id as usize],
            |id, out| {
                out.clear();
                out.extend_from_slice(&adj[id as usize]);
            },
        );
        assert!(res.len() <= 3);
    }
}
