//! Vamana graph construction (Jayaram Subramanya et al., 2019) — the
//! graph used by SVS/LeanVec — with the α-slack robust-prune rule and
//! the two-pass build schedule (Appendix A of the paper).
//!
//! Build works directly on a compressed [`ScoreStore`] (the paper's key
//! observation: construction is robust to LVQ *and* to dimensionality
//! reduction, Fig. 14), so building on LeanVec primaries is exactly as
//! fast as searching them.

use crate::config::{GraphParams, Similarity};
use crate::data::io::bin;
use crate::graph::beam::{greedy_search_ext, greedy_search_prefetch, CtxPool, SearchCtx};
use crate::linalg::matrix::l2_sq;
use crate::quant::ScoreStore;
use crate::util::threadpool::{parallel_map, resolve_threads};

/// Nodes inserted per round of the batch-synchronous parallel build.
/// Fixed (not a function of the thread count) so the parallel graph is
/// identical for every `threads > 1`: each round's searches run against
/// the same frozen snapshot regardless of how many workers execute them.
const PARALLEL_ROUND: usize = 128;

/// Adjacency storage: the mutable build/serve path keeps one flat
/// fixed-max-degree u32 slab per node; an mmap-loaded graph keeps the
/// snapshot's packed CSR lists *borrowed* from the mapping (offsets
/// owned, neighbor block zero-copy). Any mutation of a CSR graph
/// transparently re-pads it into a slab first.
enum AdjRepr {
    Slab {
        flat: Vec<u32>,
        len: Vec<u32>,
    },
    Csr {
        /// n+1 prefix sums over the per-node degrees
        offsets: Vec<u64>,
        /// every neighbor list concatenated, typically mmap-borrowed
        nbrs: crate::util::mmap::Arr<u32>,
    },
}

/// Fixed-max-degree adjacency stored as one flat u32 block per node
/// (or, for a frozen mmap-served graph, as borrowed CSR lists — see
/// [`AdjRepr`]; the accessor API is identical either way).
pub struct Adjacency {
    n: usize,
    max_degree: usize,
    repr: AdjRepr,
}

impl Adjacency {
    pub fn new(n: usize, max_degree: usize) -> Adjacency {
        Adjacency {
            n,
            max_degree,
            repr: AdjRepr::Slab {
                flat: vec![0; n * max_degree],
                len: vec![0; n],
            },
        }
    }

    /// Wrap already-validated CSR lists (degree prefix sums + packed
    /// neighbor block, typically borrowed from a mapped snapshot).
    pub(crate) fn from_csr(
        n: usize,
        max_degree: usize,
        offsets: Vec<u64>,
        nbrs: crate::util::mmap::Arr<u32>,
    ) -> Adjacency {
        debug_assert_eq!(offsets.len(), n + 1);
        debug_assert_eq!(*offsets.last().unwrap_or(&0) as usize, nbrs.len());
        Adjacency {
            n,
            max_degree,
            repr: AdjRepr::Csr { offsets, nbrs },
        }
    }

    /// True when the neighbor lists are served from the frozen CSR
    /// (i.e. this graph came through `load_mmap` and was not mutated).
    pub fn is_csr(&self) -> bool {
        matches!(self.repr, AdjRepr::Csr { .. })
    }

    /// Re-pad the CSR lists into the mutable slab layout. No-op when
    /// already a slab; copies the borrowed neighbor block exactly once.
    fn make_slab(&mut self) {
        if let AdjRepr::Csr { offsets, nbrs } = &self.repr {
            let mut flat = vec![0u32; self.n * self.max_degree];
            let mut len = vec![0u32; self.n];
            for i in 0..self.n {
                let (a, b) = (offsets[i] as usize, offsets[i + 1] as usize);
                let deg = b - a;
                flat[i * self.max_degree..i * self.max_degree + deg]
                    .copy_from_slice(&nbrs[a..b]);
                len[i] = deg as u32;
            }
            self.repr = AdjRepr::Slab { flat, len };
        }
    }

    #[inline]
    pub fn neighbors(&self, id: u32) -> &[u32] {
        let i = id as usize;
        match &self.repr {
            AdjRepr::Slab { flat, len } => {
                &flat[i * self.max_degree..i * self.max_degree + len[i] as usize]
            }
            AdjRepr::Csr { offsets, nbrs } => {
                &nbrs[offsets[i] as usize..offsets[i + 1] as usize]
            }
        }
    }

    pub fn set_neighbors(&mut self, id: u32, list: &[u32]) {
        self.make_slab();
        let i = id as usize;
        let k = list.len().min(self.max_degree);
        match &mut self.repr {
            AdjRepr::Slab { flat, len } => {
                flat[i * self.max_degree..i * self.max_degree + k].copy_from_slice(&list[..k]);
                len[i] = k as u32;
            }
            AdjRepr::Csr { .. } => unreachable!("make_slab just ran"),
        }
    }

    /// Append one neighbor; returns false when full.
    pub fn push_neighbor(&mut self, id: u32, nb: u32) -> bool {
        self.make_slab();
        let i = id as usize;
        match &mut self.repr {
            AdjRepr::Slab { flat, len } => {
                let l = len[i] as usize;
                if l >= self.max_degree {
                    return false;
                }
                flat[i * self.max_degree + l] = nb;
                len[i] = (l + 1) as u32;
                true
            }
            AdjRepr::Csr { .. } => unreachable!("make_slab just ran"),
        }
    }

    pub fn degree(&self, id: u32) -> usize {
        match &self.repr {
            AdjRepr::Slab { len, .. } => len[id as usize] as usize,
            AdjRepr::Csr { offsets, .. } => {
                let i = id as usize;
                (offsets[i + 1] - offsets[i]) as usize
            }
        }
    }

    /// Per-node degrees as a fresh vector (the snapshot writer's CSR
    /// difference form).
    pub fn degrees(&self) -> Vec<u32> {
        (0..self.n as u32).map(|i| self.degree(i) as u32).collect()
    }

    pub fn len_nodes(&self) -> usize {
        self.n
    }

    pub fn max_degree(&self) -> usize {
        self.max_degree
    }

    pub fn avg_degree(&self) -> f64 {
        let total: f64 = (0..self.n as u32).map(|i| self.degree(i) as f64).sum();
        total / self.n.max(1) as f64
    }

    /// Test-battery hook: overwrite one node's stored degree so the
    /// fsck checkers have a bound-violating corruption to detect (the
    /// accessor API clamps degrees on every write, so this state is
    /// otherwise unreachable).
    #[doc(hidden)]
    pub fn corrupt_degree_for_fsck(&mut self, id: u32, fake_len: u32) {
        self.make_slab();
        match &mut self.repr {
            AdjRepr::Slab { len, .. } => len[id as usize] = fake_len,
            AdjRepr::Csr { .. } => unreachable!("make_slab just ran"),
        }
    }

    /// Deep structural check for the fsck layer: every neighbor id in
    /// `[0, n)`, no self-loops, every degree within `max_degree`, and
    /// (for a CSR graph) monotone offsets that cover the packed
    /// neighbor block exactly. Never panics on corrupt state — degrees
    /// are validated *before* any neighbor slice is formed, and
    /// scanning stops after 16 violations so a wholly corrupt graph
    /// reports a bounded sample rather than one entry per node.
    pub fn check_invariants(&self, out: &mut Vec<crate::util::invariants::Violation>) {
        use crate::util::invariants::Violation;
        let start = out.len();
        let full = |out: &Vec<Violation>| out.len() - start >= 16;
        match &self.repr {
            AdjRepr::Slab { flat, len } => {
                if len.len() != self.n || flat.len() != self.n * self.max_degree {
                    out.push(Violation::new(
                        "graph",
                        "payload-size-mismatch",
                        format!(
                            "slab arrays {}x{} disagree with {} nodes x {} max degree",
                            len.len(),
                            flat.len(),
                            self.n,
                            self.max_degree
                        ),
                    ));
                    return;
                }
                for i in 0..self.n {
                    if full(out) {
                        return;
                    }
                    let deg = len[i] as usize;
                    if deg > self.max_degree {
                        out.push(Violation::new(
                            "graph",
                            "degree-overflow",
                            format!("node {i}: degree {deg} > max {}", self.max_degree),
                        ));
                        continue; // the slice past max_degree is not valid to form
                    }
                    let base = i * self.max_degree;
                    self.check_list(i, &flat[base..base + deg], out);
                }
            }
            AdjRepr::Csr { offsets, nbrs } => {
                if offsets.len() != self.n + 1 {
                    out.push(Violation::new(
                        "graph",
                        "csr-offsets",
                        format!("{} offsets for {} nodes (want n + 1)", offsets.len(), self.n),
                    ));
                    return;
                }
                if offsets.first() != Some(&0)
                    || offsets.windows(2).any(|w| w[0] > w[1])
                    || *offsets.last().unwrap_or(&0) as usize != nbrs.len()
                {
                    out.push(Violation::new(
                        "graph",
                        "csr-offsets",
                        format!(
                            "offsets not a monotone cover of the {}-edge block",
                            nbrs.len()
                        ),
                    ));
                    return;
                }
                for i in 0..self.n {
                    if full(out) {
                        return;
                    }
                    let (a, b) = (offsets[i] as usize, offsets[i + 1] as usize);
                    if b - a > self.max_degree {
                        out.push(Violation::new(
                            "graph",
                            "degree-overflow",
                            format!("node {i}: degree {} > max {}", b - a, self.max_degree),
                        ));
                        continue;
                    }
                    self.check_list(i, &nbrs[a..b], out);
                }
            }
        }
    }

    /// One node's neighbor list: in-range ids, no self-loop. At most
    /// one violation of each kind per node keeps reports readable.
    fn check_list(
        &self,
        node: usize,
        list: &[u32],
        out: &mut Vec<crate::util::invariants::Violation>,
    ) {
        use crate::util::invariants::Violation;
        if let Some(&nb) = list.iter().find(|&&nb| nb as usize >= self.n) {
            out.push(Violation::new(
                "graph",
                "neighbor-out-of-range",
                format!("node {node}: neighbor {nb} >= {} nodes", self.n),
            ));
        }
        if list.iter().any(|&nb| nb as usize == node) {
            out.push(Violation::new(
                "graph",
                "self-loop",
                format!("node {node} lists itself"),
            ));
        }
    }
}

/// A built Vamana graph: adjacency + entry point.
pub struct VamanaGraph {
    pub adj: Adjacency,
    pub medoid: u32,
    pub params: GraphParams,
    pub sim: Similarity,
    /// wall-clock seconds spent in `build` (Fig. 6 data)
    pub build_seconds: f64,
}

impl VamanaGraph {
    /// Deep structural check for the fsck layer: the adjacency
    /// invariants ([`Adjacency::check_invariants`]) plus a valid entry
    /// point — the medoid must name a real node whenever the graph has
    /// any.
    pub fn check_invariants(&self, out: &mut Vec<crate::util::invariants::Violation>) {
        use crate::util::invariants::Violation;
        let n = self.adj.len_nodes();
        if n > 0 && self.medoid as usize >= n {
            out.push(Violation::new(
                "graph",
                "medoid-out-of-range",
                format!("medoid {} >= {n} nodes", self.medoid),
            ));
        }
        self.adj.check_invariants(out);
    }

    /// Serialize the graph as a CSR-packed snapshot section: scalar
    /// parameters, the per-node degree array (the CSR offsets in
    /// difference form), then every neighbor list concatenated without
    /// the fixed-degree padding [`Adjacency`] keeps in memory. Byte
    /// layout: `docs/SNAPSHOT_FORMAT.md`.
    ///
    /// Returns the alignment anchor: the offset within `out` of the
    /// raw degree-array data. The packed neighbor block follows at
    /// `anchor + 4n + 8`, so anchoring the degrees on a 64-byte
    /// boundary makes the neighbor block 4-aligned too — both arrays
    /// then borrow cleanly under `load_mmap`.
    pub fn write_bytes(&self, out: &mut Vec<u8>) -> usize {
        let n = self.adj.len_nodes();
        bin::put_u64(out, n as u64);
        bin::put_u32(out, self.adj.max_degree() as u32);
        bin::put_u32(out, self.params.max_degree as u32);
        bin::put_u32(out, self.params.build_window as u32);
        bin::put_f32(out, self.params.alpha);
        bin::put_u8(out, self.sim.code());
        bin::put_u32(out, self.medoid);
        bin::put_f64(out, self.build_seconds);
        let degrees = self.adj.degrees();
        let anchor = out.len() + 8; // degree u32 data after the u64 count
        bin::put_u32s(out, &degrees);
        let total: usize = degrees.iter().map(|&l| l as usize).sum();
        bin::put_u64(out, total as u64);
        for id in 0..n as u32 {
            for &nb in self.adj.neighbors(id) {
                out.extend_from_slice(&nb.to_le_bytes());
            }
        }
        anchor
    }

    /// Inverse of [`VamanaGraph::write_bytes`], re-padding the CSR lists
    /// into the fixed-max-degree layout. Validates every degree and
    /// neighbor id so a corrupted section errors instead of panicking.
    pub fn read_bytes(cur: &mut bin::Cursor) -> std::io::Result<VamanaGraph> {
        Self::read_bytes_src(cur, None)
    }

    /// [`VamanaGraph::read_bytes`] with an optional mmap backing: when
    /// `src` is given the packed neighbor block stays *borrowed* from
    /// the mapping as frozen CSR lists (the owned path re-pads into
    /// the mutable slab exactly as before). Every validation — degree
    /// bounds, neighbor-id range, edge-count cross-check, the anti-OOM
    /// slab guard — runs identically on both paths.
    pub fn read_bytes_src(
        cur: &mut bin::Cursor,
        src: Option<&crate::util::mmap::SectionSrc>,
    ) -> std::io::Result<VamanaGraph> {
        let bad = |what: &str| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("inconsistent graph section: {what}"),
            )
        };
        let n = cur.get_u64()? as usize;
        let max_degree = cur.get_u32()? as usize;
        let params = GraphParams {
            max_degree: cur.get_u32()? as usize,
            build_window: cur.get_u32()? as usize,
            alpha: cur.get_f32()?,
        };
        let sim_code = cur.get_u8()?;
        let sim = Similarity::from_code(sim_code)
            .ok_or_else(|| bad("unknown similarity code"))?;
        let medoid = cur.get_u32()?;
        let build_seconds = cur.get_f64()?;
        let degrees = cur.get_u32s()?;
        if degrees.len() != n {
            return Err(bad("degree array length"));
        }
        if n > 0 && medoid as usize >= n {
            return Err(bad("medoid out of range"));
        }
        let total = cur.get_u64()? as usize;
        let expect: usize = degrees.iter().map(|&l| l as usize).sum();
        if total != expect {
            return Err(bad("edge count disagrees with degrees"));
        }
        // the slab is n * max_degree slots: refuse absurd sizes rather
        // than letting a corrupt-but-self-consistent header drive a
        // process-aborting allocation (2^33 u32 slots = 32 GiB, far
        // above any graph this crate builds but below OOM territory)
        match n.checked_mul(max_degree) {
            Some(slots) if max_degree <= (1 << 20) && (slots as u64) <= (1u64 << 33) => {}
            _ => return Err(bad("adjacency slab implausibly large")),
        }
        let adj = if let Some(s) = src {
            // mmap path: validate the whole packed block, then borrow
            // it (falling back to an owned copy if misaligned) behind
            // owned prefix-sum offsets
            let mut offsets = Vec::with_capacity(n + 1);
            let mut acc = 0u64;
            offsets.push(0);
            for &deg in &degrees {
                if deg as usize > max_degree {
                    return Err(bad("degree exceeds max_degree"));
                }
                acc += deg as u64;
                offsets.push(acc);
            }
            let block_off = cur.pos();
            let raw = cur.take(total * 4)?;
            for c in raw.chunks_exact(4) {
                let nb = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                if nb as usize >= n {
                    return Err(bad("neighbor id out of range"));
                }
            }
            let nbrs = match crate::util::mmap::Arr::<u32>::from_map(
                &s.map,
                s.base + block_off,
                total,
            ) {
                Some(arr) => arr,
                None => {
                    s.note_fallback();
                    crate::util::mmap::Arr::Owned(
                        raw.chunks_exact(4)
                            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect(),
                    )
                }
            };
            Adjacency::from_csr(n, max_degree, offsets, nbrs)
        } else {
            let mut adj = Adjacency::new(n, max_degree);
            let mut list = Vec::with_capacity(max_degree);
            for (i, &deg) in degrees.iter().enumerate() {
                let deg = deg as usize;
                if deg > max_degree {
                    return Err(bad("degree exceeds max_degree"));
                }
                let raw = cur.take(deg * 4)?;
                list.clear();
                for c in raw.chunks_exact(4) {
                    let nb = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                    if nb as usize >= n {
                        return Err(bad("neighbor id out of range"));
                    }
                    list.push(nb);
                }
                adj.set_neighbors(i as u32, &list);
            }
            adj
        };
        Ok(VamanaGraph {
            adj,
            medoid,
            params,
            sim,
            build_seconds,
        })
    }

    /// Beam search for a prepared query over `store`. Returns candidates
    /// best-first (up to `window`). Equivalent to
    /// [`VamanaGraph::search_filtered`] with `capacity == window` and no
    /// filter.
    pub fn search<'c>(
        &self,
        ctx: &'c mut SearchCtx,
        store: &dyn ScoreStore,
        pq: &crate::quant::PreparedQuery,
        window: usize,
    ) -> &'c [crate::graph::beam::Candidate] {
        self.search_filtered(ctx, store, pq, window, window, None)
    }

    /// [`VamanaGraph::search`] with the split-buffer and filter
    /// extensions: retain up to `capacity >= window` candidates for
    /// re-ranking, and — when `filter` is set — navigate through
    /// filtered-out nodes while returning only passing candidates (see
    /// [`crate::graph::beam::greedy_search_ext`]).
    pub fn search_filtered<'c>(
        &self,
        ctx: &'c mut SearchCtx,
        store: &dyn ScoreStore,
        pq: &crate::quant::PreparedQuery,
        window: usize,
        capacity: usize,
        filter: Option<&(dyn Fn(u32) -> bool + Sync)>,
    ) -> &'c [crate::graph::beam::Candidate] {
        ctx.ensure(self.adj.len_nodes());
        greedy_search_prefetch(
            ctx,
            &[self.medoid],
            window,
            capacity,
            filter,
            |ids: &[u32], out: &mut Vec<f32>| store.score_block(pq, ids, out),
            |id, out| {
                out.clear();
                out.extend_from_slice(self.adj.neighbors(id));
            },
            // next-hop hint: pull the likely next node's adjacency row
            // and its neighbors' code rows toward the caches while the
            // current hop's block scores (cold-page/cold-line overlap
            // for mmap-served indexes)
            |next| {
                let nbrs = self.adj.neighbors(next);
                crate::simd::prefetch_row(nbrs);
                store.prefetch_rows(nbrs);
            },
        )
    }
}

/// Candidate record used during pruning.
struct PruneCand {
    id: u32,
    /// squared L2 distance to the node being pruned
    dist_to_p: f32,
    vec: Vec<f32>,
    alive: bool,
}

/// α-slack robust prune (DiskANN convention, squared distances):
/// greedily keep the closest candidate, drop everything it "covers":
/// `s` covers `c` when `alpha_l2 * d(s, c) <= d(p, c)`. Keeps at most
/// `max_degree` ids.
///
/// Pruning geometry is always Euclidean on the decoded vectors — for
/// MIPS the navigation scores stay inner-product, but edge
/// diversification over a *proximity* structure is the robust choice
/// (the paper's alpha = 0.95 for IP expresses the same slack; we map it
/// to the equivalent L2 slack 1/alpha). Free-standing so the batch
/// builder and the live-mutation path ([`crate::mutate`]) share one
/// copy of the rule.
pub fn robust_prune(
    store: &dyn ScoreStore,
    p: u32,
    p_vec: &[f32],
    pool: &[u32],
    alpha: f32,
    max_degree: usize,
) -> Vec<u32> {
    let alpha_l2 = if alpha >= 1.0 { alpha } else { 1.0 / alpha };
    let mut cands: Vec<PruneCand> = pool
        .iter()
        .filter(|&&id| id != p)
        .map(|&id| {
            let vec = store.decode(id);
            PruneCand {
                id,
                dist_to_p: l2_sq(p_vec, &vec),
                vec,
                alive: true,
            }
        })
        .collect();
    // total_cmp: identical ordering for the (non-negative, finite)
    // squared distances the builder produces, but a NaN smuggled in by
    // runtime input must never panic the live ingest lane
    cands.sort_by(|a, b| a.dist_to_p.total_cmp(&b.dist_to_p));

    let mut out: Vec<u32> = Vec::with_capacity(max_degree);
    for i in 0..cands.len() {
        if !cands[i].alive {
            continue;
        }
        out.push(cands[i].id);
        if out.len() >= max_degree {
            break;
        }
        // deactivate covered candidates
        let (head, tail) = cands.split_at_mut(i + 1);
        let s = &head[i];
        for c in tail.iter_mut().filter(|c| c.alive) {
            if alpha_l2 * l2_sq(&s.vec, &c.vec) <= c.dist_to_p {
                c.alive = false;
            }
        }
    }
    out
}

/// Medoid of `store`: the stored vector most similar to the (sampled)
/// dataset centroid — the graph's search entry point. Shared by the
/// builder and by tombstone consolidation (which must re-anchor the
/// entry point after compaction). Returns 0 for an empty store.
pub fn medoid_of(store: &dyn ScoreStore) -> u32 {
    let n = store.len();
    if n == 0 {
        return 0;
    }
    let dim = store.dim();
    let mut mean = vec![0.0f64; dim];
    // sample up to 2048 vectors for the centroid
    let step = (n / 2048).max(1);
    let mut count = 0usize;
    let mut i = 0usize;
    while i < n {
        let v = store.decode(i as u32);
        for (m, &x) in mean.iter_mut().zip(v.iter()) {
            *m += x as f64;
        }
        count += 1;
        i += step;
    }
    let mean_f32: Vec<f32> = mean.iter().map(|&m| (m / count as f64) as f32).collect();
    let pq = store.prepare(&mean_f32, Similarity::L2);
    let mut best = (0u32, f32::NEG_INFINITY);
    i = 0;
    while i < n {
        let s = store.score(&pq, i as u32);
        if s > best.1 {
            best = (i as u32, s);
        }
        i += step;
    }
    best.0
}

/// Vamana builder.
pub struct VamanaBuilder {
    pub params: GraphParams,
    pub sim: Similarity,
    pub seed: u64,
    /// construction worker threads; 1 = the serial reference build
    /// (bit-for-bit reproducible), >1 = batch-synchronous rounds
    /// (deterministic for any thread count, but a different graph than
    /// the serial schedule — see `config::BuildParams`)
    pub threads: usize,
}

impl VamanaBuilder {
    pub fn new(params: GraphParams, sim: Similarity) -> VamanaBuilder {
        VamanaBuilder {
            params,
            sim,
            seed: 0x5EED,
            threads: 1,
        }
    }

    /// Set the construction worker count (0 = all cores).
    pub fn with_threads(mut self, threads: usize) -> VamanaBuilder {
        self.threads = resolve_threads(threads);
        self
    }

    /// Build the graph over the vectors in `store`.
    pub fn build(&self, store: &dyn ScoreStore) -> VamanaGraph {
        let t0 = std::time::Instant::now();
        let n = store.len();
        assert!(n > 0, "cannot build an empty graph");
        let r = self.params.max_degree.min(n - 1);
        let mut adj = Adjacency::new(n, self.params.max_degree);
        let mut rng = crate::util::rng::Rng::new(self.seed);

        // --- random initial graph (R/2 out-edges per node)
        let init_deg = (r / 2).max(1).min(n - 1);
        for i in 0..n {
            let mut picked = Vec::with_capacity(init_deg);
            while picked.len() < init_deg {
                let j = rng.below(n) as u32;
                if j as usize != i && !picked.contains(&j) {
                    picked.push(j);
                }
            }
            adj.set_neighbors(i as u32, &picked);
        }

        let medoid = self.find_medoid(store);
        let mut order: Vec<u32> = (0..n as u32).collect();

        // --- two passes: relaxed alpha then target alpha (DiskANN recipe)
        let alphas = match self.sim {
            Similarity::L2 | Similarity::Cosine => vec![1.0f32, self.params.alpha],
            Similarity::InnerProduct => vec![1.0f32, self.params.alpha],
        };
        // resolve here too so `threads: 0` set directly on the struct
        // means "all cores", matching every other threads knob
        if resolve_threads(self.threads) <= 1 {
            let mut ctx = SearchCtx::new(n);
            for &alpha in &alphas {
                rng.shuffle(&mut order);
                for &node in &order {
                    self.insert_node(store, &mut adj, &mut ctx, medoid, node, alpha);
                }
            }
        } else {
            self.insert_all_parallel(store, &mut adj, medoid, &mut rng, &mut order, &alphas);
        }

        VamanaGraph {
            adj,
            medoid,
            params: self.params,
            sim: self.sim,
            build_seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// Batch-synchronous parallel insertion (mirrors the round-based
    /// schedule intel/ScalableVectorSearch uses): nodes are inserted in
    /// fixed-size rounds; within a round every node runs its greedy
    /// search + robust prune concurrently against a *frozen* adjacency
    /// snapshot with a per-thread [`SearchCtx`], then the edge updates
    /// (forward lists + reverse edges with overflow re-prune) are
    /// applied serially in round order. Results are deterministic for a
    /// fixed round size no matter how many workers run the searches.
    fn insert_all_parallel(
        &self,
        store: &dyn ScoreStore,
        adj: &mut Adjacency,
        medoid: u32,
        rng: &mut crate::util::rng::Rng,
        order: &mut [u32],
        alphas: &[f32],
    ) {
        let n = store.len();
        let threads = resolve_threads(self.threads);
        let pool = CtxPool::new(threads, n);
        for &alpha in alphas {
            rng.shuffle(order);
            for round in order.chunks(PARALLEL_ROUND) {
                // (1) parallel: search the frozen snapshot + robust prune
                let selections: Vec<Vec<u32>> = {
                    let adj_snapshot: &Adjacency = adj;
                    parallel_map(round.len(), threads, |j| {
                        let node = round[j];
                        let node_vec = store.decode(node);
                        let pq = store.prepare(&node_vec, self.sim);
                        let mut ctx = pool.acquire();
                        let results = greedy_search_ext(
                            &mut *ctx,
                            &[medoid],
                            self.params.build_window,
                            self.params.build_window,
                            None,
                            |ids: &[u32], out: &mut Vec<f32>| store.score_block(&pq, ids, out),
                            |id, out| {
                                out.clear();
                                out.extend_from_slice(adj_snapshot.neighbors(id));
                            },
                        );
                        let mut ids: Vec<u32> = results.iter().map(|c| c.id).collect();
                        ids.extend_from_slice(adj_snapshot.neighbors(node));
                        ids.sort_unstable();
                        ids.dedup();
                        ids.retain(|&id| id != node);
                        self.robust_prune(store, node, &node_vec, &ids, alpha)
                    })
                };
                // (2) serial: apply edge updates in round order. A
                // node's selection came from the frozen snapshot, so it
                // cannot contain reverse edges gained from round-mates
                // applied earlier in this round — fold those in (the
                // serial schedule keeps them by putting the node's live
                // neighbor list into the prune pool), re-pruning only
                // when the union overflows the degree bound.
                let pre_round: Vec<Vec<u32>> = round
                    .iter()
                    .map(|&nd| adj.neighbors(nd).to_vec())
                    .collect();
                for (j, mut selected) in selections.into_iter().enumerate() {
                    let node = round[j];
                    for &nb in adj.neighbors(node) {
                        if nb != node
                            && !pre_round[j].contains(&nb)
                            && !selected.contains(&nb)
                        {
                            selected.push(nb);
                        }
                    }
                    if selected.len() > self.params.max_degree {
                        let node_vec = store.decode(node);
                        selected =
                            self.robust_prune(store, node, &node_vec, &selected, alpha);
                    }
                    self.apply_insertion(store, adj, node, &selected, alpha);
                }
            }
        }
    }

    /// One Vamana insertion round for `node`.
    fn insert_node(
        &self,
        store: &dyn ScoreStore,
        adj: &mut Adjacency,
        ctx: &mut SearchCtx,
        medoid: u32,
        node: u32,
        alpha: f32,
    ) {
        let node_vec = store.decode(node);
        let pq = store.prepare(&node_vec, self.sim);
        // search the current graph with the node itself as query
        let window = self.params.build_window;
        let results = greedy_search_ext(
            ctx,
            &[medoid],
            window,
            window,
            None,
            |ids: &[u32], out: &mut Vec<f32>| store.score_block(&pq, ids, out),
            |id, out| {
                out.clear();
                out.extend_from_slice(adj.neighbors(id));
            },
        );
        // candidate pool = search results + current out-neighbors
        let mut ids: Vec<u32> = results.iter().map(|c| c.id).collect();
        ids.extend_from_slice(adj.neighbors(node));
        ids.sort_unstable();
        ids.dedup();
        ids.retain(|&id| id != node);

        let selected = self.robust_prune(store, node, &node_vec, &ids, alpha);
        self.apply_insertion(store, adj, node, &selected, alpha);
    }

    /// Install `node`'s pruned out-list and its reverse edges (with the
    /// overflow re-prune). Shared verbatim by the serial and parallel
    /// schedules so `threads = 1` and `threads > 1` differ only in how
    /// candidate pools are computed, never in how edges are applied.
    fn apply_insertion(
        &self,
        store: &dyn ScoreStore,
        adj: &mut Adjacency,
        node: u32,
        selected: &[u32],
        alpha: f32,
    ) {
        adj.set_neighbors(node, selected);

        // reverse edges
        for &nb in selected {
            if adj.degree(nb) < adj.max_degree() {
                if !adj.neighbors(nb).contains(&node) {
                    adj.push_neighbor(nb, node);
                }
            } else {
                // overflow: re-prune nb's list including the new edge
                let nb_vec = store.decode(nb);
                let mut pool: Vec<u32> = adj.neighbors(nb).to_vec();
                if !pool.contains(&node) {
                    pool.push(node);
                }
                let pruned = self.robust_prune(store, nb, &nb_vec, &pool, alpha);
                adj.set_neighbors(nb, &pruned);
            }
        }
    }

    /// [`robust_prune`] at this builder's degree bound.
    fn robust_prune(
        &self,
        store: &dyn ScoreStore,
        p: u32,
        p_vec: &[f32],
        pool: &[u32],
        alpha: f32,
    ) -> Vec<u32> {
        robust_prune(store, p, p_vec, pool, alpha, self.params.max_degree)
    }

    /// Medoid: the stored vector most similar to the dataset centroid.
    fn find_medoid(&self, store: &dyn ScoreStore) -> u32 {
        medoid_of(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::dot;
    use crate::quant::F32Store;
    use crate::util::rng::Rng;

    fn clustered_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        // a few well-separated Gaussian blobs — easy recall target
        let mut rng = Rng::new(seed);
        let k = 5;
        let centers: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..d).map(|_| rng.gaussian_f32() * 4.0).collect())
            .collect();
        (0..n)
            .map(|i| {
                let c = &centers[i % k];
                c.iter().map(|&x| x + rng.gaussian_f32() * 0.3).collect()
            })
            .collect()
    }

    fn build_graph(rows: &[Vec<f32>], sim: Similarity) -> (VamanaGraph, F32Store) {
        let store = F32Store::from_rows(rows);
        let mut params = GraphParams::for_similarity(sim);
        params.max_degree = 16;
        params.build_window = 32;
        let g = VamanaBuilder::new(params, sim).build(&store);
        (g, store)
    }

    fn brute_force_topk(rows: &[Vec<f32>], q: &[f32], k: usize, sim: Similarity) -> Vec<u32> {
        let mut ids: Vec<u32> = (0..rows.len() as u32).collect();
        ids.sort_by(|&a, &b| {
            let (sa, sb) = match sim {
                Similarity::L2 => (
                    -l2_sq(q, &rows[a as usize]),
                    -l2_sq(q, &rows[b as usize]),
                ),
                _ => (dot(q, &rows[a as usize]), dot(q, &rows[b as usize])),
            };
            sb.partial_cmp(&sa).unwrap()
        });
        ids.truncate(k);
        ids
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn adjacency_basics() {
        let mut adj = Adjacency::new(4, 3);
        adj.set_neighbors(0, &[1, 2, 3]);
        assert_eq!(adj.neighbors(0), &[1, 2, 3]);
        assert_eq!(adj.degree(0), 3);
        assert!(!adj.push_neighbor(0, 2));
        assert!(adj.push_neighbor(1, 0));
        assert_eq!(adj.neighbors(1), &[0]);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn degrees_bounded_by_r() {
        let rows = clustered_rows(300, 8, 1);
        let (g, _) = build_graph(&rows, Similarity::L2);
        for i in 0..300u32 {
            assert!(g.adj.degree(i) <= g.adj.max_degree());
        }
        assert!(g.adj.avg_degree() >= 2.0, "{}", g.adj.avg_degree());
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn high_recall_l2() {
        let rows = clustered_rows(400, 8, 2);
        let (g, store) = build_graph(&rows, Similarity::L2);
        let mut rng = Rng::new(99);
        let mut ctx = SearchCtx::new(400);
        let mut hits = 0usize;
        let trials = 40;
        for _ in 0..trials {
            let q: Vec<f32> = rows[rng.below(400)]
                .iter()
                .map(|&x| x + rng.gaussian_f32() * 0.05)
                .collect();
            let truth = brute_force_topk(&rows, &q, 10, Similarity::L2);
            let pq = store.prepare(&q, Similarity::L2);
            let res = g.search(&mut ctx, &store, &pq, 40);
            let got: Vec<u32> = res.iter().take(10).map(|c| c.id).collect();
            hits += truth.iter().filter(|t| got.contains(t)).count();
        }
        let recall = hits as f64 / (10 * trials) as f64;
        assert!(recall >= 0.9, "recall@10 = {recall}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn high_recall_inner_product() {
        let rows = clustered_rows(400, 8, 3);
        let (g, store) = build_graph(&rows, Similarity::InnerProduct);
        let mut rng = Rng::new(77);
        let mut ctx = SearchCtx::new(400);
        let mut hits = 0usize;
        let trials = 40;
        for _ in 0..trials {
            let q: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            let truth = brute_force_topk(&rows, &q, 10, Similarity::InnerProduct);
            let pq = store.prepare(&q, Similarity::InnerProduct);
            let res = g.search(&mut ctx, &store, &pq, 40);
            let got: Vec<u32> = res.iter().take(10).map(|c| c.id).collect();
            hits += truth.iter().filter(|t| got.contains(t)).count();
        }
        let recall = hits as f64 / (10 * trials) as f64;
        assert!(recall >= 0.85, "recall@10 = {recall}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn no_self_loops() {
        let rows = clustered_rows(200, 6, 4);
        let (g, _) = build_graph(&rows, Similarity::L2);
        for i in 0..200u32 {
            assert!(!g.adj.neighbors(i).contains(&i), "self loop at {i}");
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn build_records_time() {
        let rows = clustered_rows(100, 6, 5);
        let (g, _) = build_graph(&rows, Similarity::L2);
        assert!(g.build_seconds > 0.0);
    }

    fn adjacency_lists(g: &VamanaGraph) -> Vec<Vec<u32>> {
        (0..g.adj.len_nodes() as u32)
            .map(|i| g.adj.neighbors(i).to_vec())
            .collect()
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn parallel_build_is_deterministic_and_thread_count_independent() {
        let rows = clustered_rows(500, 8, 21);
        let store = F32Store::from_rows(&rows);
        let mut params = GraphParams::for_similarity(Similarity::L2);
        params.max_degree = 16;
        params.build_window = 32;
        let build = |threads: usize| {
            VamanaBuilder::new(params, Similarity::L2)
                .with_threads(threads)
                .build(&store)
        };
        let a = build(2);
        let b = build(2);
        let c = build(4);
        assert_eq!(adjacency_lists(&a), adjacency_lists(&b), "repeat run differs");
        assert_eq!(
            adjacency_lists(&a),
            adjacency_lists(&c),
            "graph depends on thread count"
        );
        assert_eq!(a.medoid, c.medoid);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn parallel_build_invariants_hold() {
        let rows = clustered_rows(400, 8, 22);
        let store = F32Store::from_rows(&rows);
        let mut params = GraphParams::for_similarity(Similarity::L2);
        params.max_degree = 16;
        params.build_window = 32;
        let g = VamanaBuilder::new(params, Similarity::L2)
            .with_threads(4)
            .build(&store);
        for i in 0..400u32 {
            let nbrs = g.adj.neighbors(i);
            assert!(nbrs.len() <= params.max_degree);
            assert!(!nbrs.contains(&i), "self loop at {i}");
            let set: std::collections::HashSet<_> = nbrs.iter().collect();
            assert_eq!(set.len(), nbrs.len(), "duplicate edge at {i}");
        }
        assert!(g.adj.avg_degree() >= 2.0);
        // reverse edges gained within a round must survive the round's
        // own set_neighbors applications: almost every node keeps at
        // least one in-edge
        let mut in_deg = vec![0usize; 400];
        for i in 0..400u32 {
            for &nb in g.adj.neighbors(i) {
                in_deg[nb as usize] += 1;
            }
        }
        let orphaned = in_deg.iter().filter(|&&d| d == 0).count();
        assert!(orphaned < 40, "{orphaned}/400 nodes have no in-edges");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn parallel_build_recall_matches_serial() {
        let rows = clustered_rows(500, 8, 23);
        let store = F32Store::from_rows(&rows);
        let mut params = GraphParams::for_similarity(Similarity::L2);
        params.max_degree = 16;
        params.build_window = 32;
        let serial = VamanaBuilder::new(params, Similarity::L2).build(&store);
        let parallel = VamanaBuilder::new(params, Similarity::L2)
            .with_threads(4)
            .build(&store);
        let mut ctx = SearchCtx::new(500);
        let recall = |g: &VamanaGraph, ctx: &mut SearchCtx| {
            let trials = 40;
            let mut hits = 0usize;
            for t in 0..trials {
                // per-trial rng so both graphs see identical queries
                let mut probe_rng = Rng::new(900 + t as u64);
                let q: Vec<f32> = rows[probe_rng.below(500)]
                    .iter()
                    .map(|&x| x + probe_rng.gaussian_f32() * 0.05)
                    .collect();
                let truth = brute_force_topk(&rows, &q, 10, Similarity::L2);
                let pq = store.prepare(&q, Similarity::L2);
                let res = g.search(ctx, &store, &pq, 40);
                let got: Vec<u32> = res.iter().take(10).map(|c| c.id).collect();
                hits += truth.iter().filter(|t| got.contains(t)).count();
            }
            hits as f64 / (10 * trials) as f64
        };
        let r_serial = recall(&serial, &mut ctx);
        let r_parallel = recall(&parallel, &mut ctx);
        assert!(
            r_parallel >= r_serial - 0.03,
            "parallel recall {r_parallel} vs serial {r_serial}"
        );
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn threads_one_reproduces_serial_build_exactly() {
        let rows = clustered_rows(300, 8, 24);
        let store = F32Store::from_rows(&rows);
        let mut params = GraphParams::for_similarity(Similarity::L2);
        params.max_degree = 16;
        params.build_window = 32;
        let a = VamanaBuilder::new(params, Similarity::L2).build(&store);
        let b = VamanaBuilder::new(params, Similarity::L2)
            .with_threads(1)
            .build(&store);
        assert_eq!(adjacency_lists(&a), adjacency_lists(&b));
        assert_eq!(a.medoid, b.medoid);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn graph_write_read_roundtrip() {
        let rows = clustered_rows(250, 8, 31);
        let (g, _) = build_graph(&rows, Similarity::L2);
        let mut buf = Vec::new();
        g.write_bytes(&mut buf);
        let mut cur = crate::data::io::bin::Cursor::new(&buf);
        let back = VamanaGraph::read_bytes(&mut cur).unwrap();
        assert_eq!(cur.remaining(), 0);
        assert_eq!(back.medoid, g.medoid);
        assert_eq!(back.sim, g.sim);
        assert_eq!(back.params.max_degree, g.params.max_degree);
        assert_eq!(back.params.build_window, g.params.build_window);
        assert_eq!(back.params.alpha, g.params.alpha);
        assert_eq!(back.adj.max_degree(), g.adj.max_degree());
        assert_eq!(adjacency_lists(&back), adjacency_lists(&g));
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn graph_read_rejects_corruption() {
        let rows = clustered_rows(100, 6, 32);
        let (g, _) = build_graph(&rows, Similarity::L2);
        let mut buf = Vec::new();
        g.write_bytes(&mut buf);
        for cut in [0usize, 8, 20, buf.len() / 2, buf.len() - 1] {
            let mut cur = crate::data::io::bin::Cursor::new(&buf[..cut]);
            assert!(VamanaGraph::read_bytes(&mut cur).is_err(), "cut {cut}");
        }
        // bogus similarity code
        let mut bad = buf.clone();
        bad[8 + 4 + 4 + 4 + 4] = 0xFF; // n(u64) + max_deg + params.max_deg + window + alpha
        let mut cur = crate::data::io::bin::Cursor::new(&bad);
        assert!(VamanaGraph::read_bytes(&mut cur).is_err());
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn medoid_is_central() {
        // one tight blob: the medoid must be near the mean
        let mut rng = Rng::new(6);
        let rows: Vec<Vec<f32>> = (0..100)
            .map(|_| (0..4).map(|_| 5.0 + rng.gaussian_f32() * 0.1).collect())
            .collect();
        let store = F32Store::from_rows(&rows);
        let params = GraphParams::for_similarity(Similarity::L2);
        let b = VamanaBuilder::new(params, Similarity::L2);
        let m = b.find_medoid(&store);
        let v = &rows[m as usize];
        // medoid vector close to (5, 5, 5, 5)
        for &x in v {
            assert!((x - 5.0).abs() < 0.5);
        }
    }
}
