//! HNSW baseline (Malkov & Yashunin, 2018) — the HNSWlib stand-in for
//! the Fig. 7/8 comparisons. Layered navigable-small-world graph with
//! exponentially sampled levels, greedy descent through the upper
//! layers and beam search at layer 0.

use crate::config::Similarity;
use crate::graph::beam::{greedy_search_ext, SearchCtx};
use crate::quant::ScoreStore;
use crate::util::rng::Rng;

pub struct HnswParams {
    /// max neighbors per node at layers > 0 (layer 0 gets 2M)
    pub m: usize,
    /// construction beam width
    pub ef_construction: usize,
}

impl Default for HnswParams {
    fn default() -> Self {
        HnswParams {
            m: 16,
            ef_construction: 100,
        }
    }
}

pub struct HnswGraph {
    /// layers[l][node] = neighbor list; layer 0 covers all nodes
    layers: Vec<Vec<Vec<u32>>>,
    /// highest layer per node
    node_level: Vec<u8>,
    entry: u32,
    pub sim: Similarity,
    pub build_seconds: f64,
}

impl HnswGraph {
    /// Insert-at-a-time construction over `store`.
    pub fn build(store: &dyn ScoreStore, params: &HnswParams, sim: Similarity, seed: u64) -> HnswGraph {
        let t0 = std::time::Instant::now();
        let n = store.len();
        assert!(n > 0);
        let mut rng = Rng::new(seed);
        let ml = 1.0 / (params.m as f64).ln();
        let max_level_cap = 16usize;

        let mut node_level = vec![0u8; n];
        for lvl in node_level.iter_mut() {
            let u = rng.next_f64().max(1e-12);
            *lvl = ((-u.ln() * ml).floor() as usize).min(max_level_cap) as u8;
        }
        let top = node_level.iter().copied().max().unwrap_or(0) as usize;
        let mut layers: Vec<Vec<Vec<u32>>> = (0..=top)
            .map(|_| vec![Vec::new(); n])
            .collect();
        let mut entry = 0u32;
        let mut entry_level = node_level[0] as usize;
        let mut ctx = SearchCtx::new(n);

        for node in 0..n as u32 {
            if node == 0 {
                continue; // first node is the initial entry point
            }
            let vec = store.decode(node);
            let pq = store.prepare(&vec, sim);
            let lvl = node_level[node as usize] as usize;

            // greedy descent from the top entry to lvl+1
            let mut ep = entry;
            for l in (lvl + 1..=entry_level).rev() {
                ep = Self::greedy_layer(store, &layers[l], &pq, ep);
            }
            // insert at layers min(lvl, entry_level)..0
            for l in (0..=lvl.min(entry_level)).rev() {
                let max_deg = if l == 0 { params.m * 2 } else { params.m };
                let res = greedy_search_ext(
                    &mut ctx,
                    &[ep],
                    params.ef_construction,
                    params.ef_construction,
                    None,
                    |ids: &[u32], out: &mut Vec<f32>| store.score_block(&pq, ids, out),
                    |id, out| {
                        out.clear();
                        out.extend_from_slice(&layers[l][id as usize]);
                    },
                );
                // Algorithm-4 neighbor-selection heuristic: take e only
                // if it is closer to the new node than to every already
                // selected neighbor (diversified edges).
                let cand_ids: Vec<u32> =
                    res.iter().map(|c| c.id).filter(|&id| id != node).collect();
                let selected =
                    Self::select_neighbors_heuristic(store, sim, &vec, &cand_ids, max_deg);
                if let Some(&first) = selected.first() {
                    ep = first;
                }
                for &nb in &selected {
                    layers[l][node as usize].push(nb);
                    let nb_list = &mut layers[l][nb as usize];
                    nb_list.push(node);
                    if nb_list.len() > max_deg {
                        // shrink nb's list with the same diversification
                        let nb_vec = store.decode(nb);
                        let pool = nb_list.clone();
                        *nb_list = Self::select_neighbors_heuristic(
                            store, sim, &nb_vec, &pool, max_deg,
                        );
                    }
                }
            }
            if lvl > entry_level {
                entry = node;
                entry_level = lvl;
            }
        }

        HnswGraph {
            layers,
            node_level,
            entry,
            sim,
            build_seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// HNSW Algorithm 4: greedy diversified neighbor selection in
    /// Euclidean geometry on decoded vectors (see the Vamana prune for
    /// why geometry-based diversification is used for MIPS too).
    fn select_neighbors_heuristic(
        store: &dyn ScoreStore,
        _sim: Similarity,
        base: &[f32],
        pool: &[u32],
        max_deg: usize,
    ) -> Vec<u32> {
        use crate::linalg::matrix::l2_sq;
        let mut cands: Vec<(f32, u32, Vec<f32>)> = pool
            .iter()
            .map(|&id| {
                let v = store.decode(id);
                (l2_sq(base, &v), id, v)
            })
            .collect();
        cands.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut out: Vec<(u32, Vec<f32>)> = Vec::with_capacity(max_deg);
        let mut pruned: Vec<u32> = Vec::new();
        for (d_base, id, v) in cands {
            if out.len() >= max_deg {
                break;
            }
            let diverse = out.iter().all(|(_, s)| l2_sq(s, &v) >= d_base);
            if diverse {
                out.push((id, v));
            } else {
                pruned.push(id);
            }
        }
        let mut ids: Vec<u32> = out.into_iter().map(|(id, _)| id).collect();
        // keepPrunedConnections: refill remaining slots from the pruned
        // pool (closest first) so nodes keep full degree/connectivity
        for id in pruned {
            if ids.len() >= max_deg {
                break;
            }
            ids.push(id);
        }
        ids
    }

    fn greedy_layer(
        store: &dyn ScoreStore,
        layer: &[Vec<u32>],
        pq: &crate::quant::PreparedQuery,
        start: u32,
    ) -> u32 {
        let mut cur = start;
        let mut cur_score = store.score(pq, cur);
        loop {
            let mut improved = false;
            for &nb in &layer[cur as usize] {
                let s = store.score(pq, nb);
                if s > cur_score {
                    cur = nb;
                    cur_score = s;
                    improved = true;
                }
            }
            if !improved {
                return cur;
            }
        }
    }

    /// Search: greedy descent through upper layers, beam at layer 0.
    pub fn search<'c>(
        &self,
        ctx: &'c mut SearchCtx,
        store: &dyn ScoreStore,
        pq: &crate::quant::PreparedQuery,
        ef: usize,
    ) -> &'c [crate::graph::beam::Candidate] {
        self.search_filtered(ctx, store, pq, ef, None)
    }

    /// [`HnswGraph::search`] with a filter predicate pushed into the
    /// layer-0 beam: the upper-layer descent (pure navigation) ignores
    /// the filter, while layer 0 routes through filtered-out nodes but
    /// returns only passing candidates.
    pub fn search_filtered<'c>(
        &self,
        ctx: &'c mut SearchCtx,
        store: &dyn ScoreStore,
        pq: &crate::quant::PreparedQuery,
        ef: usize,
        filter: Option<&(dyn Fn(u32) -> bool + Sync)>,
    ) -> &'c [crate::graph::beam::Candidate] {
        ctx.ensure(store.len());
        let mut ep = self.entry;
        for l in (1..self.layers.len()).rev() {
            ep = Self::greedy_layer(store, &self.layers[l], pq, ep);
        }
        greedy_search_ext(
            ctx,
            &[ep],
            ef,
            ef,
            filter,
            |ids: &[u32], out: &mut Vec<f32>| store.score_block(pq, ids, out),
            |id, out| {
                out.clear();
                out.extend_from_slice(&self.layers[0][id as usize]);
            },
        )
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn max_level_of(&self, node: u32) -> usize {
        self.node_level[node as usize] as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::{dot, l2_sq};
    use crate::quant::F32Store;

    fn clustered_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..d).map(|_| rng.gaussian_f32() * 4.0).collect())
            .collect();
        (0..n)
            .map(|i| {
                centers[i % 5]
                    .iter()
                    .map(|&x| x + rng.gaussian_f32() * 0.3)
                    .collect()
            })
            .collect()
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn builds_with_multiple_layers() {
        let rows = clustered_rows(500, 8, 1);
        let store = F32Store::from_rows(&rows);
        let g = HnswGraph::build(&store, &HnswParams::default(), Similarity::L2, 1);
        assert!(g.num_layers() >= 2, "{}", g.num_layers());
        assert!(g.build_seconds > 0.0);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn recall_l2() {
        let rows = clustered_rows(400, 8, 2);
        let store = F32Store::from_rows(&rows);
        let g = HnswGraph::build(&store, &HnswParams::default(), Similarity::L2, 2);
        let mut rng = Rng::new(50);
        let mut ctx = SearchCtx::new(400);
        let mut hits = 0;
        let trials = 30;
        for _ in 0..trials {
            let q: Vec<f32> = rows[rng.below(400)]
                .iter()
                .map(|&x| x + rng.gaussian_f32() * 0.05)
                .collect();
            let mut truth: Vec<u32> = (0..400u32).collect();
            truth.sort_by(|&a, &b| {
                l2_sq(&q, &rows[a as usize])
                    .partial_cmp(&l2_sq(&q, &rows[b as usize]))
                    .unwrap()
            });
            let pq = store.prepare(&q, Similarity::L2);
            let res = g.search(&mut ctx, &store, &pq, 50);
            let got: Vec<u32> = res.iter().take(10).map(|c| c.id).collect();
            hits += truth[..10].iter().filter(|t| got.contains(t)).count();
        }
        let recall = hits as f64 / (10 * trials) as f64;
        assert!(recall >= 0.85, "recall@10 = {recall}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn recall_ip() {
        let rows = clustered_rows(300, 8, 3);
        let store = F32Store::from_rows(&rows);
        let g = HnswGraph::build(&store, &HnswParams::default(), Similarity::InnerProduct, 3);
        let mut rng = Rng::new(51);
        let mut ctx = SearchCtx::new(300);
        let mut hits = 0;
        let trials = 30;
        for _ in 0..trials {
            let q: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            let mut truth: Vec<u32> = (0..300u32).collect();
            truth.sort_by(|&a, &b| {
                dot(&q, &rows[b as usize])
                    .partial_cmp(&dot(&q, &rows[a as usize]))
                    .unwrap()
            });
            let pq = store.prepare(&q, Similarity::InnerProduct);
            let res = g.search(&mut ctx, &store, &pq, 50);
            let got: Vec<u32> = res.iter().take(10).map(|c| c.id).collect();
            hits += truth[..10].iter().filter(|t| got.contains(t)).count();
        }
        let recall = hits as f64 / (10 * trials) as f64;
        assert!(recall >= 0.8, "recall@10 = {recall}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn node_levels_mostly_zero() {
        let rows = clustered_rows(1000, 4, 4);
        let store = F32Store::from_rows(&rows);
        let g = HnswGraph::build(&store, &HnswParams::default(), Similarity::L2, 5);
        let zeros = (0..1000u32).filter(|&i| g.max_level_of(i) == 0).count();
        assert!(zeros > 800, "{zeros} of 1000 at level 0");
    }
}
