//! Graph indices: the Vamana graph (Jayaram Subramanya et al., 2019)
//! used by LeanVec/SVS, a greedy best-first search with backtracking
//! (Fu et al., 2019), and an HNSW baseline (Malkov & Yashunin, 2018).

pub mod beam;
pub mod hnsw;
pub mod vamana;

pub use beam::{CtxPool, PooledCtx, SearchCtx, SearchStats};
pub use vamana::{medoid_of, robust_prune, Adjacency, VamanaBuilder, VamanaGraph};
