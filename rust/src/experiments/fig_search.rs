//! Search-performance experiments: figs 1, 4, 5, 6, 7, 8, 9, 10, 14.

use super::harness::{
    build_arm, curve_json, dataset_truth, default_windows, print_table, qps_at_recall,
    qps_recall_curve, standard_arms, Arm, ExpContext,
};
use crate::config::{Compression, ProjectionKind, Similarity};
use crate::data::synth::{paper_datasets, paper_target_dim, Dataset, SynthSpec};
use crate::graph::vamana::VamanaBuilder;
use crate::index::builder::build_hnsw_baseline;
use crate::index::ivfpq::{IvfPqIndex, IvfPqParams};
use crate::index::leanvec_index::make_store;
use crate::util::json::Json;

const K: usize = 10;
const TARGET_RECALL: f64 = 0.9;

fn spec_by_name(ctx: &ExpContext, name: &str) -> SynthSpec {
    paper_datasets(ctx.scale)
        .into_iter()
        .find(|s| s.name == name)
        .expect("known dataset")
}

fn curves_for_arms(
    ds: &Dataset,
    arms: &[Arm],
    truth: &[Vec<u32>],
) -> Vec<(String, Vec<super::harness::CurvePoint>)> {
    let windows = default_windows(K);
    arms.iter()
        .map(|arm| {
            (
                arm.name.clone(),
                qps_recall_curve(&arm.index, &ds.test_queries, truth, K, &windows),
            )
        })
        .collect()
}

fn report_curves(
    ctx: &ExpContext,
    exp: &str,
    dataset: &str,
    curves: &[(String, Vec<super::harness::CurvePoint>)],
    extra: Vec<(&str, Json)>,
) -> anyhow::Result<()> {
    let mut rows = Vec::new();
    for (name, curve) in curves {
        let q90 = qps_at_recall(curve, TARGET_RECALL);
        let best = curve.last().map(|p| p.recall).unwrap_or(0.0);
        rows.push(vec![
            name.clone(),
            q90.map(|q| format!("{q:.0}")).unwrap_or("-".into()),
            format!("{best:.3}"),
            format!(
                "{:.0}",
                curve.first().map(|p| p.bytes_per_query).unwrap_or(0.0)
            ),
        ]);
    }
    println!("\n[{exp}] dataset {dataset} (k={K}, target recall {TARGET_RECALL}):");
    print_table(
        &["method", "QPS@0.9", "max recall", "bytes/query@w=k"],
        &rows,
    );
    let mut obj = vec![
        ("dataset", Json::str(dataset)),
        (
            "curves",
            Json::obj(
                curves
                    .iter()
                    .map(|(n, c)| (n.as_str(), curve_json(c)))
                    .collect(),
            ),
        ),
    ];
    obj.extend(extra);
    ctx.save(&format!("{exp}_{dataset}"), &Json::obj(obj))
}

/// Fig. 1/12: search throughput scales with compression level.
pub fn fig1(ctx: &ExpContext) -> anyhow::Result<()> {
    let ds = ctx.dataset(&spec_by_name(ctx, "rqa-768"));
    let d = paper_target_dim("rqa-768");
    let truth = dataset_truth(&ds, K);
    let arms = vec![
        build_arm(ctx, "fp16", &ds, ProjectionKind::None, 0, Compression::F16, Compression::F16),
        build_arm(ctx, "lvq8", &ds, ProjectionKind::None, 0, Compression::Lvq8, Compression::F16),
        build_arm(
            ctx,
            "lvq4x8",
            &ds,
            ProjectionKind::None,
            0,
            Compression::Lvq4x8,
            Compression::F16,
        ),
        build_arm(
            ctx,
            "leanvec",
            &ds,
            ProjectionKind::OodEigSearch,
            d,
            Compression::Lvq8,
            Compression::F16,
        ),
    ];
    // compression factors vs FP16 full-D (paper: lvq8 2x, lvq4x8 ~4x,
    // leanvec 9.6x at D=768,d=160)
    let fp16_bytes = (ds.dim * 2) as f64;
    let mut rows = Vec::new();
    for arm in &arms {
        rows.push(vec![
            arm.name.clone(),
            format!("{}", arm.index.primary.bytes_per_vector()),
            format!("{:.1}x", fp16_bytes / arm.index.primary.bytes_per_vector() as f64),
        ]);
    }
    println!("[fig1] primary-vector compression (D={} FP16 = {fp16_bytes} B):", ds.dim);
    print_table(&["method", "bytes/vector", "compression vs FP16"], &rows);

    let curves = curves_for_arms(&ds, &arms, &truth);
    report_curves(
        ctx,
        "fig1",
        &ds.name,
        &curves,
        vec![("fp16_bytes_per_vector", Json::num(fp16_bytes))],
    )
}

/// Figs. 4 (ID) and 5 (OOD): QPS-recall across the standard arms.
fn fig45(ctx: &ExpContext, exp: &str, names: &[&str]) -> anyhow::Result<()> {
    for name in names {
        let ds = ctx.dataset(&spec_by_name(ctx, name));
        let d = paper_target_dim(name);
        let truth = dataset_truth(&ds, K);
        let arms = standard_arms(ctx, &ds, d);
        let curves = curves_for_arms(&ds, &arms, &truth);
        report_curves(ctx, exp, name, &curves, vec![])?;
    }
    Ok(())
}

pub fn fig4(ctx: &ExpContext) -> anyhow::Result<()> {
    fig45(ctx, "fig4", &["gist-960", "deep-256", "open-images-512"])
}

pub fn fig5(ctx: &ExpContext) -> anyhow::Result<()> {
    fig45(ctx, "fig5", &["t2i-200", "wit-512", "rqa-768", "laion-512"])
}

/// Fig. 6: graph-construction time across representations.
pub fn fig6(ctx: &ExpContext) -> anyhow::Result<()> {
    let mut json_rows = Vec::new();
    let mut rows = Vec::new();
    for name in ["rqa-768", "open-images-512"] {
        let ds = ctx.dataset(&spec_by_name(ctx, name));
        let d = paper_target_dim(name);
        let arms = standard_arms(ctx, &ds, d);
        for arm in &arms {
            let b = arm.index.build_breakdown;
            rows.push(vec![
                name.to_string(),
                arm.name.clone(),
                format!("{:.2}", b.graph_seconds),
                format!("{:.2}", b.train_seconds),
                format!("{:.2}", b.total()),
            ]);
            json_rows.push(Json::obj(vec![
                ("dataset", Json::str(name)),
                ("method", Json::str(&arm.name)),
                ("graph_seconds", Json::num(b.graph_seconds)),
                ("train_seconds", Json::num(b.train_seconds)),
                ("project_seconds", Json::num(b.project_seconds)),
                ("quantize_seconds", Json::num(b.quantize_seconds)),
                ("total_seconds", Json::num(b.total())),
            ]));
        }
    }
    println!("[fig6] index-construction time:");
    print_table(
        &["dataset", "method", "graph s", "train s", "total s"],
        &rows,
    );
    ctx.save("fig6", &Json::arr(json_rows))
}

/// Fig. 7: LeanVec vs HNSW / Vamana / IVF-PQ.
pub fn fig7(ctx: &ExpContext) -> anyhow::Result<()> {
    for name in ["deep-256", "rqa-768"] {
        let ds = ctx.dataset(&spec_by_name(ctx, name));
        let d = paper_target_dim(name);
        let truth = dataset_truth(&ds, K);
        let windows = default_windows(K);

        // SVS-LeanVec + SVS-LVQ arms
        let arms = vec![
            build_arm(
                ctx,
                "svs-leanvec",
                &ds,
                ProjectionKind::OodEigSearch,
                d,
                Compression::Lvq8,
                Compression::F16,
            ),
            build_arm(
                ctx,
                "svs-lvq",
                &ds,
                ProjectionKind::None,
                0,
                Compression::Lvq4x8,
                Compression::F16,
            ),
            // "vamana" baseline: vamana graph over uncompressed f32
            build_arm(ctx, "vamana-f32", &ds, ProjectionKind::None, 0, Compression::F32, Compression::F32),
        ];
        let mut curves = curves_for_arms(&ds, &arms, &truth);

        // HNSW baseline — same sweep through the VectorIndex trait
        // (window = ef for the HNSW arm)
        let graph_sim = if ds.similarity == Similarity::Cosine {
            Similarity::InnerProduct
        } else {
            ds.similarity
        };
        let hnsw = build_hnsw_baseline(&ds.database, graph_sim, Compression::F16, ctx.seed);
        curves.push((
            "hnsw".to_string(),
            qps_recall_curve(&hnsw, &ds.test_queries, &truth, K, &windows),
        ));

        // IVF-PQ baseline (window = nprobe through the trait)
        if ds.dim % 8 == 0 {
            let ivf = IvfPqIndex::build(
                &ds.database,
                IvfPqParams {
                    nlist: (ds.database.len() as f64).sqrt() as usize,
                    m: 8,
                    ksub: 256,
                    kmeans_iters: 6,
                },
                graph_sim,
                ctx.seed,
            );
            let nprobes = [1usize, 2, 4, 8, 16, 32, 64];
            curves.push((
                "faiss-ivfpq".to_string(),
                qps_recall_curve(&ivf, &ds.test_queries, &truth, K, &nprobes),
            ));
        }
        report_curves(ctx, "fig7", name, &curves, vec![])?;
    }
    Ok(())
}

/// Fig. 8: scaling with database size.
pub fn fig8(ctx: &ExpContext) -> anyhow::Result<()> {
    let base = spec_by_name(ctx, "rqa-768");
    for mult in [1usize, 4] {
        let mut spec = base.clone();
        spec.n *= mult;
        spec.name = format!("rqa-768-{}k", spec.n / 1000);
        let ds = ctx.dataset(&spec);
        let truth = dataset_truth(&ds, K);
        let d = paper_target_dim("rqa-768");
        let arms = vec![
            build_arm(
                ctx,
                "svs-leanvec",
                &ds,
                ProjectionKind::OodEigSearch,
                d,
                Compression::Lvq8,
                Compression::F16,
            ),
            build_arm(
                ctx,
                "svs-lvq",
                &ds,
                ProjectionKind::None,
                0,
                Compression::Lvq4x8,
                Compression::F16,
            ),
        ];
        let curves = curves_for_arms(&ds, &arms, &truth);
        report_curves(ctx, "fig8", &ds.name, &curves, vec![])?;
    }
    Ok(())
}

/// Fig. 9: target-dimensionality ablation.
pub fn fig9(ctx: &ExpContext) -> anyhow::Result<()> {
    let ds = ctx.dataset(&spec_by_name(ctx, "rqa-768"));
    let truth = dataset_truth(&ds, K);
    let dims = [64usize, 96, 128, 160, 256, 320];
    let mut curves = Vec::new();
    for &d in &dims {
        let arm = build_arm(
            ctx,
            &format!("d={d}"),
            &ds,
            ProjectionKind::OodEigSearch,
            d,
            Compression::Lvq8,
            Compression::F16,
        );
        curves.push((
            arm.name.clone(),
            qps_recall_curve(&arm.index, &ds.test_queries, &truth, K, &default_windows(K)),
        ));
    }
    report_curves(ctx, "fig9", &ds.name, &curves, vec![])
}

/// Fig. 10: quantization-level ablation (primary x secondary).
pub fn fig10(ctx: &ExpContext) -> anyhow::Result<()> {
    let ds = ctx.dataset(&spec_by_name(ctx, "wit-512"));
    let d = paper_target_dim("wit-512");
    let truth = dataset_truth(&ds, K);
    let combos: [(&str, Compression, Compression); 5] = [
        ("lvq8+fp16", Compression::Lvq8, Compression::F16),
        ("lvq8+lvq8", Compression::Lvq8, Compression::Lvq8),
        ("lvq4+fp16", Compression::Lvq4, Compression::F16),
        ("fp16+fp16", Compression::F16, Compression::F16),
        ("lvq8+fp32", Compression::Lvq8, Compression::F32),
    ];
    let mut curves = Vec::new();
    for (name, prim, sec) in combos {
        let arm = build_arm(ctx, name, &ds, ProjectionKind::OodEigSearch, d, prim, sec);
        curves.push((
            arm.name.clone(),
            qps_recall_curve(&arm.index, &ds.test_queries, &truth, K, &default_windows(K)),
        ));
    }
    report_curves(ctx, "fig10", &ds.name, &curves, vec![])
}

/// Fig. 14: graphs built with vs without dimensionality reduction have
/// the same search quality.
pub fn fig14(ctx: &ExpContext) -> anyhow::Result<()> {
    let ds = ctx.dataset(&spec_by_name(ctx, "wit-512"));
    let d = paper_target_dim("wit-512");
    let truth = dataset_truth(&ds, K);

    // arm A: everything standard (graph built over reduced primaries)
    let arm_a = build_arm(
        ctx,
        "graph-on-reduced",
        &ds,
        ProjectionKind::OodEigSearch,
        d,
        Compression::Lvq8,
        Compression::F16,
    );
    // arm B: same primaries, but the graph is built over the FULL-D
    // LVQ8 store and transplanted
    let mut arm_b = build_arm(
        ctx,
        "graph-on-full",
        &ds,
        ProjectionKind::OodEigSearch,
        d,
        Compression::Lvq8,
        Compression::F16,
    );
    let full_store = make_store(&ds.database, Compression::Lvq8);
    let graph_sim = if ds.similarity == Similarity::Cosine {
        Similarity::InnerProduct
    } else {
        ds.similarity
    };
    let gp = ctx.graph_params(ds.similarity);
    arm_b.index.graph = VamanaBuilder::new(gp, graph_sim).build(full_store.as_ref());

    let curves = curves_for_arms(&ds, &[arm_a, arm_b], &truth);
    report_curves(ctx, "fig14", &ds.name, &curves, vec![])
}
