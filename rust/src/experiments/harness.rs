//! Shared experiment plumbing: contexts, QPS-recall sweeps, table
//! printing, JSON output.

use crate::config::{Compression, GraphParams, ProjectionKind, Similarity};
use crate::data::gt::{ground_truth, recall_at_k};
use crate::data::synth::{generate, Dataset, SynthSpec};
use crate::graph::beam::SearchCtx;
use crate::index::builder::IndexBuilder;
use crate::index::leanvec_index::{LeanVecIndex, SearchParams};
use crate::index::query::{Query, VectorIndex};
use crate::util::json::Json;
use std::path::PathBuf;
use std::time::Instant;

/// Experiment context (CLI flags).
#[derive(Clone, Debug)]
pub struct ExpContext {
    pub out_dir: PathBuf,
    /// multiplies dataset sizes (1.0 -> 20k vectors/dataset)
    pub scale: f64,
    /// use the PJRT artifacts for training/projection when available
    pub use_pjrt: bool,
    pub seed: u64,
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            out_dir: PathBuf::from("results"),
            scale: 0.35,
            use_pjrt: false,
            seed: 7,
        }
    }
}

impl ExpContext {
    pub fn save(&self, name: &str, json: &Json) -> anyhow::Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = self.out_dir.join(format!("{name}.json"));
        std::fs::write(&path, json.to_pretty())?;
        println!("[saved {path:?}]");
        Ok(())
    }

    /// Graph parameters scaled for the testbed.
    pub fn graph_params(&self, sim: Similarity) -> GraphParams {
        let mut gp = GraphParams::for_similarity(sim);
        gp.max_degree = 32;
        gp.build_window = 64;
        gp
    }

    pub fn dataset(&self, spec: &SynthSpec) -> Dataset {
        let mut s = spec.clone();
        s.n = ((s.n as f64) as usize).max(500);
        generate(&s)
    }
}

/// One method arm in a search comparison.
pub struct Arm {
    pub name: String,
    pub index: LeanVecIndex,
}

/// Build one LeanVec-index arm.
pub fn build_arm(
    ctx: &ExpContext,
    name: &str,
    ds: &Dataset,
    projection: ProjectionKind,
    d: usize,
    primary: Compression,
    secondary: Compression,
) -> Arm {
    let gp = ctx.graph_params(ds.similarity);
    let index = IndexBuilder::new()
        .projection(projection)
        .target_dim(d)
        .primary(primary)
        .secondary(secondary)
        .graph_params(gp)
        .seed(ctx.seed)
        .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
    Arm {
        name: name.to_string(),
        index,
    }
}

/// The standard arms of figs 4/5: FP16 (no reduction), LVQ (4x8, no
/// reduction), LeanVec-ID, LeanVec-OOD — all sharing graph params.
pub fn standard_arms(ctx: &ExpContext, ds: &Dataset, d: usize) -> Vec<Arm> {
    vec![
        build_arm(ctx, "fp16", ds, ProjectionKind::None, 0, Compression::F16, Compression::F16),
        build_arm(
            ctx,
            "lvq4x8",
            ds,
            ProjectionKind::None,
            0,
            Compression::Lvq4x8,
            Compression::F16,
        ),
        build_arm(ctx, "leanvec-id", ds, ProjectionKind::Id, d, Compression::Lvq8, Compression::F16),
        build_arm(
            ctx,
            "leanvec-ood",
            ds,
            ProjectionKind::OodEigSearch,
            d,
            Compression::Lvq8,
            Compression::F16,
        ),
    ]
}

/// One point on a QPS-recall curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub window: usize,
    pub recall: f64,
    pub qps: f64,
    pub bytes_per_query: f64,
}

/// Measure one point — recall, single-thread QPS, bytes/query — at
/// explicit [`SearchParams`] (so a split-buffer `rerank_window` larger
/// than the traversal window is measurable). The single copy of the
/// measurement loop behind [`qps_recall_curve`] and the CLI's
/// point report.
pub fn qps_recall_point<I: VectorIndex>(
    index: &I,
    queries: &[Vec<f32>],
    truth: &[Vec<u32>],
    k: usize,
    params: SearchParams,
) -> CurvePoint {
    let mut ctx = SearchCtx::new(index.len());
    let mut got: Vec<Vec<u32>> = Vec::with_capacity(queries.len());
    let mut bytes = 0usize;
    let t0 = Instant::now();
    for q in queries {
        let r = index.search(
            &mut ctx,
            &Query::new(q)
                .k(k)
                .window(params.window)
                .rerank_window(params.rerank_window),
        );
        bytes += r.stats.bytes_touched;
        got.push(r.ids);
    }
    let wall = t0.elapsed().as_secs_f64();
    CurvePoint {
        window: params.window,
        recall: recall_at_k(&got, truth, k),
        qps: queries.len() as f64 / wall.max(1e-12),
        bytes_per_query: bytes as f64 / queries.len().max(1) as f64,
    }
}

/// Sweep the search window, measuring recall and single-thread QPS.
/// Generic over [`VectorIndex`], so one sweep serves every arm — for
/// IVF-PQ the "window" is its `nprobe`, for HNSW its `ef`.
pub fn qps_recall_curve<I: VectorIndex>(
    index: &I,
    queries: &[Vec<f32>],
    truth: &[Vec<u32>],
    k: usize,
    windows: &[usize],
) -> Vec<CurvePoint> {
    windows
        .iter()
        .map(|&w| {
            qps_recall_point(
                index,
                queries,
                truth,
                k,
                SearchParams {
                    window: w,
                    rerank_window: w,
                },
            )
        })
        .collect()
}

/// The paper's headline metric: QPS at the first window reaching the
/// recall target (linear interpolation between bracketing points).
pub fn qps_at_recall(curve: &[CurvePoint], target: f64) -> Option<f64> {
    let mut prev: Option<&CurvePoint> = None;
    for p in curve {
        if p.recall >= target {
            return Some(match prev {
                Some(lo) if p.recall > lo.recall => {
                    let t = (target - lo.recall) / (p.recall - lo.recall);
                    lo.qps + t * (p.qps - lo.qps)
                }
                _ => p.qps,
            });
        }
        prev = Some(p);
    }
    None
}

/// Default window sweep.
pub fn default_windows(k: usize) -> Vec<usize> {
    let mut w: Vec<usize> = vec![k, k * 2, k * 3, k * 5, k * 8, k * 12, k * 20, k * 30];
    w.dedup();
    w
}

/// Ground truth for the test queries of a dataset.
pub fn dataset_truth(ds: &Dataset, k: usize) -> Vec<Vec<u32>> {
    ground_truth(&ds.database, &ds.test_queries, k, ds.similarity)
}

/// Pretty-print a table.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(
        &widths
            .iter()
            .map(|w| "-".repeat(*w))
            .collect::<Vec<_>>(),
    );
    for row in rows {
        line(row);
    }
}

/// Curve points -> JSON.
pub fn curve_json(curve: &[CurvePoint]) -> Json {
    Json::arr(curve.iter().map(|p| {
        Json::obj(vec![
            ("window", Json::num(p.window as f64)),
            ("recall", Json::num(p.recall)),
            ("qps", Json::num(p.qps)),
            ("bytes_per_query", Json::num(p.bytes_per_query)),
        ])
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::QueryDist;

    fn tiny_ctx() -> ExpContext {
        ExpContext {
            out_dir: std::env::temp_dir().join(format!("leanvec-exp-{}", std::process::id())),
            scale: 1.0,
            use_pjrt: false,
            seed: 1,
        }
    }

    fn tiny_ds() -> Dataset {
        generate(&SynthSpec {
            name: "tiny".into(),
            dim: 24,
            n: 600,
            n_learn_queries: 100,
            n_test_queries: 60,
            similarity: Similarity::InnerProduct,
            queries: QueryDist::InDistribution,
            decay: 0.7,
            seed: 5,
        })
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn curve_is_monotone_in_recall() {
        let ctx = tiny_ctx();
        let ds = tiny_ds();
        let arm = build_arm(
            &ctx,
            "t",
            &ds,
            ProjectionKind::Id,
            8,
            Compression::Lvq8,
            Compression::F16,
        );
        let truth = dataset_truth(&ds, 10);
        let curve = qps_recall_curve(&arm.index, &ds.test_queries, &truth, 10, &[10, 30, 80]);
        assert_eq!(curve.len(), 3);
        assert!(curve[2].recall >= curve[0].recall - 0.02);
        assert!(curve.iter().all(|p| p.qps > 0.0));
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn qps_at_recall_interpolates() {
        let curve = vec![
            CurvePoint {
                window: 10,
                recall: 0.5,
                qps: 1000.0,
                bytes_per_query: 0.0,
            },
            CurvePoint {
                window: 20,
                recall: 0.9,
                qps: 500.0,
                bytes_per_query: 0.0,
            },
        ];
        let q = qps_at_recall(&curve, 0.7).unwrap();
        assert!(q < 1000.0 && q > 500.0);
        assert!(qps_at_recall(&curve, 0.95).is_none());
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn save_writes_json() {
        let ctx = tiny_ctx();
        ctx.save("unit", &Json::obj(vec![("x", Json::num(1.0))]))
            .unwrap();
        let path = ctx.out_dir.join("unit.json");
        assert!(path.exists());
        std::fs::remove_file(path).ok();
    }
}
