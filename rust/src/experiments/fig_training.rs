//! Projection-learning experiments: figs 2, 3, 13, 15 and Prop. 1.

use super::harness::{print_table, ExpContext};
use crate::data::synth::{generate, paper_datasets, paper_target_dim, SynthSpec};
use crate::leanvec::eigsearch::{beta_sweep, eigsearch, NativeTopd, TopdBackend};
use crate::leanvec::fw::{frank_wolfe, FwParams, FwStepper, NativeStepper};
use crate::leanvec::loss::ood_loss;
use crate::leanvec::model::rows_to_matrix;
use crate::leanvec::pca::pca;
use crate::linalg::Matrix;
use crate::util::json::Json;
use std::time::Instant;

fn spec_by_name(ctx: &ExpContext, name: &str) -> SynthSpec {
    paper_datasets(ctx.scale)
        .into_iter()
        .find(|s| s.name == name)
        .expect("known dataset")
}

fn moments(ctx: &ExpContext, name: &str) -> (Matrix, Matrix, usize) {
    let ds = ctx.dataset(&spec_by_name(ctx, name));
    let kx = rows_to_matrix(&ds.database).second_moment();
    let kq = rows_to_matrix(&ds.learn_queries).second_moment();
    let d = paper_target_dim(name);
    (kq, kx, d)
}

/// Pick the FW stepper: PJRT artifact when requested + available.
fn make_stepper(ctx: &ExpContext) -> Box<dyn FwStepper> {
    if ctx.use_pjrt {
        if let Ok(rt) = crate::runtime::executor::open_shared(
            &crate::runtime::default_artifacts_dir(),
        ) {
            return Box::new(crate::runtime::PjrtFwStepper::new(rt));
        }
        eprintln!("[warn] pjrt requested but unavailable; native stepper");
    }
    Box::new(NativeStepper)
}

fn make_topd(ctx: &ExpContext) -> Box<dyn TopdBackend> {
    if ctx.use_pjrt {
        if let Ok(rt) = crate::runtime::executor::open_shared(
            &crate::runtime::default_artifacts_dir(),
        ) {
            return Box::new(crate::runtime::PjrtTopd::new(rt));
        }
    }
    Box::new(NativeTopd)
}

/// Fig. 2: Frank-Wolfe convergence (loss vs iteration, runtime).
pub fn fig2(ctx: &ExpContext) -> anyhow::Result<()> {
    let (kq, kx, d) = moments(ctx, "wit-512");
    let init = eigsearch(&kq, &kx, d, make_topd(ctx).as_mut());
    let mut stepper = make_stepper(ctx);
    let t0 = Instant::now();
    let res = frank_wolfe(
        stepper.as_mut(),
        init.p.clone(),
        init.p.clone(),
        &kq,
        &kx,
        FwParams {
            max_iters: 100,
            alpha: 0.7,
            tol: 1e-3,
        },
    );
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "[fig2] FW ({}) converged in {} iterations, {:.2}s (early-stop |Δf|/f <= 1e-3)",
        stepper.name(),
        res.iterations,
        secs
    );
    let show = res.losses.len().min(8);
    for (t, l) in res.losses.iter().take(show).enumerate() {
        println!("  iter {t:>3}: loss {l:.6e}");
    }
    println!("  ...    best: {:.6e}", res.best_loss);
    ctx.save(
        "fig2",
        &Json::obj(vec![
            ("backend", Json::str(stepper.name())),
            ("iterations", Json::num(res.iterations as f64)),
            ("seconds", Json::num(secs)),
            ("converged", Json::Bool(res.converged)),
            (
                "losses",
                Json::arr(res.losses.iter().map(|&l| Json::num(l))),
            ),
        ]),
    )
}

/// Fig. 3/17: the eigsearch loss is a smooth function of beta with a
/// (unique) interior minimizer per d.
pub fn fig3(ctx: &ExpContext) -> anyhow::Result<()> {
    let (kq, kx, _) = moments(ctx, "wit-512");
    let betas: Vec<f64> = (0..=20).map(|i| i as f64 / 20.0).collect();
    let mut out = Vec::new();
    for d in [64usize, 128, 192, 256] {
        let sweep = beta_sweep(&kq, &kx, d, &betas, make_topd(ctx).as_mut());
        let (bmin, lmin) = sweep
            .iter()
            .cloned()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        println!("[fig3] d={d}: argmin beta = {bmin:.2} (loss {lmin:.4e})");
        out.push(Json::obj(vec![
            ("d", Json::num(d as f64)),
            ("argmin_beta", Json::num(bmin)),
            (
                "curve",
                Json::arr(sweep.iter().map(|&(b, l)| {
                    Json::obj(vec![("beta", Json::num(b)), ("loss", Json::num(l))])
                })),
            ),
        ]));
    }
    ctx.save("fig3", &Json::arr(out))
}

/// Fig. 13/18: FW vs ES vs ES-initialized-FW, loss + runtime.
pub fn fig13(ctx: &ExpContext) -> anyhow::Result<()> {
    let mut rows = Vec::new();
    let mut json = Vec::new();
    for name in ["t2i-200", "wit-512", "rqa-768"] {
        let (kq, kx, d) = moments(ctx, name);

        let t0 = Instant::now();
        let es = eigsearch(&kq, &kx, d, make_topd(ctx).as_mut());
        let es_s = t0.elapsed().as_secs_f64();

        // FW from random init
        let mut rng = crate::util::rng::Rng::new(ctx.seed);
        let r0 = crate::linalg::qr::random_orthonormal(d, kx.rows, &mut rng);
        let t0 = Instant::now();
        let fw = frank_wolfe(
            make_stepper(ctx).as_mut(),
            r0.clone(),
            r0,
            &kq,
            &kx,
            FwParams::default(),
        );
        let fw_s = t0.elapsed().as_secs_f64();

        // ES + FW (paper's Fig. 18 composite)
        let t0 = Instant::now();
        let esfw = frank_wolfe(
            make_stepper(ctx).as_mut(),
            es.p.clone(),
            es.p.clone(),
            &kq,
            &kx,
            FwParams::default(),
        );
        let esfw_s = es_s + t0.elapsed().as_secs_f64();

        for (m, loss, secs) in [
            ("leanvec-es", es.loss, es_s),
            ("leanvec-fw", fw.best_loss, fw_s),
            ("leanvec-es+fw", esfw.best_loss.min(es.loss), esfw_s),
        ] {
            rows.push(vec![
                name.to_string(),
                m.to_string(),
                format!("{loss:.4e}"),
                format!("{secs:.2}"),
            ]);
            json.push(Json::obj(vec![
                ("dataset", Json::str(name)),
                ("method", Json::str(m)),
                ("loss", Json::num(loss)),
                ("seconds", Json::num(secs)),
            ]));
        }
    }
    println!("[fig13] OOD-loss by optimizer:");
    print_table(&["dataset", "method", "loss", "train s"], &rows);
    ctx.save("fig13", &Json::arr(json))
}

/// Fig. 15/16: subsampling robustness of K_Q / K_X.
pub fn fig15(ctx: &ExpContext) -> anyhow::Result<()> {
    let mut spec = spec_by_name(ctx, "wit-512");
    // the sweep needs up to 8D learn queries / database samples
    spec.n_learn_queries = spec.dim * 8;
    spec.n = spec.n.max(spec.dim * 8);
    let ds = generate(&spec);
    let d = paper_target_dim("wit-512");
    let dd = ds.dim;
    let full_kx = rows_to_matrix(&ds.database).second_moment();
    let full_kq = rows_to_matrix(&ds.learn_queries).second_moment();
    let p_full = eigsearch(&full_kq, &full_kx, d, &mut NativeTopd).p;
    let loss_full = ood_loss(&p_full, &p_full, &full_kq, &full_kx);

    let mut out = Vec::new();
    let mut rows = Vec::new();
    for frac_d in [1usize, 2, 4, 8] {
        let ns = (dd * frac_d).min(ds.database.len()).min(ds.learn_queries.len());
        let kx = rows_to_matrix(&ds.database[..ns]).second_moment();
        let kq = rows_to_matrix(&ds.learn_queries[..ns.min(ds.learn_queries.len())])
            .second_moment();
        let p = eigsearch(&kq, &kx, d, &mut NativeTopd).p;
        // evaluate the subsampled solution on the FULL moments
        let loss = ood_loss(&p, &p, &full_kq, &full_kx);
        let rel = (loss - loss_full) / loss_full.abs().max(1e-30);
        rows.push(vec![
            format!("{frac_d}D = {ns}"),
            format!("{loss:.4e}"),
            format!("{rel:+.3}"),
        ]);
        out.push(Json::obj(vec![
            ("samples", Json::num(ns as f64)),
            ("loss_on_full", Json::num(loss)),
            ("relative_excess", Json::num(rel)),
        ]));
    }
    println!("[fig15] subsampled training vs full (full loss {loss_full:.4e}):");
    print_table(&["samples", "loss on full moments", "rel. excess"], &rows);
    ctx.save("fig15", &Json::arr(out))
}

/// Prop. 1: the OOD learners' loss never exceeds the PCA (SVD) bound.
pub fn prop1(ctx: &ExpContext) -> anyhow::Result<()> {
    let mut rows = Vec::new();
    let mut json = Vec::new();
    let mut all_hold = true;
    for name in ["deep-256", "t2i-200", "wit-512", "rqa-768"] {
        let (kq, kx, d) = moments(ctx, name);
        let p = pca(&kx, d);
        let bound = ood_loss(&p, &p, &kq, &kx);
        let es = eigsearch(&kq, &kx, d, &mut NativeTopd);
        let holds = es.loss <= bound * (1.0 + 1e-6);
        all_hold &= holds;
        rows.push(vec![
            name.to_string(),
            format!("{:.4e}", es.loss),
            format!("{bound:.4e}"),
            holds.to_string(),
        ]);
        json.push(Json::obj(vec![
            ("dataset", Json::str(name)),
            ("ood_loss", Json::num(es.loss)),
            ("pca_bound", Json::num(bound)),
            ("holds", Json::Bool(holds)),
        ]));
    }
    println!("[prop1] LeanVec-OOD loss <= PCA upper bound (Proposition 1):");
    print_table(&["dataset", "OOD loss", "PCA bound", "holds"], &rows);
    anyhow::ensure!(all_hold, "Proposition 1 violated");
    ctx.save("prop1", &Json::arr(json))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Prop. 1 at unit-test scale: the eigsearch loss never exceeds the
    /// PCA bound, on small synthetic OOD moments (the full-scale check
    /// runs as `repro experiment prop1`).
    #[test]
    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn prop1_property_small_moments() {
        let mut rng = Rng::new(3);
        let dd = 40;
        let ub = crate::linalg::qr::random_orthonormal(dd, dd, &mut rng);
        let uq = crate::linalg::qr::random_orthonormal(dd, dd, &mut rng);
        let x = Matrix::randn(300, dd, &mut rng).matmul(&ub);
        let q = Matrix::randn(200, dd, &mut rng).matmul(&uq);
        let (kx, kq) = (x.second_moment(), q.second_moment());
        for d in [5usize, 10, 20] {
            let p = pca(&kx, d);
            let bound = ood_loss(&p, &p, &kq, &kx);
            let es = eigsearch(&kq, &kx, d, &mut NativeTopd);
            assert!(es.loss <= bound * (1.0 + 1e-6), "d={d}: {} > {bound}", es.loss);
        }
    }
}
