//! Experiment harness: one entry per paper table/figure (DESIGN.md's
//! experiment index). Each experiment prints the paper-style rows and
//! writes machine-readable JSON under the output directory.
//!
//! Run via the CLI: `repro experiment <id> [--out results] [--scale S]`
//! where `<id>` is one of: table1, fig1, fig2, fig3, fig4, fig5, fig6,
//! fig7, fig8, fig9, fig10, fig11, fig13, fig14, fig15, prop1, all.

pub mod fig_rerank;
pub mod fig_search;
pub mod fig_training;
pub mod harness;
pub mod table1;

use harness::ExpContext;

/// Dispatch an experiment by id.
pub fn run(id: &str, ctx: &ExpContext) -> anyhow::Result<()> {
    match id {
        "table1" => table1::run(ctx),
        "fig1" => fig_search::fig1(ctx),
        "fig2" => fig_training::fig2(ctx),
        "fig3" => fig_training::fig3(ctx),
        "fig4" => fig_search::fig4(ctx),
        "fig5" => fig_search::fig5(ctx),
        "fig6" => fig_search::fig6(ctx),
        "fig7" => fig_search::fig7(ctx),
        "fig8" => fig_search::fig8(ctx),
        "fig9" => fig_search::fig9(ctx),
        "fig10" => fig_search::fig10(ctx),
        "fig11" => fig_rerank::fig11(ctx),
        "fig13" => fig_training::fig13(ctx),
        "fig14" => fig_search::fig14(ctx),
        "fig15" => fig_training::fig15(ctx),
        "prop1" => fig_training::prop1(ctx),
        "all" => {
            for id in [
                "table1", "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8",
                "fig9", "fig10", "fig11", "fig13", "fig14", "fig15", "prop1",
            ] {
                println!("\n================ {id} ================");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown experiment '{other}'")),
    }
}
