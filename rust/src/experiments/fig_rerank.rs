//! Fig. 11: the re-ranking ablation. Exhaustive search over reduced
//! vectors: recall@10 is poor for every dimensionality-reduction
//! method, recall@50 is strong, and re-ranking 50 candidates with the
//! secondary vectors restores recall@10.
//!
//! NN-MDS and CCST (neural baselines) are substituted with a random
//! orthonormal projection (see DESIGN.md §Substitutions): the figure's
//! claim — rerank closes the gap; query-aware projection dominates on
//! OOD data — is preserved.

use super::harness::{print_table, ExpContext};
use crate::config::{ProjectionKind, Similarity};
use crate::data::gt::{ground_truth, recall_at_k};
use crate::data::synth::{paper_datasets, paper_target_dim, SynthSpec};
use crate::index::flat::FlatIndex;
use crate::leanvec::model::{train_projection, TrainBackends};
use crate::util::json::Json;

fn spec_by_name(ctx: &ExpContext, name: &str) -> SynthSpec {
    paper_datasets(ctx.scale)
        .into_iter()
        .find(|s| s.name == name)
        .expect("known dataset")
}

pub fn fig11(ctx: &ExpContext) -> anyhow::Result<()> {
    let mut rows = Vec::new();
    let mut json = Vec::new();
    for name in ["deep-256", "t2i-200", "rqa-768"] {
        let ds = ctx.dataset(&spec_by_name(ctx, name));
        // paper reduces 4x (2x for t2i)
        let d = if name == "t2i-200" {
            ds.dim / 2
        } else {
            ds.dim / 4
        };
        let _ = paper_target_dim(name);
        let truth = ground_truth(&ds.database, &ds.test_queries, 10, ds.similarity);
        let flat_full = FlatIndex::new(&ds.database, effective_sim(ds.similarity));

        for kind in [
            ProjectionKind::Id,
            ProjectionKind::OodEigSearch,
            ProjectionKind::Random,
        ] {
            let mut backends = TrainBackends::default();
            let model = train_projection(
                kind,
                &ds.database[..ds.database.len().min(10_000)],
                Some(&ds.learn_queries),
                d,
                &mut backends,
                ctx.seed,
            );
            // exhaustive search in the reduced space
            let reduced_db = model.project_database(&ds.database);
            let flat_reduced = FlatIndex::new(&reduced_db, effective_sim(ds.similarity));

            let mut got10 = Vec::new();
            let mut got50_reranked = Vec::new();
            let mut got50_raw_hits = 0usize;
            for (qi, q) in ds.test_queries.iter().enumerate() {
                let qp = model.project_query(q);
                let (ids10, _) = flat_reduced.search(&qp, 10);
                got10.push(ids10);
                let (ids50, _) = flat_reduced.search(&qp, 50);
                // recall@50 of the true top-10
                let t10 = &truth[qi][..10.min(truth[qi].len())];
                got50_raw_hits += t10.iter().filter(|t| ids50.contains(t)).count();
                // rerank the 50 with exact full-D scores
                let reranked = rerank_exact(&flat_full, q, &ids50, 10);
                got50_reranked.push(reranked);
            }
            let r10 = recall_at_k(&got10, &truth, 10);
            let r50 = got50_raw_hits as f64 / (10 * ds.test_queries.len()) as f64;
            let r10_rr = recall_at_k(&got50_reranked, &truth, 10);
            rows.push(vec![
                name.to_string(),
                kind.name().to_string(),
                format!("{r10:.3}"),
                format!("{r50:.3}"),
                format!("{r10_rr:.3}"),
            ]);
            json.push(Json::obj(vec![
                ("dataset", Json::str(name)),
                ("method", Json::str(kind.name())),
                ("recall10", Json::num(r10)),
                ("recall50", Json::num(r50)),
                ("recall10_after_rerank", Json::num(r10_rr)),
            ]));
        }
    }
    println!("[fig11] exhaustive-search rerank ablation (reduction 4x; 2x for t2i):");
    print_table(
        &["dataset", "method", "recall@10", "recall@50", "recall@10+rerank"],
        &rows,
    );
    ctx.save("fig11", &Json::arr(json))
}

fn effective_sim(sim: Similarity) -> Similarity {
    if sim == Similarity::Cosine {
        Similarity::InnerProduct
    } else {
        sim
    }
}

/// Exact re-rank of candidate ids using the full-dimensional index.
fn rerank_exact(flat: &FlatIndex, q: &[f32], ids: &[u32], k: usize) -> Vec<u32> {
    let mut scored: Vec<(f32, u32)> = ids
        .iter()
        .map(|&id| (flat.score_one(q, id), id))
        .collect();
    scored.sort_by(|a, b| b.0.total_cmp(&a.0));
    scored.truncate(k);
    scored.into_iter().map(|(_, id)| id).collect()
}
