//! Table 1: the dataset roster (synthetic stand-ins; see DESIGN.md
//! §Substitutions) with the target dimensionality d per dataset.

use super::harness::{print_table, ExpContext};
use crate::data::synth::{paper_datasets, paper_target_dim, QueryDist};
use crate::util::json::Json;

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let specs = paper_datasets(ctx.scale);
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for s in &specs {
        let ood = matches!(s.queries, QueryDist::OutOfDistribution(_));
        let d = paper_target_dim(&s.name);
        rows.push(vec![
            s.name.clone(),
            s.dim.to_string(),
            s.n.to_string(),
            s.similarity.name().to_string(),
            if ood { "OOD" } else { "ID" }.to_string(),
            d.to_string(),
        ]);
        json_rows.push(Json::obj(vec![
            ("name", Json::str(&s.name)),
            ("D", Json::num(s.dim as f64)),
            ("n", Json::num(s.n as f64)),
            ("similarity", Json::str(s.similarity.name())),
            ("ood", Json::Bool(ood)),
            ("d", Json::num(d as f64)),
        ]));
    }
    println!("Table 1 — evaluated datasets (synthetic stand-ins, scale {}):", ctx.scale);
    print_table(&["dataset", "D", "n", "similarity", "queries", "d"], &rows);
    ctx.save("table1", &Json::arr(json_rows))
}
