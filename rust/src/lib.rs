//! # LeanVec
//!
//! A production-oriented reproduction of *"LeanVec: Searching vectors
//! faster by making them fit"* (Tepper et al., Intel Labs, 2023):
//! graph-based similarity search accelerated by combining **linear
//! dimensionality reduction** (in-distribution PCA and two query-aware
//! out-of-distribution learners) with **Locally-adaptive Vector
//! Quantization (LVQ)** in a search-and-rerank pipeline.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//! JAX/Pallas (Layers 1-2) author the projection-learning and batch
//! projection computations, which are AOT-lowered to HLO-text artifacts
//! at build time; this crate loads them through the PJRT C API
//! ([`runtime`]) and owns everything on the request path: the Vamana
//! graph ([`graph`]), the compressed vector stores ([`quant`]), the
//! search-and-rerank index ([`index`]), and the batching query engine
//! ([`coordinator`]). Python never runs at serve time.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod graph;
pub mod index;
pub mod leanvec;
pub mod linalg;
pub mod quant;
pub mod runtime;
pub mod util;

pub use config::Similarity;
