//! # LeanVec
//!
//! A production-oriented reproduction of *"LeanVec: Searching vectors
//! faster by making them fit"* (Tepper et al., Intel Labs, 2023):
//! graph-based similarity search accelerated by combining **linear
//! dimensionality reduction** (in-distribution PCA and two query-aware
//! out-of-distribution learners) with **Locally-adaptive Vector
//! Quantization (LVQ)** in a search-and-rerank pipeline.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//! JAX/Pallas (Layers 1-2) author the projection-learning and batch
//! projection computations, which are AOT-lowered to HLO-text artifacts
//! at build time; this crate loads them through the PJRT C API
//! ([`runtime`]) and owns everything on the request path: the Vamana
//! graph ([`graph`]), the compressed vector stores ([`quant`]), the
//! search-and-rerank index ([`index`]), and the batching query engine
//! ([`coordinator`]). Python never runs at serve time.
//!
//! Built indices round-trip to disk through the versioned snapshot
//! layer ([`index::persist`]): `build` constructs and saves once,
//! `serve`/`search` load and answer queries bit-identically — the
//! build/serve split. `docs/ARCHITECTURE.md` maps the modules and data
//! flows; `docs/SNAPSHOT_FORMAT.md` specifies the on-disk bytes.
//!
//! Every index speaks one typed query API ([`index::query`]): build a
//! [`index::Query`], call [`index::VectorIndex::search`], get a
//! [`index::SearchResult`] — with per-request window/rerank-window
//! overrides (split-buffer semantics) and filtered search pushed into
//! the traversal.
//!
//! Indexes are live, not frozen: [`mutate::LiveIndex`] accepts
//! streaming inserts and deletes concurrently with search
//! (FreshDiskANN-style tombstones + α-robust-prune linking), compacts
//! itself via background consolidation, and round-trips the mutated
//! state through versioned live snapshots. The serving engine feeds it
//! through an ingest lane ([`coordinator::Engine::start_live`]).
//!
//! The serving stack is observable while it runs: the [`obs`] layer
//! keeps lock-free counters and log-linear latency histograms for every
//! query stage (batcher queue wait, projection, per-shard scatter,
//! merge, rerank, end-to-end) plus ingest and mmap health, exposed as
//! Prometheus text or JSON via [`coordinator::Engine::metrics_text`] /
//! [`coordinator::Engine::metrics_json`], with a slow-query flight
//! recorder ([`obs::FlightRecorder`]) capturing per-stage breakdowns of
//! the slowest requests. `docs/OBSERVABILITY.md` has the catalog.
//!
//! Scoring bottoms out in the [`simd`] kernel layer: explicit
//! AVX2/FMA/F16C kernels selected once at startup by runtime CPU
//! detection, with a portable scalar fallback that is bit-identical to
//! the historical loops (`LEANVEC_FORCE_SCALAR=1` pins it). Graph
//! traversal and the flat/IVF scans feed those kernels in blocks with
//! software prefetch of upcoming code rows.
//!
//! # Quickstart
//!
//! Build an index over toy vectors, snapshot it, and query the loaded
//! copy through the unified `Query` → `VectorIndex` → `SearchResult`
//! path:
//!
//! ```
//! use leanvec::config::{ProjectionKind, Similarity};
//! use leanvec::index::{IndexBuilder, LeanVecIndex, Query, SnapshotMeta, VectorIndex};
//!
//! // 64 toy vectors in 8 dimensions
//! let rows: Vec<Vec<f32>> = (0..64)
//!     .map(|i| (0..8).map(|j| ((i * 31 + j * 7) as f32).sin()).collect())
//!     .collect();
//! let index = IndexBuilder::new()
//!     .projection(ProjectionKind::Id) // PCA to 4 dims
//!     .target_dim(4)
//!     .build(&rows, None, Similarity::L2);
//!
//! // build/serve split: snapshot to disk, load it back
//! let path = std::env::temp_dir().join(format!(
//!     "leanvec-doctest-{}.leanvec",
//!     std::process::id()
//! ));
//! index.save(&path, &SnapshotMeta::default()).unwrap();
//! let (loaded, _meta) = LeanVecIndex::load(&path).unwrap();
//! std::fs::remove_file(&path).ok();
//!
//! // builder -> search -> SearchResult; split buffer: rerank_window
//! // may exceed the traversal window
//! let query = Query::new(&rows[0]).k(3).window(20).rerank_window(40);
//! let result = loaded.search_one(&query);
//! assert_eq!(result.ids.len(), 3);
//! assert!(result.stats.primary_scored > 0);
//!
//! // the loaded index answers bit-identically to the built one
//! assert_eq!(result.ids, index.search_one(&query).ids);
//!
//! // filtered search: excluded ids are never returned
//! let even_only = |id: u32| id % 2 == 0;
//! let filtered = loaded.search_one(&Query::new(&rows[0]).k(3).filter(&even_only));
//! assert!(filtered.ids.iter().all(|id| id % 2 == 0));
//! assert!(filtered.stats.filtered > 0);
//! ```

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod graph;
pub mod index;
pub mod leanvec;
pub mod linalg;
pub mod mutate;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod shard;
pub mod simd;
pub mod util;

pub use config::Similarity;
