//! The serving coordinator: a batching query engine over a registry of
//! named collections ([`crate::shard::CollectionRegistry`]), each a
//! sharded index ([`crate::shard::ShardedIndex`]).
//!
//! Request path (Python never runs here):
//!
//! ```text
//! clients --> request channel --> batcher thread --> worker pool --> responses
//!                                  (collects up to     (scatter-gather
//!                                   max_batch or        across the
//!                                   max_wait, groups    collection's
//!                                   by collection,      shards: graph
//!                                   projects each       search + rerank
//!                                   group A q as one    with per-shard
//!                                   batched matmul —    pooled contexts,
//!                                   natively or through  stats-merging
//!                                   the PJRT project_q  top-k reduce)
//!                                   artifact)
//! ```
//!
//! Batching exists to amortize the query projection (a batched matmul —
//! exactly the granularity where PJRT dispatch pays off) and to give the
//! workers cache-friendly runs; per-query state stays on the workers.
//! Requests name their collection in [`protocol::QuerySpec`]; admission
//! quotas are enforced per collection at `Engine::submit*` time, which
//! returns [`engine::EngineError`] instead of panicking on a stopped
//! engine, an unknown collection, or an exhausted quota.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod protocol;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{
    Engine, EngineConfig, EngineError, IngestSnapshot, IngestStats, QueryProjectorKind,
    ShedPolicy, SwapReport, SWAP_DRAIN_TIMEOUT,
};
pub use metrics::{Metrics, QueryStatsSummary, ServeReport, StageSummary, StatsPercentiles};
pub use protocol::{Mutation, QuerySpec, Request, Response, StageTimes};
