//! The serving coordinator: a batching query engine over a
//! [`crate::index::LeanVecIndex`].
//!
//! Request path (Python never runs here):
//!
//! ```text
//! clients --> request channel --> batcher thread --> worker pool --> responses
//!                                  (collects up to     (graph search +
//!                                   max_batch or        rerank, one
//!                                   max_wait, projects  SearchCtx per
//!                                   queries A q as one  worker, zero
//!                                   batched matmul —    steady-state
//!                                   natively or through  allocations)
//!                                   the PJRT project_q
//!                                   artifact)
//! ```
//!
//! Batching exists to amortize the query projection (a batched matmul —
//! exactly the granularity where PJRT dispatch pays off) and to give the
//! workers cache-friendly runs; per-query state stays on the workers.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod protocol;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{Engine, EngineConfig, IngestSnapshot, IngestStats, QueryProjectorKind};
pub use metrics::{Metrics, QueryStatsSummary, ServeReport, StatsPercentiles};
pub use protocol::{Mutation, QuerySpec, Request, Response};
