//! Serving metrics: QPS, latency percentiles, recall, and the
//! per-query [`QueryStats`] distribution (hops, bytes touched,
//! filtered, tombstones routed through) aggregated as p50/p99 instead
//! of being dropped after the response echo.
//!
//! Percentiles come from the SAME histogram code path the live
//! telemetry registry uses ([`crate::obs::hist`], via detached
//! instruments): post-hoc reports and `metrics_text()` exposition can
//! never disagree about what "p99" means. Quantiles therefore carry
//! the log-linear buckets' bounded relative error (< ~2%) instead of
//! being exact order statistics.
//!
//! [`QueryStats`]: crate::index::query::QueryStats

use super::protocol::Response;
use crate::index::query::QueryStats;
use crate::obs::hist::HistSnapshot;
use crate::obs::metrics::NANOS;
use crate::obs::registry::Histogram;

/// p50/p99 of one per-query counter.
#[derive(Clone, Copy, Debug, Default)]
pub struct StatsPercentiles {
    pub p50: f64,
    pub p99: f64,
}

impl StatsPercentiles {
    fn of(s: &HistSnapshot) -> StatsPercentiles {
        StatsPercentiles {
            p50: s.p50(),
            p99: s.p99(),
        }
    }

    /// Same, scaled seconds -> milliseconds.
    fn of_ms(s: &HistSnapshot) -> StatsPercentiles {
        StatsPercentiles {
            p50: s.p50() * 1e3,
            p99: s.p99() * 1e3,
        }
    }
}

/// p50/p99 of each per-stage latency, milliseconds (zeros when the
/// engine ran with telemetry disabled — the stages weren't timed).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageSummary {
    /// batcher queue wait
    pub queue: StatsPercentiles,
    /// per-request share of the batched projection matmul
    pub project: StatsPercentiles,
    /// worker-side search (scatter + merge + rerank)
    pub search: StatsPercentiles,
    /// scatter-gather top-k merge
    pub merge: StatsPercentiles,
}

/// The served [`QueryStats`] distribution across one run.
///
/// [`QueryStats`]: crate::index::query::QueryStats
#[derive(Clone, Copy, Debug, Default)]
pub struct QueryStatsSummary {
    /// graph hops per query
    pub hops: StatsPercentiles,
    /// bytes of vector data read per query
    pub bytes_touched: StatsPercentiles,
    /// ids excluded by the request's filter predicate
    pub filtered: StatsPercentiles,
    /// tombstoned ids routed through (live indexes)
    pub deleted_skipped: StatsPercentiles,
    /// total tombstone skips across the run (a quick liveness signal)
    pub deleted_skipped_total: usize,
    /// every counter summed across the run ([`QueryStats::merge`] over
    /// all responses) — the same reduction the sharded scatter-gather
    /// applies per query, applied once more across the workload
    pub totals: QueryStats,
}

impl QueryStatsSummary {
    pub fn from_responses(responses: &[Response]) -> QueryStatsSummary {
        // detached instruments: the registry's histogram math without
        // the registry (always-on, never exposed)
        let hops = Histogram::detached(1.0);
        let bytes = Histogram::detached(1.0);
        let filtered = Histogram::detached(1.0);
        let deleted = Histogram::detached(1.0);
        let mut deleted_total = 0usize;
        let mut totals = QueryStats::default();
        for r in responses {
            hops.record(r.stats.hops as u64);
            bytes.record(r.stats.bytes_touched as u64);
            filtered.record(r.stats.filtered as u64);
            deleted.record(r.stats.deleted_skipped as u64);
            // saturating: a soak's running total pins at usize::MAX
            // instead of wrapping into a nonsense small number
            deleted_total = deleted_total.saturating_add(r.stats.deleted_skipped);
            totals.merge(&r.stats);
        }
        QueryStatsSummary {
            hops: StatsPercentiles::of(&hops.snapshot()),
            bytes_touched: StatsPercentiles::of(&bytes.snapshot()),
            filtered: StatsPercentiles::of(&filtered.snapshot()),
            deleted_skipped: StatsPercentiles::of(&deleted.snapshot()),
            deleted_skipped_total: deleted_total,
            totals,
        }
    }
}

/// Aggregated serving metrics.
#[derive(Clone, Debug)]
pub struct Metrics {
    pub queries: usize,
    pub wall_seconds: f64,
    pub qps: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub latency_p999_ms: f64,
    pub latency_mean_ms: f64,
    pub mean_batch: f64,
    /// where the latency went, stage by stage (zeros when telemetry
    /// was off during the run)
    pub stages: StageSummary,
    /// per-query traversal accounting, aggregated (not dropped)
    pub query_stats: QueryStatsSummary,
}

impl Metrics {
    pub fn from_responses(responses: &[Response], wall_seconds: f64) -> Metrics {
        let lat = Histogram::detached(NANOS);
        let queue = Histogram::detached(NANOS);
        let project = Histogram::detached(NANOS);
        let search = Histogram::detached(NANOS);
        let merge = Histogram::detached(NANOS);
        let mut batch = 0.0f64;
        for r in responses {
            lat.record_seconds(r.latency_s);
            queue.record_seconds(r.stages.queue_s);
            project.record_seconds(r.stages.project_s);
            search.record_seconds(r.stages.search_s);
            merge.record_seconds(r.stages.merge_s);
            batch += r.batch_size as f64;
        }
        let n = responses.len();
        let ls = lat.snapshot();
        Metrics {
            queries: n,
            wall_seconds,
            qps: if wall_seconds > 0.0 {
                n as f64 / wall_seconds
            } else {
                0.0
            },
            latency_p50_ms: ls.p50() * 1e3,
            latency_p99_ms: ls.p99() * 1e3,
            latency_p999_ms: ls.p999() * 1e3,
            latency_mean_ms: ls.mean() * 1e3,
            mean_batch: if n > 0 { batch / n as f64 } else { 0.0 },
            stages: StageSummary {
                queue: StatsPercentiles::of_ms(&queue.snapshot()),
                project: StatsPercentiles::of_ms(&project.snapshot()),
                search: StatsPercentiles::of_ms(&search.snapshot()),
                merge: StatsPercentiles::of_ms(&merge.snapshot()),
            },
            query_stats: QueryStatsSummary::from_responses(responses),
        }
    }
}

impl std::fmt::Display for Metrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let qs = &self.query_stats;
        let st = &self.stages;
        write!(
            f,
            "{} queries in {:.3}s -> {:.0} QPS | lat p50 {:.3} ms p99 {:.3} ms p999 {:.3} ms \
             | mean batch {:.1}\n\
             stages ms p50/p99: queue {:.3}/{:.3} project {:.3}/{:.3} search {:.3}/{:.3} \
             merge {:.3}/{:.3}\n\
             per-query: hops p50 {:.0} p99 {:.0} | bytes p50 {:.0} p99 {:.0} | \
             filtered p50 {:.0} p99 {:.0} | deleted-skipped p50 {:.0} p99 {:.0} (total {})",
            self.queries,
            self.wall_seconds,
            self.qps,
            self.latency_p50_ms,
            self.latency_p99_ms,
            self.latency_p999_ms,
            self.mean_batch,
            st.queue.p50,
            st.queue.p99,
            st.project.p50,
            st.project.p99,
            st.search.p50,
            st.search.p99,
            st.merge.p50,
            st.merge.p99,
            qs.hops.p50,
            qs.hops.p99,
            qs.bytes_touched.p50,
            qs.bytes_touched.p99,
            qs.filtered.p50,
            qs.filtered.p99,
            qs.deleted_skipped.p50,
            qs.deleted_skipped.p99,
            qs.deleted_skipped_total
        )
    }
}

/// Full report for one serve run: metrics + recall vs ground truth.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub metrics: Metrics,
    pub recall_at_k: f64,
    pub k: usize,
}

impl ServeReport {
    /// Compute recall by matching response ids against per-query truth.
    /// `truth[i]` corresponds to the request with `id == i`.
    pub fn new(responses: &[Response], truth: &[Vec<u32>], k: usize, wall_seconds: f64) -> ServeReport {
        let mut hits = 0usize;
        let mut total = 0usize;
        for r in responses {
            let t = &truth[r.id as usize];
            let tk = &t[..k.min(t.len())];
            hits += r.ids.iter().take(k).filter(|id| tk.contains(id)).count();
            total += k;
        }
        ServeReport {
            metrics: Metrics::from_responses(responses, wall_seconds),
            recall_at_k: if total > 0 {
                hits as f64 / total as f64
            } else {
                0.0
            },
            k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, ids: Vec<u32>, lat: f64, batch: usize) -> Response {
        Response {
            id,
            scores: vec![0.0; ids.len()],
            ids,
            stats: crate::index::query::QueryStats::default(),
            latency_s: lat,
            batch_size: batch,
            stages: crate::coordinator::protocol::StageTimes {
                queue_s: lat * 0.25,
                project_s: lat * 0.05,
                search_s: lat * 0.6,
                merge_s: lat * 0.1,
            },
            error: None,
            degraded: false,
            shards_failed: 0,
            partial: false,
        }
    }

    fn resp_with_stats(id: u64, hops: usize, bytes: usize, deleted: usize) -> Response {
        let mut r = resp(id, vec![1], 0.001, 1);
        r.stats.hops = hops;
        r.stats.bytes_touched = bytes;
        r.stats.deleted_skipped = deleted;
        r
    }

    #[test]
    fn query_stats_aggregate_as_percentiles() {
        let rs: Vec<Response> = (0..100)
            .map(|i| resp_with_stats(i, i as usize, 1000 * i as usize, if i < 10 { 3 } else { 0 }))
            .collect();
        let m = Metrics::from_responses(&rs, 1.0);
        let qs = m.query_stats;
        assert!(qs.hops.p50 > 40.0 && qs.hops.p50 < 60.0, "{:?}", qs.hops);
        assert!(qs.hops.p99 > qs.hops.p50);
        assert!(qs.bytes_touched.p99 > 90_000.0);
        assert_eq!(qs.deleted_skipped_total, 30);
        assert_eq!(qs.filtered.p99, 0.0);
        // the merged totals agree with a hand sum over the responses
        assert_eq!(qs.totals.hops, (0..100).sum::<usize>());
        assert_eq!(qs.totals.bytes_touched, (0..100).map(|i| 1000 * i).sum::<usize>());
        assert_eq!(qs.totals.deleted_skipped, 30);
        assert_eq!(qs.totals.filtered, 0);
        // the Display line carries the aggregates
        let text = format!("{m}");
        assert!(text.contains("deleted-skipped"), "{text}");
    }

    #[test]
    fn metrics_aggregate() {
        let rs = vec![
            resp(0, vec![1], 0.001, 2),
            resp(1, vec![2], 0.003, 2),
            resp(2, vec![3], 0.002, 4),
        ];
        let m = Metrics::from_responses(&rs, 0.5);
        assert_eq!(m.queries, 3);
        assert!((m.qps - 6.0).abs() < 1e-9);
        // histogram quantiles carry the buckets' ~2% relative error
        assert!((m.latency_p50_ms - 2.0).abs() < 0.05, "{}", m.latency_p50_ms);
        assert!((m.latency_p99_ms - 3.0).abs() < 0.08, "{}", m.latency_p99_ms);
        // p999 of 3 samples is the max
        assert!((m.latency_p999_ms - 3.0).abs() < 0.08, "{}", m.latency_p999_ms);
        // the mean uses the exact recorded sum, not bucket midpoints
        assert!((m.latency_mean_ms - 2.0).abs() < 1e-3, "{}", m.latency_mean_ms);
        assert!((m.mean_batch - 8.0 / 3.0).abs() < 1e-9);
        // stage percentiles ride the same histogram path (search =
        // 60% of e2e in the fixture)
        assert!(
            (m.stages.search.p50 - 1.2).abs() < 0.05,
            "{:?}",
            m.stages.search
        );
        assert!(m.stages.queue.p99 > m.stages.queue.p50 - 1e-9);
        let text = format!("{m}");
        assert!(text.contains("stages ms"), "{text}");
        assert!(text.contains("p999"), "{text}");
    }

    #[test]
    fn deleted_skipped_total_saturates() {
        // regression: two huge per-response counts must pin at
        // usize::MAX, not wrap around into a small number
        let mut a = resp(0, vec![1], 0.001, 1);
        a.stats.deleted_skipped = usize::MAX - 5;
        let mut b = resp(1, vec![2], 0.001, 1);
        b.stats.deleted_skipped = 100;
        let qs = QueryStatsSummary::from_responses(&[a, b]);
        assert_eq!(qs.deleted_skipped_total, usize::MAX);
        assert_eq!(qs.totals.deleted_skipped, usize::MAX, "merge saturates too");
    }

    #[test]
    fn report_recall() {
        let truth = vec![vec![1u32, 2], vec![3u32, 4]];
        let rs = vec![
            resp(0, vec![1, 9], 0.001, 1),
            resp(1, vec![3, 4], 0.001, 1),
        ];
        let rep = ServeReport::new(&rs, &truth, 2, 1.0);
        assert!((rep.recall_at_k - 0.75).abs() < 1e-9);
    }

    #[test]
    fn empty_responses() {
        let m = Metrics::from_responses(&[], 1.0);
        assert_eq!(m.queries, 0);
        assert_eq!(m.qps, 0.0);
    }
}
