//! Serving metrics: QPS, latency percentiles, recall.

use super::protocol::Response;
use crate::util::stats::Summary;

/// Aggregated serving metrics.
#[derive(Clone, Debug)]
pub struct Metrics {
    pub queries: usize,
    pub wall_seconds: f64,
    pub qps: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub latency_mean_ms: f64,
    pub mean_batch: f64,
}

impl Metrics {
    pub fn from_responses(responses: &[Response], wall_seconds: f64) -> Metrics {
        let mut lat = Summary::new();
        let mut batch = 0.0f64;
        for r in responses {
            lat.push(r.latency_s * 1e3);
            batch += r.batch_size as f64;
        }
        let n = responses.len();
        Metrics {
            queries: n,
            wall_seconds,
            qps: if wall_seconds > 0.0 {
                n as f64 / wall_seconds
            } else {
                0.0
            },
            latency_p50_ms: lat.p50(),
            latency_p99_ms: lat.p99(),
            latency_mean_ms: lat.mean(),
            mean_batch: if n > 0 { batch / n as f64 } else { 0.0 },
        }
    }
}

impl std::fmt::Display for Metrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} queries in {:.3}s -> {:.0} QPS | lat p50 {:.3} ms p99 {:.3} ms | mean batch {:.1}",
            self.queries,
            self.wall_seconds,
            self.qps,
            self.latency_p50_ms,
            self.latency_p99_ms,
            self.mean_batch
        )
    }
}

/// Full report for one serve run: metrics + recall vs ground truth.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub metrics: Metrics,
    pub recall_at_k: f64,
    pub k: usize,
}

impl ServeReport {
    /// Compute recall by matching response ids against per-query truth.
    /// `truth[i]` corresponds to the request with `id == i`.
    pub fn new(responses: &[Response], truth: &[Vec<u32>], k: usize, wall_seconds: f64) -> ServeReport {
        let mut hits = 0usize;
        let mut total = 0usize;
        for r in responses {
            let t = &truth[r.id as usize];
            let tk = &t[..k.min(t.len())];
            hits += r.ids.iter().take(k).filter(|id| tk.contains(id)).count();
            total += k;
        }
        ServeReport {
            metrics: Metrics::from_responses(responses, wall_seconds),
            recall_at_k: if total > 0 {
                hits as f64 / total as f64
            } else {
                0.0
            },
            k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, ids: Vec<u32>, lat: f64, batch: usize) -> Response {
        Response {
            id,
            scores: vec![0.0; ids.len()],
            ids,
            stats: crate::index::query::QueryStats::default(),
            latency_s: lat,
            batch_size: batch,
        }
    }

    #[test]
    fn metrics_aggregate() {
        let rs = vec![
            resp(0, vec![1], 0.001, 2),
            resp(1, vec![2], 0.003, 2),
            resp(2, vec![3], 0.002, 4),
        ];
        let m = Metrics::from_responses(&rs, 0.5);
        assert_eq!(m.queries, 3);
        assert!((m.qps - 6.0).abs() < 1e-9);
        assert!((m.latency_p50_ms - 2.0).abs() < 1e-9);
        assert!((m.mean_batch - 8.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn report_recall() {
        let truth = vec![vec![1u32, 2], vec![3u32, 4]];
        let rs = vec![
            resp(0, vec![1, 9], 0.001, 1),
            resp(1, vec![3, 4], 0.001, 1),
        ];
        let rep = ServeReport::new(&rs, &truth, 2, 1.0);
        assert!((rep.recall_at_k - 0.75).abs() < 1e-9);
    }

    #[test]
    fn empty_responses() {
        let m = Metrics::from_responses(&[], 1.0);
        assert_eq!(m.queries, 0);
        assert_eq!(m.qps, 0.0);
    }
}
