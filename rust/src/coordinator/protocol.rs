//! Request/response types for the serving engine.

use super::engine::EngineError;
use crate::index::query::QueryStats;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Instant;

/// Per-request search knobs: what a client may override on top of the
/// engine-wide [`SearchParams`] defaults. Owned (no borrows) so it can
/// travel through channels to the worker pool.
///
/// [`SearchParams`]: crate::index::leanvec_index::SearchParams
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct QuerySpec {
    /// results to return
    pub k: usize,
    /// greedy-search window override (engine default when `None`)
    pub window: Option<usize>,
    /// re-rank buffer override; may exceed `window` (split buffer)
    pub rerank_window: Option<usize>,
    /// allow-list filter: when set, only these ids may be returned
    /// (the worker reads it as a predicate pushed into traversal).
    /// `Arc` so a tenant's (possibly large) allow-set is hashed once —
    /// at spec construction — and shared across every request and
    /// worker that uses it, never rebuilt per query.
    pub allow: Option<Arc<HashSet<u32>>>,
    /// collection this request targets; `None` routes to
    /// [`DEFAULT_COLLECTION`](crate::shard::DEFAULT_COLLECTION).
    /// `Arc` so a tenant's requests share one allocation of the name.
    pub collection: Option<Arc<str>>,
    /// request deadline, milliseconds from submission. `None` (the
    /// default) means no deadline. An expired request is shed in the
    /// batcher queue or cancelled mid-search; either way it resolves to
    /// [`EngineError::DeadlineExceeded`] — or, with
    /// [`QuerySpec::allow_partial`], to whatever results traversal had
    /// accumulated when the deadline tripped.
    pub timeout_ms: Option<u64>,
    /// when the deadline trips mid-search, return the partial results
    /// gathered so far (marked [`Response::partial`]) instead of
    /// [`EngineError::DeadlineExceeded`]
    pub allow_partial: bool,
}

impl QuerySpec {
    /// A plain top-k spec with engine-default knobs.
    pub fn top_k(k: usize) -> QuerySpec {
        QuerySpec {
            k,
            ..QuerySpec::default()
        }
    }

    pub fn with_window(mut self, window: usize) -> QuerySpec {
        self.window = Some(window);
        self
    }

    pub fn with_rerank_window(mut self, rerank_window: usize) -> QuerySpec {
        self.rerank_window = Some(rerank_window);
        self
    }

    /// Restrict results to `ids` (allow-list filtered search). Builds
    /// the lookup set once; reuse the spec (or
    /// [`QuerySpec::with_allow_set`]) to share it across requests.
    pub fn with_allow_list(self, ids: Vec<u32>) -> QuerySpec {
        self.with_allow_set(Arc::new(ids.into_iter().collect()))
    }

    /// Restrict results to a pre-built shared allow-set.
    pub fn with_allow_set(mut self, ids: Arc<HashSet<u32>>) -> QuerySpec {
        self.allow = Some(ids);
        self
    }

    /// Give this request `ms` milliseconds from submission; past that
    /// it resolves to [`EngineError::DeadlineExceeded`] (or a partial
    /// answer under [`QuerySpec::with_allow_partial`]).
    pub fn with_timeout_ms(mut self, ms: u64) -> QuerySpec {
        self.timeout_ms = Some(ms);
        self
    }

    /// On a mid-search deadline miss, return the partial results
    /// accumulated so far instead of an error.
    pub fn with_allow_partial(mut self) -> QuerySpec {
        self.allow_partial = true;
        self
    }

    /// Route this request to a named collection instead of the default.
    pub fn with_collection(mut self, name: impl AsRef<str>) -> QuerySpec {
        self.collection = Some(Arc::from(name.as_ref()));
        self
    }

    /// The collection name this spec routes to.
    pub fn collection_name(&self) -> &str {
        self.collection
            .as_deref()
            .unwrap_or(crate::shard::DEFAULT_COLLECTION)
    }
}

/// One mutation for the live engine's ingest lane
/// ([`Engine::submit_insert`] / [`Engine::submit_delete`]): applied in
/// submission order by the ingest worker, interleaved with — never
/// blocking — the search workers.
///
/// [`Engine::submit_insert`]: crate::coordinator::Engine::submit_insert
/// [`Engine::submit_delete`]: crate::coordinator::Engine::submit_delete
#[derive(Clone, Debug)]
pub enum Mutation {
    /// Insert `vector` under the caller's external id.
    Insert { ext_id: u32, vector: Vec<f32> },
    /// Tombstone the vector with this external id.
    Delete { ext_id: u32 },
}

/// One similarity-search request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub query: Vec<f32>,
    /// per-request knobs (k + overrides + optional filter)
    pub spec: QuerySpec,
    /// submission timestamp (set by `Engine::submit`)
    pub submitted: Option<Instant>,
}

impl Request {
    pub fn new(id: u64, query: Vec<f32>, k: usize) -> Request {
        Request::with_spec(id, query, QuerySpec::top_k(k))
    }

    pub fn with_spec(id: u64, query: Vec<f32>, spec: QuerySpec) -> Request {
        Request {
            id,
            query,
            spec,
            submitted: None,
        }
    }
}

/// Per-stage wall times for one request's trip through the engine,
/// seconds. All zeros when telemetry is disabled
/// (`LEANVEC_NO_TELEMETRY=1`) — the engine skips the clock reads.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageTimes {
    /// waiting in the batcher queue (submit -> dequeue)
    pub queue_s: f64,
    /// this request's share of its batch group's projection matmul
    pub project_s: f64,
    /// worker-side search (scatter + merge + rerank)
    pub search_s: f64,
    /// the top-k merge step of the scatter-gather (0 when unsharded)
    pub merge_s: f64,
}

/// The engine's answer. Every admitted request produces exactly one
/// `Response` — including requests that fail after admission (deadline
/// missed in queue or mid-search), which arrive with [`Response::error`]
/// set and empty results, so a drain loop never hangs counting
/// responses that will not come.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub ids: Vec<u32>,
    pub scores: Vec<f32>,
    /// per-query traffic accounting (observability: bytes touched,
    /// hops, filtered count — mirrors what direct search returns)
    pub stats: QueryStats,
    /// end-to-end latency (submit -> response ready), seconds
    pub latency_s: f64,
    /// batch this request was served in (observability)
    pub batch_size: usize,
    /// where the latency went (observability; zeros when telemetry off)
    pub stages: StageTimes,
    /// why the request failed after admission (`None` on success; a
    /// degraded or partial answer is a success with its flag set)
    pub error: Option<EngineError>,
    /// one or more shards failed to contribute to this answer
    pub degraded: bool,
    /// how many shards failed to contribute (0 on a clean answer)
    pub shards_failed: usize,
    /// results are partial: the deadline tripped mid-search and the
    /// request opted into [`QuerySpec::allow_partial`]
    pub partial: bool,
}

impl Response {
    /// Whether this response carries usable results (possibly degraded
    /// or partial, never an error).
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_fields() {
        let r = Request::new(7, vec![1.0, 2.0], 10);
        assert_eq!(r.id, 7);
        assert_eq!(r.spec.k, 10);
        assert_eq!(r.spec.window, None);
        assert!(r.submitted.is_none());
    }

    #[test]
    fn spec_builder_accumulates() {
        let s = QuerySpec::top_k(5)
            .with_window(40)
            .with_rerank_window(120)
            .with_allow_list(vec![1, 2, 3]);
        assert_eq!(s.k, 5);
        assert_eq!(s.window, Some(40));
        assert_eq!(s.rerank_window, Some(120), "split buffer travels");
        let allow = s.allow.clone().unwrap();
        assert_eq!(allow.len(), 3);
        assert!(allow.contains(&2) && !allow.contains(&4));
        assert_eq!(s.collection_name(), crate::shard::DEFAULT_COLLECTION);
        let s = s.with_collection("tenant-b");
        assert_eq!(s.collection_name(), "tenant-b");
    }

    #[test]
    fn deadline_knobs_default_off_and_accumulate() {
        let s = QuerySpec::top_k(3);
        assert_eq!(s.timeout_ms, None, "no deadline unless asked for");
        assert!(!s.allow_partial);
        let s = s.with_timeout_ms(25).with_allow_partial();
        assert_eq!(s.timeout_ms, Some(25));
        assert!(s.allow_partial);
    }
}
