//! Request/response types for the serving engine.

use std::time::Instant;

/// One similarity-search request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub query: Vec<f32>,
    pub k: usize,
    /// submission timestamp (set by `Engine::submit`)
    pub submitted: Option<Instant>,
}

impl Request {
    pub fn new(id: u64, query: Vec<f32>, k: usize) -> Request {
        Request {
            id,
            query,
            k,
            submitted: None,
        }
    }
}

/// The engine's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub ids: Vec<u32>,
    pub scores: Vec<f32>,
    /// end-to-end latency (submit -> response ready), seconds
    pub latency_s: f64,
    /// batch this request was served in (observability)
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_fields() {
        let r = Request::new(7, vec![1.0, 2.0], 10);
        assert_eq!(r.id, 7);
        assert_eq!(r.k, 10);
        assert!(r.submitted.is_none());
    }
}
