//! Dynamic batching policy: collect requests until the batch is full or
//! the oldest request has waited long enough.
//!
//! Batches are deliberately **spec-heterogeneous**: the only operation
//! performed at batch granularity is the query projection (one matmul),
//! which does not depend on any per-request knob, so requests with
//! different `QuerySpec`s (k, window/rerank overrides, allow-list
//! filters) batch together freely — grouping by spec would only shrink
//! batches and hurt the amortization. Per-request knobs are honored
//! downstream, where they matter: each worker resolves its item's spec
//! against the engine defaults before searching.

use super::protocol::Request;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_micros(500),
        }
    }
}

/// Pulls requests off a channel according to the policy.
pub struct Batcher {
    policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy }
    }

    /// Block for the next batch. Returns `None` when the channel is
    /// closed and drained (shutdown).
    pub fn next_batch(&self, rx: &Receiver<Request>) -> Option<Vec<Request>> {
        // block for the first request
        // DEADLINE: this is the batcher's idle state — there is nothing
        // to do until a request exists; shutdown closes the channel,
        // which wakes this with Err.
        let first = rx.recv().ok()?;
        let deadline = Instant::now() + self.policy.max_wait;
        let mut batch = vec![first];
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0.0], 1)
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn full_batch_returned_immediately() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let b = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
        });
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn timeout_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(1)).unwrap();
        tx.send(req(2)).unwrap();
        let b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        });
        let t0 = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn closed_empty_channel_yields_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let b = Batcher::new(BatchPolicy::default());
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn disconnect_mid_wait_flushes() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(1)).unwrap();
        drop(tx);
        let b = Batcher::new(BatchPolicy {
            max_batch: 10,
            max_wait: Duration::from_secs(5),
        });
        let t0 = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }
}
