//! The serving engine: batcher thread + worker pool over a shared
//! index — a frozen [`LeanVecIndex`], or a [`LiveIndex`] with an
//! **ingest lane**: a dedicated mutation worker that applies streaming
//! inserts/deletes interleaved with (never blocking) the search
//! workers, and runs tombstone consolidation off the hot path when the
//! tombstone fraction crosses [`EngineConfig::consolidate_threshold`].

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::{Metrics, ServeReport};
use super::protocol::{Mutation, QuerySpec, Request, Response};
use crate::index::leanvec_index::{LeanVecIndex, SearchParams};
use crate::index::query::{Query, SearchResult};
use crate::graph::beam::SearchCtx;
use crate::leanvec::model::{rows_to_matrix, LeanVecModel};
use crate::linalg::Matrix;
use crate::mutate::LiveIndex;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// The index a running engine serves: frozen or live. Workers and the
/// batcher are generic over this, so the live path reuses the whole
/// batching/projection/worker machinery.
#[derive(Clone)]
enum ServeIndex {
    Frozen(Arc<LeanVecIndex>),
    Live(Arc<LiveIndex>),
}

impl ServeIndex {
    fn model(&self) -> &LeanVecModel {
        match self {
            ServeIndex::Frozen(ix) => &ix.model,
            ServeIndex::Live(ix) => ix.model(),
        }
    }

    fn len(&self) -> usize {
        match self {
            ServeIndex::Frozen(ix) => ix.len(),
            ServeIndex::Live(ix) => ix.total_slots(),
        }
    }

    fn search_prepared(
        &self,
        ctx: &mut SearchCtx,
        q_proj: &[f32],
        query: &Query,
    ) -> SearchResult {
        match self {
            ServeIndex::Frozen(ix) => ix.search_prepared(ctx, q_proj, query),
            ServeIndex::Live(ix) => ix.search_prepared(ctx, q_proj, query),
        }
    }
}

/// Ingest-lane counters (atomics: the lane runs on its own thread).
#[derive(Debug, Default)]
pub struct IngestStats {
    pub inserts: AtomicUsize,
    pub deletes: AtomicUsize,
    /// rejected mutations (duplicate/unknown id, dimension mismatch)
    pub errors: AtomicUsize,
    pub consolidations: AtomicUsize,
    /// total wall-clock nanoseconds spent consolidating
    pub consolidate_nanos: AtomicU64,
}

/// A plain-value copy of [`IngestStats`] for reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct IngestSnapshot {
    pub inserts: usize,
    pub deletes: usize,
    pub errors: usize,
    pub consolidations: usize,
    pub consolidate_seconds: f64,
}

impl IngestStats {
    pub fn snapshot(&self) -> IngestSnapshot {
        IngestSnapshot {
            inserts: self.inserts.load(Ordering::Relaxed),
            deletes: self.deletes.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            consolidations: self.consolidations.load(Ordering::Relaxed),
            consolidate_seconds: self.consolidate_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

/// How the batcher projects query batches.
#[derive(Clone, Debug)]
pub enum QueryProjectorKind {
    /// native matmul on the batcher thread
    Native,
    /// PJRT `project_q` artifact from this directory (the runtime is
    /// constructed *on the batcher thread* — PJRT handles are not Send)
    Pjrt(std::path::PathBuf),
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
    pub search: SearchParams,
    pub projector: QueryProjectorKind,
    /// Live engines only: tombstone fraction at which the ingest lane
    /// runs a consolidation pass (after applying a mutation, off the
    /// search hot path). `<= 0` disables the tombstone-fraction
    /// trigger; the pending-insert-log memory bound still folds the
    /// journal regardless.
    pub consolidate_threshold: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            batch: BatchPolicy::default(),
            search: SearchParams::default(),
            projector: QueryProjectorKind::Native,
            consolidate_threshold: 0.2,
        }
    }
}

/// A running engine. Submit requests, then `drain` responses; live
/// engines additionally accept mutations
/// ([`Engine::submit_insert`]/[`Engine::submit_delete`]) on the ingest
/// lane.
pub struct Engine {
    req_tx: Option<Sender<Request>>,
    resp_rx: Receiver<Response>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    // ingest lane (live engines only)
    mut_tx: Option<Sender<Mutation>>,
    ingest: Option<JoinHandle<()>>,
    ingest_stats: Arc<IngestStats>,
    live: Option<Arc<LiveIndex>>,
    next_id: AtomicU64,
    started: Instant,
}

/// Work item: one request plus its projected query.
struct WorkItem {
    req: Request,
    q_proj: Vec<f32>,
    batch_size: usize,
}

impl Engine {
    /// Start a serving engine directly from an on-disk index snapshot
    /// (see `crate::index::persist`): the build/serve split. The
    /// training, projection and graph-construction paths are never
    /// touched — the process goes from snapshot bytes to answering
    /// queries.
    ///
    /// `cfg` receives the snapshot's metadata before the engine starts,
    /// so the recommended serving parameters it carries are usable:
    ///
    /// ```ignore
    /// let (engine, _meta) = Engine::start_from_snapshot(path, |meta| EngineConfig {
    ///     search: meta.search_defaults,
    ///     ..EngineConfig::default()
    /// })?;
    /// ```
    pub fn start_from_snapshot<F>(
        path: &std::path::Path,
        cfg: F,
    ) -> Result<(Engine, crate::index::persist::SnapshotMeta), crate::index::persist::SnapshotError>
    where
        F: FnOnce(&crate::index::persist::SnapshotMeta) -> EngineConfig,
    {
        let (index, meta) = LeanVecIndex::load(path)?;
        let cfg = cfg(&meta);
        Ok((Engine::start(Arc::new(index), cfg), meta))
    }

    pub fn start(index: Arc<LeanVecIndex>, cfg: EngineConfig) -> Engine {
        Engine::start_serve(ServeIndex::Frozen(index), cfg)
    }

    /// Start a **live** engine over a mutable index: the same
    /// batcher/worker pipeline as [`Engine::start`], plus an ingest
    /// lane — one mutation thread draining
    /// [`Engine::submit_insert`]/[`Engine::submit_delete`] in
    /// submission order, concurrently with the search workers (no
    /// global lock: searches hold read guards, mutations write briefly).
    /// After each mutation the lane checks the tombstone fraction and
    /// runs [`LiveIndex::consolidate`] when it crosses
    /// [`EngineConfig::consolidate_threshold`] — off the search path.
    pub fn start_live(live: Arc<LiveIndex>, cfg: EngineConfig) -> Engine {
        let threshold = cfg.consolidate_threshold;
        let mut engine = Engine::start_serve(ServeIndex::Live(Arc::clone(&live)), cfg);
        let (mut_tx, mut_rx) = channel::<Mutation>();
        let stats = Arc::clone(&engine.ingest_stats);
        let ilive = Arc::clone(&live);
        let ingest = std::thread::Builder::new()
            .name("leanvec-ingest".into())
            .spawn(move || {
                ingest_loop(ilive, mut_rx, stats, threshold);
            })
            .expect("spawn ingest");
        engine.mut_tx = Some(mut_tx);
        engine.ingest = Some(ingest);
        engine.live = Some(live);
        engine
    }

    fn start_serve(index: ServeIndex, cfg: EngineConfig) -> Engine {
        let (req_tx, req_rx) = channel::<Request>();
        let (work_tx, work_rx) = channel::<WorkItem>();
        let (resp_tx, resp_rx) = channel::<Response>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        // --- batcher thread: batch, project, fan out
        let bindex = index.clone();
        let bcfg = cfg.clone();
        let batcher = std::thread::Builder::new()
            .name("leanvec-batcher".into())
            .spawn(move || {
                batcher_loop(bindex, bcfg, req_rx, work_tx);
            })
            .expect("spawn batcher");

        // --- workers: search + rerank
        let workers = (0..cfg.workers.max(1))
            .map(|w| {
                let windex = index.clone();
                let wrx = Arc::clone(&work_rx);
                let wtx = resp_tx.clone();
                let search = cfg.search;
                std::thread::Builder::new()
                    .name(format!("leanvec-search-{w}"))
                    .spawn(move || {
                        let mut ctx = SearchCtx::new(windex.len());
                        loop {
                            let item = { wrx.lock().unwrap().recv() };
                            let item = match item {
                                Ok(i) => i,
                                Err(_) => break,
                            };
                            // per-request spec wins over the engine-wide
                            // defaults; the allow-list becomes a filter
                            // predicate pushed into traversal
                            let result = {
                                let spec = &item.req.spec;
                                let params = resolve_spec(spec, search);
                                let base = Query::new(&item.req.query)
                                    .k(spec.k)
                                    .window(params.window)
                                    .rerank_window(params.rerank_window);
                                match spec.allow.as_ref() {
                                    // the set was built once at spec
                                    // construction; here it is only read
                                    Some(allow) => {
                                        let pred = |id: u32| allow.contains(&id);
                                        windex.search_prepared(
                                            &mut ctx,
                                            &item.q_proj,
                                            &base.filter(&pred),
                                        )
                                    }
                                    None => windex.search_prepared(
                                        &mut ctx,
                                        &item.q_proj,
                                        &base,
                                    ),
                                }
                            };
                            let latency_s = item
                                .req
                                .submitted
                                .map(|t| t.elapsed().as_secs_f64())
                                .unwrap_or(0.0);
                            let _ = wtx.send(Response {
                                id: item.req.id,
                                ids: result.ids,
                                scores: result.scores,
                                stats: result.stats,
                                latency_s,
                                batch_size: item.batch_size,
                            });
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();

        Engine {
            req_tx: Some(req_tx),
            resp_rx,
            batcher: Some(batcher),
            workers,
            mut_tx: None,
            ingest: None,
            ingest_stats: Arc::new(IngestStats::default()),
            live: None,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// Submit one query with engine-default knobs; returns its request
    /// id.
    pub fn submit(&self, query: Vec<f32>, k: usize) -> u64 {
        self.submit_spec(query, QuerySpec::top_k(k))
    }

    /// Submit one query with per-request knobs (window / rerank-window
    /// overrides, allow-list filter); returns its request id.
    pub fn submit_spec(&self, query: Vec<f32>, spec: QuerySpec) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request::with_spec(id, query, spec);
        req.submitted = Some(Instant::now());
        self.req_tx
            .as_ref()
            .expect("engine running")
            .send(req)
            .expect("batcher alive");
        id
    }

    /// Enqueue an insert on the ingest lane (live engines only; panics
    /// on an engine started with [`Engine::start`]). Applied
    /// asynchronously, in submission order, concurrently with searches.
    pub fn submit_insert(&self, ext_id: u32, vector: Vec<f32>) {
        self.mut_tx
            .as_ref()
            .expect("mutations need a live engine (Engine::start_live)")
            .send(Mutation::Insert { ext_id, vector })
            .expect("ingest alive");
    }

    /// Enqueue a delete on the ingest lane (live engines only; panics
    /// on an engine started with [`Engine::start`]).
    pub fn submit_delete(&self, ext_id: u32) {
        self.mut_tx
            .as_ref()
            .expect("mutations need a live engine (Engine::start_live)")
            .send(Mutation::Delete { ext_id })
            .expect("ingest alive");
    }

    /// Ingest-lane counters (zeros on a frozen engine).
    pub fn ingest_stats(&self) -> IngestSnapshot {
        self.ingest_stats.snapshot()
    }

    /// The live index this engine serves, if started with
    /// [`Engine::start_live`].
    pub fn live_index(&self) -> Option<&Arc<LiveIndex>> {
        self.live.as_ref()
    }

    /// Block until every mutation submitted so far has been applied:
    /// closes the ingest lane and joins the ingest worker. Searches are
    /// unaffected; further `submit_insert`/`submit_delete` calls panic.
    pub fn quiesce_mutations(&mut self) {
        drop(self.mut_tx.take());
        if let Some(h) = self.ingest.take() {
            let _ = h.join();
        }
    }

    /// Blockingly collect `n` responses.
    pub fn drain(&self, n: usize) -> Vec<Response> {
        (0..n)
            .map(|_| self.resp_rx.recv().expect("workers alive"))
            .collect()
    }

    /// Stop accepting requests, join all threads. Pending mutations are
    /// applied before the ingest lane joins.
    pub fn shutdown(mut self) -> Vec<Response> {
        self.quiesce_mutations();
        drop(self.req_tx.take());
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // collect any leftover responses
        let mut rest = Vec::new();
        while let Ok(r) = self.resp_rx.try_recv() {
            rest.push(r);
        }
        rest
    }

    pub fn uptime(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Direct parallel batch path (no channels): project the whole
    /// batch as ONE matmul on the calling thread — the same
    /// amortization the batcher thread performs — then fan the searches
    /// out across `workers` threads with pooled contexts, the same
    /// chunking discipline as the parallel index builder. Returns
    /// `(ids, scores)` per query, in query order, identical to serial
    /// per-query trait searches for every worker count.
    pub fn run_batch_direct(
        index: &LeanVecIndex,
        queries: &[Vec<f32>],
        k: usize,
        params: SearchParams,
        workers: usize,
    ) -> Vec<(Vec<u32>, Vec<f32>)> {
        if queries.is_empty() {
            return Vec::new();
        }
        // batched projection: Q (B, D) x A^T -> (B, d)
        let qm = rows_to_matrix(queries);
        let proj: Matrix = qm.matmul_nt(&index.model.a);
        index.batch_fan_out(queries.len(), workers, |ctx, i| {
            let query = Query::new(&queries[i])
                .k(k)
                .window(params.window)
                .rerank_window(params.rerank_window);
            let r = index.search_prepared(ctx, proj.row(i), &query);
            (r.ids, r.scores)
        })
    }

    /// Convenience: run a closed-loop workload and report (used by the
    /// e2e example and the serving benches).
    pub fn run_workload(
        index: Arc<LeanVecIndex>,
        cfg: EngineConfig,
        queries: &[Vec<f32>],
        k: usize,
        truth: Option<&[Vec<u32>]>,
    ) -> (Vec<Response>, ServeReport) {
        let engine = Engine::start(index, cfg);
        let t0 = Instant::now();
        for q in queries {
            engine.submit(q.clone(), k);
        }
        let mut responses = engine.drain(queries.len());
        let wall = t0.elapsed().as_secs_f64();
        let mut leftovers = engine.shutdown();
        responses.append(&mut leftovers);
        responses.sort_by_key(|r| r.id);
        let report = match truth {
            Some(t) => ServeReport::new(&responses, t, k, wall),
            None => ServeReport {
                metrics: Metrics::from_responses(&responses, wall),
                recall_at_k: f64::NAN,
                k,
            },
        };
        (responses, report)
    }
}

/// Resolve a request's [`QuerySpec`] against the engine-wide defaults
/// via the one shared rule ([`crate::index::query::resolve_params`]).
/// The results are clamped to >= 1 so a malformed spec degrades
/// instead of panicking the worker.
fn resolve_spec(spec: &QuerySpec, defaults: SearchParams) -> SearchParams {
    let p = crate::index::query::resolve_params(spec.window, spec.rerank_window, defaults);
    SearchParams {
        window: p.window.max(1),
        rerank_window: p.rerank_window.max(1),
    }
}

fn batcher_loop(
    index: ServeIndex,
    cfg: EngineConfig,
    req_rx: Receiver<Request>,
    work_tx: Sender<WorkItem>,
) {
    let batcher = Batcher::new(cfg.batch);
    // PJRT runtime (if requested) must be constructed on this thread.
    let mut pjrt = match &cfg.projector {
        QueryProjectorKind::Pjrt(dir) => match crate::runtime::executor::open_shared(dir) {
            Ok(rt) => Some(crate::runtime::PjrtProjector::new(rt)),
            Err(e) => {
                eprintln!("engine: pjrt projector unavailable ({e}); using native");
                None
            }
        },
        QueryProjectorKind::Native => None,
    };

    while let Some(batch) = batcher.next_batch(&req_rx) {
        let bs = batch.len();
        // project the whole batch as one matmul: (d, D) x (D, B). The
        // projection model is frozen even on a live index, so batching
        // is mutation-oblivious.
        let queries: Vec<Vec<f32>> = batch.iter().map(|r| r.query.clone()).collect();
        let projected: Vec<Vec<f32>> = match pjrt.as_mut() {
            Some(p) => {
                use crate::index::builder::BatchProjector;
                p.project(&index.model().a, &queries)
            }
            None => {
                // single matmul on the batcher thread: Q (B, D) x A^T
                let qm = rows_to_matrix(&queries);
                let proj: Matrix = qm.matmul_nt(&index.model().a); // (B, d)
                (0..bs).map(|i| proj.row(i).to_vec()).collect()
            }
        };
        for (req, q_proj) in batch.into_iter().zip(projected.into_iter()) {
            if work_tx
                .send(WorkItem {
                    req,
                    q_proj,
                    batch_size: bs,
                })
                .is_err()
            {
                return;
            }
        }
    }
}

/// Pending-insert-log bound for the ingest lane: once this many inserts
/// accumulate since the last consolidation, the lane folds the log even
/// with zero tombstones (insert-only workloads must not grow the
/// journal — and every snapshot's MUTLOG section — without bound).
const INGEST_LOG_FOLD: usize = 65_536;

/// The ingest lane: apply mutations in submission order; rejections are
/// counted, never fatal. After each mutation, consolidate if the
/// tombstone fraction crossed the threshold (or the pending insert log
/// outgrew [`INGEST_LOG_FOLD`]) — this runs here, on the ingest thread,
/// so the search workers never pay for it (searches proceed
/// concurrently through the rewiring phase and block only for the
/// final compaction swap).
fn ingest_loop(
    live: Arc<LiveIndex>,
    mut_rx: Receiver<Mutation>,
    stats: Arc<IngestStats>,
    consolidate_threshold: f64,
) {
    while let Ok(m) = mut_rx.recv() {
        let applied = match m {
            Mutation::Insert { ext_id, vector } => match live.insert(ext_id, &vector) {
                Ok(_) => {
                    stats.inserts.fetch_add(1, Ordering::Relaxed);
                    true
                }
                Err(e) => {
                    eprintln!("ingest: {e}");
                    false
                }
            },
            Mutation::Delete { ext_id } => match live.delete(ext_id) {
                Ok(_) => {
                    stats.deletes.fetch_add(1, Ordering::Relaxed);
                    true
                }
                Err(e) => {
                    eprintln!("ingest: {e}");
                    false
                }
            },
        };
        if !applied {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        // the log-size bound is independent of the tombstone trigger: a
        // disabled threshold must not disable the memory bound
        let tombstones_due =
            consolidate_threshold > 0.0 && live.tombstone_fraction() >= consolidate_threshold;
        if tombstones_due || live.pending_inserts() >= INGEST_LOG_FOLD {
            let report = live.consolidate();
            stats.consolidations.fetch_add(1, Ordering::Relaxed);
            stats
                .consolidate_nanos
                .fetch_add((report.seconds * 1e9) as u64, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, ProjectionKind, Similarity};
    use crate::index::builder::IndexBuilder;
    use crate::index::query::VectorIndex;
    use crate::util::rng::Rng;

    fn build_index_sim(n: usize, dd: usize, d: usize, sim: Similarity) -> Arc<LeanVecIndex> {
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dd).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let mut gp = GraphParams::for_similarity(sim);
        gp.max_degree = 12;
        gp.build_window = 30;
        Arc::new(
            IndexBuilder::new()
                .projection(ProjectionKind::Id)
                .target_dim(d)
                .graph_params(gp)
                .build(&rows, None, sim),
        )
    }

    fn build_index(n: usize, dd: usize, d: usize) -> Arc<LeanVecIndex> {
        build_index_sim(n, dd, d, Similarity::InnerProduct)
    }

    #[test]
    fn serves_all_requests() {
        let index = build_index(300, 16, 8);
        let engine = Engine::start(
            index,
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
        );
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let q: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
            engine.submit(q, 5);
        }
        let responses = engine.drain(50);
        assert_eq!(responses.len(), 50);
        for r in &responses {
            assert_eq!(r.ids.len(), 5);
            assert!(r.latency_s >= 0.0);
            assert!(r.batch_size >= 1);
        }
        engine.shutdown();
    }

    #[test]
    fn run_workload_reports_recall_one() {
        // self-queries under L2 (self is always the true top-1; under IP
        // a higher-norm vector could legitimately outscore it)
        let index = build_index_sim(200, 12, 12, Similarity::L2); // d == D
        let queries: Vec<Vec<f32>> = (0..20u32).map(|i| index.secondary.decode(i)).collect();
        let truth: Vec<Vec<u32>> = (0..20u32).map(|i| vec![i]).collect();
        let (responses, report) = Engine::run_workload(
            index,
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
            &queries,
            1,
            Some(&truth),
        );
        assert_eq!(responses.len(), 20);
        assert!(report.recall_at_k >= 0.95, "{}", report.recall_at_k);
        assert!(report.metrics.qps > 0.0);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let index = build_index(100, 8, 4);
        let engine = Engine::start(index, EngineConfig::default());
        engine.submit(vec![0.0; 8], 3);
        std::thread::sleep(std::time::Duration::from_millis(50));
        let rest = engine.shutdown();
        // the one response may have been drained here or not at all
        assert!(rest.len() <= 1);
    }

    #[test]
    fn run_batch_direct_matches_engine_and_is_worker_count_invariant() {
        let index = build_index(250, 16, 8);
        let mut rng = Rng::new(13);
        let queries: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let params = SearchParams::default();
        let direct1 = Engine::run_batch_direct(&index, &queries, 5, params, 1);
        let direct3 = Engine::run_batch_direct(&index, &queries, 5, params, 3);
        assert_eq!(direct1, direct3, "results depend on worker count");
        // agrees with the channel-based engine
        let (mut responses, _) = Engine::run_workload(
            Arc::clone(&index),
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
            &queries,
            5,
            None,
        );
        responses.sort_by_key(|r| r.id);
        for (r, (ids, _)) in responses.iter().zip(direct1.iter()) {
            assert_eq!(&r.ids, ids);
        }
    }

    #[test]
    fn engine_from_snapshot_matches_in_memory_engine() {
        let index = build_index(200, 16, 8);
        let path = std::env::temp_dir().join(format!(
            "leanvec-engine-snap-{}.leanvec",
            std::process::id()
        ));
        index
            .save(&path, &crate::index::persist::SnapshotMeta::default())
            .unwrap();
        let (engine, _meta) = Engine::start_from_snapshot(&path, |meta| EngineConfig {
            workers: 2,
            search: meta.search_defaults,
            ..EngineConfig::default()
        })
        .unwrap();
        let mut rng = Rng::new(17);
        let queries: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        for q in &queries {
            engine.submit(q.clone(), 5);
        }
        let mut responses = engine.drain(queries.len());
        responses.sort_by_key(|r| r.id);
        engine.shutdown();
        for (r, q) in responses.iter().zip(queries.iter()) {
            let direct = index.search_one(&Query::new(q).k(5));
            assert_eq!(r.ids, direct.ids);
            assert_eq!(r.scores, direct.scores);
            assert_eq!(r.stats, direct.stats, "served stats match direct stats");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn responses_match_direct_search() {
        let index = build_index(250, 16, 8);
        let mut rng = Rng::new(11);
        let q: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        let direct = index.search_one(&Query::new(&q).k(5));
        let (responses, _) = Engine::run_workload(
            Arc::clone(&index),
            EngineConfig {
                workers: 1,
                ..EngineConfig::default()
            },
            &[q],
            5,
            None,
        );
        assert_eq!(responses[0].ids, direct.ids);
    }

    #[test]
    fn live_engine_ingest_lane_applies_mutations_and_consolidates() {
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<f32>> = (0..300)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let mut gp = GraphParams::for_similarity(Similarity::L2);
        gp.max_degree = 12;
        gp.build_window = 30;
        let built = IndexBuilder::new()
            .projection(ProjectionKind::Id)
            .target_dim(8)
            .graph_params(gp)
            .build(&rows, None, Similarity::L2);
        let live = Arc::new(crate::mutate::LiveIndex::from_index(built));
        let mut engine = Engine::start_live(
            Arc::clone(&live),
            EngineConfig {
                workers: 2,
                consolidate_threshold: 0.05,
                ..EngineConfig::default()
            },
        );
        // mutations and searches interleaved on a running engine
        for i in 0..30u32 {
            engine.submit_delete(i);
        }
        for i in 0..30u32 {
            let v: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
            engine.submit_insert(1000 + i, v);
        }
        for q in rows.iter().take(20) {
            engine.submit(q.clone(), 5);
        }
        let responses = engine.drain(20);
        assert_eq!(responses.len(), 20);
        for r in &responses {
            assert_eq!(r.ids.len(), 5);
        }
        engine.quiesce_mutations();
        let stats = engine.ingest_stats();
        assert_eq!(stats.inserts, 30);
        assert_eq!(stats.deletes, 30);
        assert_eq!(stats.errors, 0);
        assert!(stats.consolidations >= 1, "5% threshold crossed: {stats:?}");
        assert!(stats.consolidate_seconds >= 0.0);
        assert_eq!(live.live_len(), 300);
        // with the lane quiesced, deleted ids can never surface again
        let r = live.search_one(&Query::new(&rows[0]).k(10).window(60));
        assert!(
            r.ids.iter().all(|&id| id >= 30),
            "deleted id returned: {:?}",
            r.ids
        );
        engine.shutdown();
    }

    #[test]
    fn frozen_engine_has_no_ingest_lane() {
        let index = build_index(100, 8, 4);
        let engine = Engine::start(index, EngineConfig::default());
        assert!(engine.live_index().is_none());
        let stats = engine.ingest_stats();
        assert_eq!(stats.inserts + stats.deletes + stats.errors, 0);
        engine.shutdown();
    }

    #[test]
    fn per_request_spec_overrides_engine_defaults() {
        let index = build_index(400, 16, 8);
        // deliberately tiny engine-wide window so the override is visible
        let engine = Engine::start(
            Arc::clone(&index),
            EngineConfig {
                workers: 1,
                search: SearchParams {
                    window: 5,
                    rerank_window: 5,
                },
                ..EngineConfig::default()
            },
        );
        let mut rng = Rng::new(23);
        let q: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        engine.submit(q.clone(), 5); // engine defaults
        engine.submit_spec(
            q.clone(),
            QuerySpec::top_k(5).with_window(80).with_rerank_window(120),
        );
        let mut responses = engine.drain(2);
        responses.sort_by_key(|r| r.id);
        engine.shutdown();
        // the overridden request must match a direct search at its own
        // params, not the engine-wide ones
        let wide = index.search_one(&Query::new(&q).k(5).window(80).rerank_window(120));
        assert_eq!(responses[1].ids, wide.ids);
        assert_eq!(responses[1].stats, wide.stats);
        let narrow = index.search_one(&Query::new(&q).k(5).window(5));
        assert_eq!(responses[0].ids, narrow.ids);
        // wider window scores strictly more vectors
        assert!(responses[1].stats.primary_scored > responses[0].stats.primary_scored);
    }
}
