//! The serving engine: batcher thread + worker pool over a registry of
//! named [`Collection`]s, each a [`ShardedIndex`] (frozen or live
//! shards) with per-collection search defaults and admission quotas.
//! Requests carry a collection name in their [`QuerySpec`]; the batcher
//! groups each batch by collection (one projection matmul per group)
//! and the workers answer by concurrent scatter-gather across that
//! collection's shards. Live collections share one **ingest lane**: a
//! dedicated mutation worker that routes inserts/deletes to the owning
//! shard by id hash, interleaved with (never blocking) the search
//! workers, and staggers tombstone consolidation one shard at a time
//! when a shard crosses [`EngineConfig::consolidate_threshold`].

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::{Metrics, ServeReport};
use super::protocol::{Mutation, QuerySpec, Request, Response, StageTimes};
use crate::index::leanvec_index::{LeanVecIndex, SearchParams};
use crate::index::query::Query;
use crate::leanvec::model::rows_to_matrix;
use crate::linalg::Matrix;
use crate::mutate::LiveIndex;
use crate::obs::{self, CaptureKind, FlightRecord, FlightRecorder, Outcome};
use crate::shard::{Collection, CollectionRegistry, ShardedIndex, DEFAULT_COLLECTION, MANIFEST_NAME};
use crate::util::cancel::CancelToken;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Everything the engine can reject or fail a request with instead of
/// panicking: a stopped (or mutation-quiesced) engine, an unregistered
/// collection, a tenant over its admission quota, a mutation aimed at a
/// frozen collection, a missed deadline, overload shedding, or a failed
/// snapshot hot-swap.
///
/// Display messages are stable: the CLI prints them verbatim and maps
/// each variant to a distinct exit code ([`EngineError::exit_code`]),
/// so scripts can branch on the failure class.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// The engine (or its ingest lane) no longer accepts submissions —
    /// it was shut down, or mutations were quiesced.
    Stopped,
    /// No collection registered under this name.
    UnknownCollection(String),
    /// The collection's [`TenantQuota`](crate::shard::TenantQuota)
    /// rejected the submission (too many in-flight searches or pending
    /// mutations).
    QuotaExceeded { collection: String },
    /// Mutation submitted to a collection whose shards are frozen.
    NotLive { collection: String },
    /// The request's deadline ([`QuerySpec::timeout_ms`]) expired
    /// before a full answer was produced — shed in the batcher queue or
    /// cancelled mid-search.
    ///
    /// [`QuerySpec::timeout_ms`]: super::protocol::QuerySpec::timeout_ms
    DeadlineExceeded,
    /// Overload protection ([`ShedPolicy`]) rejected the request at
    /// admission; retry after roughly this many milliseconds.
    Overloaded { retry_after_ms: u64 },
    /// A snapshot hot-swap ([`Engine::swap_collection`]) failed; the
    /// previous index is untouched and still serving.
    SwapFailed { collection: String, reason: String },
}

impl EngineError {
    /// Distinct process exit code for each failure class (the CLI's
    /// contract with scripts; 1 stays the generic failure code).
    pub fn exit_code(&self) -> i32 {
        match self {
            EngineError::Stopped => 10,
            EngineError::UnknownCollection(_) => 11,
            EngineError::QuotaExceeded { .. } => 12,
            EngineError::NotLive { .. } => 13,
            EngineError::DeadlineExceeded => 14,
            EngineError::Overloaded { .. } => 15,
            EngineError::SwapFailed { .. } => 16,
        }
    }
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Stopped => write!(f, "engine stopped accepting submissions"),
            EngineError::UnknownCollection(name) => {
                write!(f, "no collection named {name:?}")
            }
            EngineError::QuotaExceeded { collection } => {
                write!(f, "collection {collection:?}: admission quota exceeded")
            }
            EngineError::NotLive { collection } => {
                write!(
                    f,
                    "collection {collection:?} is frozen (mutations need live shards)"
                )
            }
            EngineError::DeadlineExceeded => {
                write!(f, "request deadline exceeded")
            }
            EngineError::Overloaded { retry_after_ms } => {
                write!(
                    f,
                    "engine overloaded, retry after {retry_after_ms} ms"
                )
            }
            EngineError::SwapFailed { collection, reason } => {
                write!(
                    f,
                    "collection {collection:?}: snapshot swap failed ({reason}); \
                     previous index still serving"
                )
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// Ingest-lane counters (atomics: the lane runs on its own thread).
#[derive(Debug, Default)]
pub struct IngestStats {
    pub inserts: AtomicUsize,
    pub deletes: AtomicUsize,
    /// rejected mutations (duplicate/unknown id, dimension mismatch)
    pub errors: AtomicUsize,
    pub consolidations: AtomicUsize,
    /// total wall-clock nanoseconds spent consolidating
    pub consolidate_nanos: AtomicU64,
}

/// A plain-value copy of [`IngestStats`] for reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct IngestSnapshot {
    pub inserts: usize,
    pub deletes: usize,
    pub errors: usize,
    pub consolidations: usize,
    pub consolidate_seconds: f64,
}

impl IngestStats {
    pub fn snapshot(&self) -> IngestSnapshot {
        // ORDERING: Relaxed — monotonic stat counters read for
        // reporting; they guard no shared data, so no edge is needed.
        let ld = |c: &AtomicUsize| c.load(Ordering::Relaxed);
        // ORDERING: Relaxed — same stat-counter argument as above.
        let nanos = self.consolidate_nanos.load(Ordering::Relaxed);
        IngestSnapshot {
            inserts: ld(&self.inserts),
            deletes: ld(&self.deletes),
            errors: ld(&self.errors),
            consolidations: ld(&self.consolidations),
            consolidate_seconds: nanos as f64 / 1e9,
        }
    }
}

/// How the batcher projects query batches.
#[derive(Clone, Debug)]
pub enum QueryProjectorKind {
    /// native matmul on the batcher thread
    Native,
    /// PJRT `project_q` artifact from this directory (the runtime is
    /// constructed *on the batcher thread* — PJRT handles are not Send)
    Pjrt(std::path::PathBuf),
}

/// Overload-shedding policy: reject requests **at admission** (with
/// [`EngineError::Overloaded`] and a retry hint) once the batcher queue
/// is measurably behind, so goodput holds under offered load well past
/// capacity instead of every request timing out in the queue. Both
/// knobs default to 0 = disabled: an unconfigured engine behaves
/// exactly as before.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShedPolicy {
    /// Shed when this many requests are already waiting between submit
    /// and batcher dequeue (0 = no depth bound).
    pub max_queue_depth: usize,
    /// Shed when the most recently measured batcher queue wait exceeds
    /// this budget while the queue is non-empty (0 = no wait bound).
    pub max_queue_wait_ms: u64,
}

impl ShedPolicy {
    pub fn enabled(&self) -> bool {
        self.max_queue_depth > 0 || self.max_queue_wait_ms > 0
    }

    /// Admission check: `Some(retry_after_ms)` when the request should
    /// be shed.
    fn should_shed(&self, p: &QueuePressure) -> Option<u64> {
        // ORDERING: Acquire pairs with the Release sides of the
        // depth/wait updates; stale-by-one reads only shift the shed
        // boundary by one request, never corrupt it.
        let depth = p.depth.load(Ordering::Acquire);
        let wait_ms = p.wait_nanos.load(Ordering::Acquire) / 1_000_000;
        let over_depth = self.max_queue_depth > 0 && depth >= self.max_queue_depth;
        let over_wait =
            self.max_queue_wait_ms > 0 && depth > 0 && wait_ms > self.max_queue_wait_ms;
        if over_depth || over_wait {
            // hint: the backlog should clear in about one measured
            // queue wait; never advertise 0 (that reads as "now")
            Some(wait_ms.max(1))
        } else {
            None
        }
    }
}

/// Shared submit-side/batcher-side view of the request queue: how many
/// requests are between `submit` and batcher dequeue, and the last
/// queue wait the batcher measured. This is what [`ShedPolicy`] reads
/// at admission.
#[derive(Debug, Default)]
struct QueuePressure {
    /// requests submitted and not yet dequeued by the batcher
    depth: AtomicUsize,
    /// most recently measured queue wait (oldest request of the last
    /// batch), nanoseconds
    wait_nanos: AtomicU64,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
    /// overload shedding at admission (default: disabled)
    pub shed: ShedPolicy,
    /// engine-wide search defaults; collections registered through
    /// [`Engine::start`]/[`Engine::start_live`] adopt these as their
    /// per-collection defaults ([`Engine::start_collections`] callers
    /// set defaults on each [`Collection`] instead)
    pub search: SearchParams,
    pub projector: QueryProjectorKind,
    /// Live collections only: tombstone fraction at which the ingest
    /// lane consolidates a shard (after applying a mutation, off the
    /// search hot path; at most one shard per mutation, so multi-shard
    /// consolidations stagger across the stream). `<= 0` disables the
    /// tombstone-fraction trigger; the pending-insert-log memory bound
    /// still folds each shard's journal regardless.
    pub consolidate_threshold: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            batch: BatchPolicy::default(),
            shed: ShedPolicy::default(),
            search: SearchParams::default(),
            projector: QueryProjectorKind::Native,
            consolidate_threshold: 0.2,
        }
    }
}

/// A running engine. Submit requests, then `drain` responses; engines
/// with live collections additionally accept mutations
/// ([`Engine::submit_insert`]/[`Engine::submit_delete`], or the `_to`
/// variants naming a collection) on the ingest lane.
pub struct Engine {
    registry: Arc<CollectionRegistry>,
    req_tx: Option<Sender<Request>>,
    /// Mutex-wrapped so the engine is `Sync`: submissions may fan out
    /// from many threads while one drainer collects responses.
    resp_rx: Mutex<Receiver<Response>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    // ingest lane (engines with live collections only)
    mut_tx: Option<Sender<(Arc<Collection>, Mutation)>>,
    ingest: Option<JoinHandle<()>>,
    ingest_stats: Arc<IngestStats>,
    live: Option<Arc<LiveIndex>>,
    next_id: AtomicU64,
    started: Instant,
    /// slow-query flight recorder, fed by the worker pool
    flight: Arc<FlightRecorder>,
    /// per-collection metric handles, resolved once at start so the
    /// hot path never does a label lookup
    coll_metrics: Arc<HashMap<String, Arc<CollHandles>>>,
    /// queue-depth / queue-wait view shared with the batcher; read by
    /// `shed` at admission
    pressure: Arc<QueuePressure>,
    shed: ShedPolicy,
}

/// Telemetry handles for one collection's labeled series, resolved
/// once at engine start ([`obs::handles()`] family lookups) so workers
/// record through plain `Arc` derefs on the hot path.
struct CollHandles {
    queries: obs::Counter,
    rejected: obs::Counter,
    e2e: obs::Histogram,
    search: obs::Histogram,
    hops: obs::Histogram,
    touched: obs::Histogram,
    deleted_skipped: obs::Counter,
    filtered: obs::Counter,
    deadline_exceeded: obs::Counter,
    shed: obs::Counter,
    degraded: obs::Counter,
}

impl CollHandles {
    fn resolve(name: &str) -> CollHandles {
        let h = obs::handles();
        CollHandles {
            queries: h.engine_queries.with(name),
            rejected: h.engine_rejected.with(name),
            e2e: h.engine_e2e.with(name),
            search: h.engine_search.with(name),
            hops: h.query_hops.with(name),
            touched: h.query_touched.with(name),
            deleted_skipped: h.query_deleted_skipped.with(name),
            filtered: h.query_filtered.with(name),
            deadline_exceeded: h.engine_deadline_exceeded.with(name),
            shed: h.engine_shed.with(name),
            degraded: h.engine_degraded.with(name),
        }
    }
}

/// Work item: one request, its projected query, and the collection that
/// answers it (resolved once, by the batcher).
struct WorkItem {
    req: Request,
    q_proj: Vec<f32>,
    batch_size: usize,
    collection: Arc<Collection>,
    /// the serve-index snapshot the batcher projected `q_proj` against.
    /// The worker MUST search this exact index: a hot-swap between
    /// projection and search would otherwise pair a query projected
    /// with the old model against the new index.
    index: Arc<ShardedIndex>,
    /// absolute deadline derived from the spec's `timeout_ms` at
    /// submission (`None` = no deadline)
    deadline: Option<Instant>,
    /// time this request waited in the batcher queue (0 when telemetry
    /// is off — the batcher skips the clock reads)
    queue_s: f64,
    /// this request's share of its group's projection matmul
    project_s: f64,
    /// the collection's resolved metric handles
    obs: Arc<CollHandles>,
}

impl Engine {
    /// Start a serving engine directly from an on-disk index snapshot
    /// (see `crate::index::persist`): the build/serve split. The
    /// training, projection and graph-construction paths are never
    /// touched — the process goes from snapshot bytes to answering
    /// queries.
    ///
    /// `cfg` receives the snapshot's metadata before the engine starts,
    /// so the recommended serving parameters it carries are usable:
    ///
    /// ```ignore
    /// let (engine, _meta) = Engine::start_from_snapshot(path, |meta| EngineConfig {
    ///     search: meta.search_defaults,
    ///     ..EngineConfig::default()
    /// })?;
    /// ```
    pub fn start_from_snapshot<F>(
        path: &std::path::Path,
        cfg: F,
    ) -> Result<(Engine, crate::index::persist::SnapshotMeta), crate::index::persist::SnapshotError>
    where
        F: FnOnce(&crate::index::persist::SnapshotMeta) -> EngineConfig,
    {
        Engine::start_from_snapshot_with(path, None, cfg)
    }

    /// [`Engine::start_from_snapshot`] with an explicit residency
    /// choice: `Some(policy)` serves straight off a memory map of the
    /// snapshot file ([`LeanVecIndex::load_mmap_with`]) so an index
    /// larger than RAM can serve; `None` decodes into owned memory
    /// (honoring `LEANVEC_FORCE_MMAP`, like [`LeanVecIndex::load`]).
    pub fn start_from_snapshot_with<F>(
        path: &std::path::Path,
        mmap: Option<crate::index::persist::MmapPolicy>,
        cfg: F,
    ) -> Result<(Engine, crate::index::persist::SnapshotMeta), crate::index::persist::SnapshotError>
    where
        F: FnOnce(&crate::index::persist::SnapshotMeta) -> EngineConfig,
    {
        let (index, meta) = match mmap {
            Some(policy) => LeanVecIndex::load_mmap_with(path, policy)?,
            None => LeanVecIndex::load(path)?,
        };
        let cfg = cfg(&meta);
        Ok((Engine::start(Arc::new(index), cfg), meta))
    }

    /// Start a single-collection engine over a frozen index: the index
    /// is registered as the [`DEFAULT_COLLECTION`] with `cfg.search` as
    /// its defaults.
    pub fn start(index: Arc<LeanVecIndex>, cfg: EngineConfig) -> Engine {
        let mut registry = CollectionRegistry::new();
        registry.register(
            Collection::new(DEFAULT_COLLECTION, ShardedIndex::from_single(index))
                .with_defaults(cfg.search),
        );
        Engine::start_collections(registry, cfg)
    }

    /// Start a **live** single-collection engine over a mutable index:
    /// the same batcher/worker pipeline as [`Engine::start`], plus an
    /// ingest lane — one mutation thread draining
    /// [`Engine::submit_insert`]/[`Engine::submit_delete`] in
    /// submission order, concurrently with the search workers (no
    /// global lock: searches hold read guards, mutations write briefly).
    /// After each mutation the lane checks the tombstone fraction and
    /// runs [`LiveIndex::consolidate`] when it crosses
    /// [`EngineConfig::consolidate_threshold`] — off the search path.
    pub fn start_live(live: Arc<LiveIndex>, cfg: EngineConfig) -> Engine {
        let mut registry = CollectionRegistry::new();
        registry.register(
            Collection::new(DEFAULT_COLLECTION, ShardedIndex::from_live(Arc::clone(&live)))
                .with_defaults(cfg.search),
        );
        let mut engine = Engine::start_collections(registry, cfg);
        engine.live = Some(live);
        engine
    }

    /// Start the engine over a full [`CollectionRegistry`]: the
    /// multi-tenant entry point. Every registered collection is served
    /// by the shared batcher/worker pipeline, routed by the collection
    /// name in each request's [`QuerySpec`]. An ingest lane is started
    /// iff any collection has live shards.
    pub fn start_collections(registry: CollectionRegistry, cfg: EngineConfig) -> Engine {
        assert!(
            !registry.is_empty(),
            "engine needs at least one collection"
        );
        let registry = Arc::new(registry);
        let (req_tx, req_rx) = channel::<Request>();
        let (work_tx, work_rx) = channel::<WorkItem>();
        let (resp_tx, resp_rx) = channel::<Response>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        // resolve every collection's labeled metric handles up front;
        // workers and the batcher then record without label lookups
        let coll_metrics: Arc<HashMap<String, Arc<CollHandles>>> = Arc::new(
            registry
                .names()
                .into_iter()
                .map(|n| {
                    let handles = Arc::new(CollHandles::resolve(&n));
                    (n, handles)
                })
                .collect(),
        );
        let flight = Arc::new(FlightRecorder::default());

        let pressure = Arc::new(QueuePressure::default());

        // --- batcher thread: batch, group by collection, project, fan out
        let bregistry = Arc::clone(&registry);
        let bcfg = cfg.clone();
        let bmetrics = Arc::clone(&coll_metrics);
        let bpressure = Arc::clone(&pressure);
        let bflight = Arc::clone(&flight);
        let bresp = resp_tx.clone();
        let batcher = std::thread::Builder::new()
            .name("leanvec-batcher".into())
            .spawn(move || {
                batcher_loop(bregistry, bcfg, req_rx, work_tx, bresp, bmetrics, bpressure, bflight);
            })
            // lint:allow(serve-path-panic): engine construction, not the
            // request path — an engine without its batcher cannot exist,
            // so a failed spawn at startup is fatal by design.
            .expect("spawn batcher");

        // --- workers: scatter-gather search + rerank
        let workers = (0..cfg.workers.max(1))
            .map(|w| {
                let wrx = Arc::clone(&work_rx);
                let wtx = resp_tx.clone();
                let wflight = Arc::clone(&flight);
                std::thread::Builder::new()
                    .name(format!("leanvec-search-{w}"))
                    .spawn(move || {
                        loop {
                            // a poisoned lock only means a sibling
                            // worker panicked while holding it; the
                            // receiver inside is still intact
                            // DEADLINE: blocking recv is the worker's
                            // idle state; shutdown closes the channel,
                            // which wakes this with Err.
                            let item = {
                                wrx.lock() // DEADLINE: held only for one recv, never across a search
                                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                                    .recv() // DEADLINE: worker idle state; shutdown closes the channel
                            };
                            let item = match item {
                                Ok(i) => i,
                                Err(_) => break,
                            };
                            // per-request spec wins over the collection's
                            // defaults; the allow-list becomes a filter
                            // predicate pushed into traversal
                            let telem = obs::enabled();
                            let coll = &item.collection;
                            let spec = &item.req.spec;
                            let params = resolve_spec(spec, coll.defaults);
                            // already past the deadline and not allowed
                            // to return partials: answer with the typed
                            // error instead of burning a worker on a
                            // dead request
                            if let Some(d) = item.deadline {
                                if !spec.allow_partial && Instant::now() >= d {
                                    send_deadline_failure(
                                        &wtx, &wflight, &item, telem,
                                    );
                                    continue;
                                }
                            }
                            // the cancel token polls the deadline inside
                            // per-shard traversal; an allow_partial
                            // request that is already expired still runs
                            // and returns whatever the first poll
                            // interval accumulates
                            let cancel = item
                                .deadline
                                .map(|d| Arc::new(CancelToken::with_deadline(d)));
                            let base = Query::new(&item.req.query)
                                .k(spec.k)
                                .window(params.window)
                                .rerank_window(params.rerank_window);
                            let t_search = if telem { Some(Instant::now()) } else { None };
                            let (result, scatter) = match spec.allow.as_ref() {
                                // the set was built once at spec
                                // construction; here it is only read
                                Some(allow) => {
                                    let pred = |id: u32| allow.contains(&id);
                                    item.index.search_scatter_timed_cancel(
                                        &item.q_proj,
                                        &base.filter(&pred),
                                        cancel.as_ref(),
                                    )
                                }
                                None => item.index.search_scatter_timed_cancel(
                                    &item.q_proj,
                                    &base,
                                    cancel.as_ref(),
                                ),
                            };
                            let search_s =
                                t_search.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
                            // release the admission slot before the send:
                            // once the caller drains this response the
                            // quota capacity is observably free
                            item.collection.finish_search();
                            let latency_s = item
                                .req
                                .submitted
                                .map(|t| t.elapsed().as_secs_f64())
                                .unwrap_or(0.0);
                            let (merge_s, shard_seconds) = match scatter {
                                Some(t) => (t.merge_seconds, t.per_shard_seconds),
                                None => (0.0, Vec::new()),
                            };
                            let timed_out =
                                cancel.as_ref().is_some_and(|t| t.is_cancelled());
                            let degraded = result.degraded;
                            let shards_failed = result.shards_failed;
                            let stats = result.stats;
                            let outcome = if timed_out && !spec.allow_partial {
                                Outcome::DeadlineExceeded
                            } else if timed_out {
                                Outcome::Partial
                            } else if degraded {
                                Outcome::Degraded
                            } else {
                                Outcome::Ok
                            };
                            if telem {
                                let m = &item.obs;
                                m.queries.inc();
                                m.e2e.record_seconds(latency_s);
                                m.search.record_seconds(search_s);
                                m.hops.record(stats.hops as u64);
                                m.touched.record(stats.bytes_touched as u64);
                                if stats.deleted_skipped > 0 {
                                    m.deleted_skipped.add(stats.deleted_skipped as u64);
                                }
                                if stats.filtered > 0 {
                                    m.filtered.add(stats.filtered as u64);
                                }
                                if timed_out {
                                    m.deadline_exceeded.inc();
                                }
                                if degraded {
                                    m.degraded.inc();
                                }
                                let build = || FlightRecord {
                                    id: item.req.id,
                                    collection: item.collection.name().to_string(),
                                    kind: CaptureKind::Slow,
                                    e2e_seconds: latency_s,
                                    queue_seconds: item.queue_s,
                                    project_seconds: item.project_s,
                                    search_seconds: search_s,
                                    merge_seconds: merge_s,
                                    shard_seconds,
                                    stats,
                                    params,
                                    k: spec.k,
                                    batch_size: item.batch_size,
                                    outcome,
                                };
                                if outcome == Outcome::Ok {
                                    wflight.capture_with(latency_s, build);
                                } else {
                                    // abnormal outcomes always land in
                                    // the failure ring, however fast
                                    wflight.capture_failure(build());
                                }
                            }
                            let (error, partial, ids, scores) =
                                if timed_out && !spec.allow_partial {
                                    (
                                        Some(EngineError::DeadlineExceeded),
                                        false,
                                        Vec::new(),
                                        Vec::new(),
                                    )
                                } else {
                                    (None, timed_out, result.ids, result.scores)
                                };
                            let _ = wtx.send(Response {
                                id: item.req.id,
                                ids,
                                scores,
                                stats,
                                latency_s,
                                batch_size: item.batch_size,
                                stages: StageTimes {
                                    queue_s: item.queue_s,
                                    project_s: item.project_s,
                                    search_s,
                                    merge_s,
                                },
                                error,
                                degraded,
                                shards_failed,
                                partial,
                            });
                        }
                    })
                    // lint:allow(serve-path-panic): engine
                    // construction (see the batcher spawn above).
                    .expect("spawn worker")
            })
            .collect();

        // --- ingest lane, iff any collection accepts mutations
        let ingest_stats = Arc::new(IngestStats::default());
        let (mut_tx, ingest) = if registry.any_live() {
            let (tx, rx) = channel::<(Arc<Collection>, Mutation)>();
            let stats = Arc::clone(&ingest_stats);
            let threshold = cfg.consolidate_threshold;
            let handle = std::thread::Builder::new()
                .name("leanvec-ingest".into())
                .spawn(move || {
                    ingest_loop(rx, stats, threshold);
                })
                // lint:allow(serve-path-panic): engine construction
                // (see the batcher spawn above).
                .expect("spawn ingest");
            (Some(tx), Some(handle))
        } else {
            (None, None)
        };

        Engine {
            registry,
            req_tx: Some(req_tx),
            resp_rx: Mutex::new(resp_rx),
            batcher: Some(batcher),
            workers,
            mut_tx,
            ingest,
            ingest_stats,
            live: None,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
            flight,
            coll_metrics,
            pressure,
            shed: cfg.shed,
        }
    }

    /// The collections this engine serves.
    pub fn registry(&self) -> &Arc<CollectionRegistry> {
        &self.registry
    }

    /// One collection by name (admission counters, defaults, index).
    pub fn collection(&self, name: &str) -> Option<&Arc<Collection>> {
        self.registry.get(name)
    }

    /// Submit one query to the default collection with its default
    /// knobs; returns the request id.
    pub fn submit(&self, query: Vec<f32>, k: usize) -> Result<u64, EngineError> {
        self.submit_spec(query, QuerySpec::top_k(k))
    }

    /// Submit one query with per-request knobs (collection, window /
    /// rerank-window overrides, allow-list filter); returns the request
    /// id, or the reason the request was not admitted.
    pub fn submit_spec(&self, query: Vec<f32>, spec: QuerySpec) -> Result<u64, EngineError> {
        let name = spec.collection_name();
        let coll = match self.registry.get(name) {
            Some(c) => c,
            None => {
                // unknown names go through the family lookup (its
                // cardinality cap folds hostile name floods into the
                // overflow child) rather than a pre-resolved handle
                obs::handles().engine_rejected.with(name).inc();
                return Err(EngineError::UnknownCollection(name.to_string()));
            }
        };
        let tx = self.req_tx.as_ref().ok_or(EngineError::Stopped)?;
        // overload shedding: engine-global queue pressure, checked
        // before the per-tenant quota so an overloaded engine rejects
        // in O(two atomic loads) without touching collection state
        if let Some(retry_after_ms) = self.shed.should_shed(&self.pressure) {
            if let Some(m) = self.coll_metrics.get(name) {
                m.shed.inc();
                m.rejected.inc();
            }
            if obs::enabled() {
                // shed requests never drew a ticket: id u64::MAX marks
                // "rejected at admission" in the failure ring
                self.flight.capture_failure(FlightRecord {
                    id: u64::MAX,
                    collection: name.to_string(),
                    kind: CaptureKind::Failure,
                    e2e_seconds: 0.0,
                    queue_seconds: 0.0,
                    project_seconds: 0.0,
                    search_seconds: 0.0,
                    merge_seconds: 0.0,
                    shard_seconds: Vec::new(),
                    stats: Default::default(),
                    params: SearchParams::default(),
                    k: spec.k,
                    batch_size: 0,
                    outcome: Outcome::Shed,
                });
            }
            return Err(EngineError::Overloaded { retry_after_ms });
        }
        if !coll.admit_search() {
            if let Some(m) = self.coll_metrics.get(name) {
                m.rejected.inc();
            }
            return Err(EngineError::QuotaExceeded {
                collection: name.to_string(),
            });
        }
        // ORDERING: Relaxed — a unique-ticket counter; the RMW's
        // atomicity alone guarantees distinct ids, and the id orders
        // nothing else.
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request::with_spec(id, query, spec);
        req.submitted = Some(Instant::now());
        // ORDERING: AcqRel — depth is incremented before the send so
        // the batcher's decrement (after dequeue) can never underflow;
        // pairs with should_shed's Acquire read.
        self.pressure.depth.fetch_add(1, Ordering::AcqRel);
        if tx.send(req).is_err() {
            // ORDERING: AcqRel — roll back the increment above.
            self.pressure.depth.fetch_sub(1, Ordering::AcqRel);
            coll.finish_search();
            return Err(EngineError::Stopped);
        }
        Ok(id)
    }

    /// Enqueue an insert for the default collection on the ingest lane.
    /// Applied asynchronously, in submission order, concurrently with
    /// searches. Errors instead of panicking when the collection is
    /// frozen, unknown, over quota, or the lane is quiesced/stopped.
    pub fn submit_insert(&self, ext_id: u32, vector: Vec<f32>) -> Result<(), EngineError> {
        self.submit_mutation(DEFAULT_COLLECTION, Mutation::Insert { ext_id, vector })
    }

    /// Enqueue a delete for the default collection on the ingest lane.
    pub fn submit_delete(&self, ext_id: u32) -> Result<(), EngineError> {
        self.submit_mutation(DEFAULT_COLLECTION, Mutation::Delete { ext_id })
    }

    /// Enqueue an insert for a named collection.
    pub fn submit_insert_to(
        &self,
        collection: &str,
        ext_id: u32,
        vector: Vec<f32>,
    ) -> Result<(), EngineError> {
        self.submit_mutation(collection, Mutation::Insert { ext_id, vector })
    }

    /// Enqueue a delete for a named collection.
    pub fn submit_delete_to(&self, collection: &str, ext_id: u32) -> Result<(), EngineError> {
        self.submit_mutation(collection, Mutation::Delete { ext_id })
    }

    fn submit_mutation(&self, name: &str, m: Mutation) -> Result<(), EngineError> {
        let coll = self
            .registry
            .get(name)
            .ok_or_else(|| EngineError::UnknownCollection(name.to_string()))?;
        if !coll.index().is_live() {
            return Err(EngineError::NotLive {
                collection: name.to_string(),
            });
        }
        let tx = self.mut_tx.as_ref().ok_or(EngineError::Stopped)?;
        if !coll.admit_mutation() {
            return Err(EngineError::QuotaExceeded {
                collection: name.to_string(),
            });
        }
        if tx.send((Arc::clone(coll), m)).is_err() {
            coll.finish_mutation();
            return Err(EngineError::Stopped);
        }
        Ok(())
    }

    /// Ingest-lane counters (zeros on an all-frozen engine).
    pub fn ingest_stats(&self) -> IngestSnapshot {
        self.ingest_stats.snapshot()
    }

    /// The live index this engine serves, if started with
    /// [`Engine::start_live`].
    pub fn live_index(&self) -> Option<&Arc<LiveIndex>> {
        self.live.as_ref()
    }

    /// Block until every mutation submitted so far has been applied:
    /// closes the ingest lane and joins the ingest worker. Searches are
    /// unaffected; further `submit_insert`/`submit_delete` calls return
    /// [`EngineError::Stopped`].
    pub fn quiesce_mutations(&mut self) {
        drop(self.mut_tx.take());
        if let Some(h) = self.ingest.take() {
            // DEADLINE: the ingest worker exits as soon as its (just
            // dropped) channel drains — bounded by the pending backlog.
            let _ = h.join();
        }
    }

    /// Blockingly collect `n` responses. If the workers disconnect
    /// first (engine failure mid-drain), returns the responses that
    /// did arrive rather than panicking the caller.
    pub fn drain(&self, n: usize) -> Vec<Response> {
        // a poisoned response lane only means another drainer panicked
        // between recvs; the receiver itself is still intact
        let rx = self
            .resp_rx
            // DEADLINE: held by the (single) drainer; contention here
            // is a caller bug, not a serve-path wait
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        (0..n)
            // DEADLINE: every admitted request yields exactly one
            // response (shed/expired ones are answered at admission or
            // by the batcher), so recv waits at most one in-flight
            // search; worker death surfaces as Err and ends the drain.
            .map_while(|_| rx.recv().ok())
            .collect()
    }

    /// Stop accepting requests, join all threads. Pending mutations are
    /// applied before the ingest lane joins.
    pub fn shutdown(mut self) -> Vec<Response> {
        self.quiesce_mutations();
        drop(self.req_tx.take());
        if let Some(b) = self.batcher.take() {
            // DEADLINE: the batcher exits once the (just dropped)
            // request channel drains — bounded by the queued backlog.
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            // DEADLINE: workers exit when the batcher closes the work
            // channel; each finishes at most one in-flight search.
            let _ = w.join();
        }
        // collect any leftover responses
        let rx = self
            .resp_rx
            // DEADLINE: all threads are joined; nothing else can hold
            // or contend for the response lane now
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut rest = Vec::new();
        while let Ok(r) = rx.try_recv() {
            rest.push(r);
        }
        rest
    }

    pub fn uptime(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Prometheus text exposition (v0.0.4) of the process metric
    /// registry. Refreshes the uptime gauge first. The output parses
    /// cleanly back through [`crate::obs::expo::parse_text`] — CI
    /// scrapes and validates every dump with exactly that parser.
    pub fn metrics_text(&self) -> String {
        obs::handles().engine_uptime.set(self.uptime());
        obs::expo::render_text(&obs::registry().snapshot())
    }

    /// JSON rendering of the same registry snapshot as
    /// [`Engine::metrics_text`], with raw histogram buckets included.
    pub fn metrics_json(&self) -> String {
        obs::handles().engine_uptime.set(self.uptime());
        obs::expo::render_json(&obs::registry().snapshot()).to_pretty()
    }

    /// Everything the flight recorder currently holds, slowest first:
    /// the per-stage breakdowns of the slowest queries seen (plus a
    /// small periodic sample of ordinary traffic).
    pub fn flight_records(&self) -> Vec<FlightRecord> {
        self.flight.records()
    }

    /// The engine's flight recorder (e.g. to check [`FlightRecorder::seen`]).
    pub fn flight_recorder(&self) -> &Arc<FlightRecorder> {
        &self.flight
    }

    /// Graceful snapshot hot-swap: replace `name`'s serve index with
    /// the snapshot at `path` **without dropping a single in-flight
    /// query**. The protocol:
    ///
    /// 1. load the new snapshot on a spawned thread (a load panic
    ///    becomes [`EngineError::SwapFailed`], never an engine abort);
    /// 2. gate it behind the same deep-invariant fsck the `fsck`
    ///    subcommand runs, plus an input-dimension compatibility check
    ///    against the index currently serving;
    /// 3. atomically swap the collection's serve slot (queries admitted
    ///    after this point search the new index; queries already in
    ///    flight keep their `Arc` snapshot of the old one);
    /// 4. drain: wait for the old index's refcount to fall to one
    ///    before dropping it, bounded by [`SWAP_DRAIN_TIMEOUT`].
    ///
    /// On any failure the old index is untouched and still serving.
    /// Live (mutable) collections refuse to hot-swap: their mutation
    /// journal would be silently discarded.
    pub fn swap_collection(
        &self,
        name: &str,
        path: &std::path::Path,
    ) -> Result<SwapReport, EngineError> {
        let fail = |reason: String| EngineError::SwapFailed {
            collection: name.to_string(),
            reason,
        };
        let coll = self
            .registry
            .get(name)
            .ok_or_else(|| EngineError::UnknownCollection(name.to_string()))?;
        if coll.index().is_live() {
            return Err(fail(
                "live collections cannot hot-swap (their mutation journal would be lost); \
                 quiesce and restart instead"
                    .to_string(),
            ));
        }
        // 1. load off-thread: isolates load panics from the caller
        let snap = path.to_path_buf();
        let loader = std::thread::Builder::new()
            .name("leanvec-swap-load".into())
            .spawn(move || -> Result<ShardedIndex, String> {
                #[cfg(any(test, feature = "failpoints"))]
                if crate::util::failpoints::hit("io_error_on_load", None).is_some() {
                    return Err("injected i/o error (failpoint io_error_on_load)".to_string());
                }
                if snap.join(MANIFEST_NAME).is_file() {
                    ShardedIndex::load_dir(&snap)
                        .map(|(ix, _meta)| ix)
                        .map_err(|e| e.to_string())
                } else {
                    LeanVecIndex::load(&snap)
                        .map(|(ix, _meta)| ShardedIndex::from_single(Arc::new(ix)))
                        .map_err(|e| e.to_string())
                }
            })
            .map_err(|e| fail(format!("spawn loader: {e}")))?;
        let new_index = loader
            // DEADLINE: bounded by one snapshot read + decode on the
            // loader thread; this is the swap control path, not the
            // query path.
            .join()
            .map_err(|_| fail("snapshot loader panicked".to_string()))?
            .map_err(fail)?;
        // 2. fsck gate: never swap in a corrupt index
        let report = new_index.check_invariants();
        if !report.is_clean() {
            let first = &report.violations[0];
            return Err(fail(format!(
                "fsck found {} violation(s); first: [{}/{}] {}",
                report.violations.len(),
                first.layer,
                first.code,
                first.detail
            )));
        }
        let old_probe = coll.index();
        let (old_dim, new_dim) = (
            old_probe.model().input_dim(),
            new_index.model().input_dim(),
        );
        drop(old_probe); // must not hold an extra refcount into the drain
        if old_dim != new_dim {
            return Err(fail(format!(
                "query dimension mismatch: serving {old_dim}, snapshot {new_dim}"
            )));
        }
        // 3. atomic swap: a pointer exchange under the collection's lock
        let shards = new_index.shards();
        let old = coll.swap_index(Arc::new(new_index));
        // 4. drain: every in-flight query holds an `Arc` snapshot of
        // the old index (workers via WorkItem, the batcher via its
        // per-group snapshot); when the refcount falls to one, `old`
        // here is the last holder and the drop below frees it.
        // DEADLINE: poll loop bounded by SWAP_DRAIN_TIMEOUT.
        let t0 = Instant::now();
        while Arc::strong_count(&old) > 1 && t0.elapsed() < SWAP_DRAIN_TIMEOUT {
            std::thread::sleep(Duration::from_millis(1));
        }
        let drained = Arc::strong_count(&old) == 1;
        let drain_seconds = t0.elapsed().as_secs_f64();
        drop(old);
        if obs::enabled() {
            obs::handles().engine_swaps.inc();
        }
        Ok(SwapReport {
            collection: name.to_string(),
            shards,
            drained,
            drain_seconds,
        })
    }

    /// Direct parallel batch path (no channels): project the whole
    /// batch as ONE matmul on the calling thread — the same
    /// amortization the batcher thread performs — then fan the searches
    /// out across `workers` threads with pooled contexts, the same
    /// chunking discipline as the parallel index builder. Returns
    /// `(ids, scores)` per query, in query order, identical to serial
    /// per-query trait searches for every worker count.
    pub fn run_batch_direct(
        index: &LeanVecIndex,
        queries: &[Vec<f32>],
        k: usize,
        params: SearchParams,
        workers: usize,
    ) -> Vec<(Vec<u32>, Vec<f32>)> {
        if queries.is_empty() {
            return Vec::new();
        }
        // batched projection: Q (B, D) x A^T -> (B, d)
        let qm = rows_to_matrix(queries);
        let proj: Matrix = qm.matmul_nt(&index.model.a);
        index.batch_fan_out(queries.len(), workers, |ctx, i| {
            let query = Query::new(&queries[i])
                .k(k)
                .window(params.window)
                .rerank_window(params.rerank_window);
            let r = index.search_prepared(ctx, proj.row(i), &query);
            (r.ids, r.scores)
        })
    }

    /// Convenience: run a closed-loop workload and report (used by the
    /// e2e example and the serving benches).
    pub fn run_workload(
        index: Arc<LeanVecIndex>,
        cfg: EngineConfig,
        queries: &[Vec<f32>],
        k: usize,
        truth: Option<&[Vec<u32>]>,
    ) -> (Vec<Response>, ServeReport) {
        let engine = Engine::start(index, cfg);
        let t0 = Instant::now();
        for q in queries {
            engine
                .submit(q.clone(), k)
                // lint:allow(serve-path-panic): bench/report harness
                // entry point, not the serving request path.
                .expect("submit on a freshly started engine");
        }
        let mut responses = engine.drain(queries.len());
        let wall = t0.elapsed().as_secs_f64();
        let mut leftovers = engine.shutdown();
        responses.append(&mut leftovers);
        responses.sort_by_key(|r| r.id);
        let report = match truth {
            Some(t) => ServeReport::new(&responses, t, k, wall),
            None => ServeReport {
                metrics: Metrics::from_responses(&responses, wall),
                recall_at_k: f64::NAN,
                k,
            },
        };
        (responses, report)
    }
}

/// How long [`Engine::swap_collection`] waits for in-flight queries
/// against the old index to finish before dropping its handle anyway
/// (the index memory is then freed by whichever straggler drops last).
pub const SWAP_DRAIN_TIMEOUT: Duration = Duration::from_secs(10);

/// What one [`Engine::swap_collection`] hot-swap did.
#[derive(Clone, Debug)]
pub struct SwapReport {
    pub collection: String,
    /// shards in the incoming index
    pub shards: usize,
    /// the old index's refcount reached one (every in-flight query
    /// finished) before the swap call returned
    pub drained: bool,
    /// seconds spent waiting for in-flight queries on the old index
    pub drain_seconds: f64,
}

/// Resolve a request's [`QuerySpec`] against its collection's defaults
/// via the one shared rule ([`crate::index::query::resolve_params`]).
/// The results are clamped to >= 1 so a malformed spec degrades
/// instead of panicking the worker.
fn resolve_spec(spec: &QuerySpec, defaults: SearchParams) -> SearchParams {
    let p = crate::index::query::resolve_params(spec.window, spec.rerank_window, defaults);
    SearchParams {
        window: p.window.max(1),
        rerank_window: p.rerank_window.max(1),
    }
}

/// Resolve an admitted request whose deadline expired before its search
/// ran (shed in the batcher queue, or caught at the worker): release
/// the admission slot, count it, record the failure, and send exactly
/// ONE typed-error response so drain bookkeeping never hangs.
fn send_deadline_failure(
    resp_tx: &Sender<Response>,
    flight: &FlightRecorder,
    item: &WorkItem,
    telem: bool,
) {
    // release the admission slot before the send (same discipline as
    // the success path)
    item.collection.finish_search();
    let latency_s = item
        .req
        .submitted
        .map(|t| t.elapsed().as_secs_f64())
        .unwrap_or(0.0);
    if telem {
        item.obs.deadline_exceeded.inc();
        flight.capture_failure(FlightRecord {
            id: item.req.id,
            collection: item.collection.name().to_string(),
            kind: CaptureKind::Failure,
            e2e_seconds: latency_s,
            queue_seconds: item.queue_s,
            project_seconds: item.project_s,
            search_seconds: 0.0,
            merge_seconds: 0.0,
            shard_seconds: Vec::new(),
            stats: Default::default(),
            params: SearchParams::default(),
            k: item.req.spec.k,
            batch_size: item.batch_size,
            outcome: Outcome::DeadlineExceeded,
        });
    }
    let _ = resp_tx.send(Response {
        id: item.req.id,
        ids: Vec::new(),
        scores: Vec::new(),
        stats: Default::default(),
        latency_s,
        batch_size: item.batch_size,
        stages: StageTimes {
            queue_s: item.queue_s,
            project_s: item.project_s,
            ..StageTimes::default()
        },
        error: Some(EngineError::DeadlineExceeded),
        degraded: false,
        shards_failed: 0,
        partial: false,
    });
}

#[allow(clippy::too_many_arguments)] // one call site, spawned at start
fn batcher_loop(
    registry: Arc<CollectionRegistry>,
    cfg: EngineConfig,
    req_rx: Receiver<Request>,
    work_tx: Sender<WorkItem>,
    resp_tx: Sender<Response>,
    metrics: Arc<HashMap<String, Arc<CollHandles>>>,
    pressure: Arc<QueuePressure>,
    flight: Arc<FlightRecorder>,
) {
    let batcher = Batcher::new(cfg.batch);
    // PJRT runtime (if requested) must be constructed on this thread.
    let mut pjrt = match &cfg.projector {
        QueryProjectorKind::Pjrt(dir) => match crate::runtime::executor::open_shared(dir) {
            Ok(rt) => Some(crate::runtime::PjrtProjector::new(rt)),
            Err(e) => {
                eprintln!("engine: pjrt projector unavailable ({e}); using native");
                None
            }
        },
        QueryProjectorKind::Native => None,
    };

    while let Some(batch) = batcher.next_batch(&req_rx) {
        let bs = batch.len();
        // ORDERING: AcqRel — every request in this batch left the
        // queue; pairs with submit's pre-send increment, so this can
        // never underflow.
        pressure.depth.fetch_sub(bs, Ordering::AcqRel);
        // telemetry checked per batch: the disabled path skips every
        // clock read below (unless shedding or deadlines need one),
        // not just the record() calls
        let telem = obs::enabled();
        if telem {
            obs::handles().batcher_batch_size.record(bs as u64);
        }
        let need_clock = telem
            || cfg.shed.enabled()
            || batch.iter().any(|r| r.spec.timeout_ms.is_some());
        let dequeued = if need_clock { Some(Instant::now()) } else { None };
        // feed the shed policy the oldest queue wait in this batch:
        // that is what the NEXT admitted request is signing up for
        if cfg.shed.enabled() {
            if let Some(d) = dequeued {
                let oldest = batch
                    .iter()
                    .filter_map(|r| r.submitted)
                    .map(|t| d.duration_since(t))
                    .max()
                    .unwrap_or_default();
                // ORDERING: Release pairs with should_shed's Acquire.
                pressure
                    .wait_nanos
                    .store(oldest.as_nanos() as u64, Ordering::Release);
            }
        }
        // group the batch by collection: one projection matmul per
        // collection (each has its own model), insertion order kept so
        // single-collection batches stay one contiguous matmul.
        // Requests that already missed their deadline are shed here —
        // before paying for their share of the projection — and resolve
        // to a typed-error response (allow_partial requests continue:
        // the worker gives them whatever traversal can still gather).
        let mut groups: Vec<(Arc<Collection>, Vec<Request>)> = Vec::new();
        for req in batch {
            let name = req.spec.collection_name();
            let coll = match registry.get(name) {
                Some(c) => c,
                // submit_spec validated the name; a miss here means the
                // registry changed under us, which it never does
                None => continue,
            };
            let expired = match (dequeued, req.submitted, req.spec.timeout_ms) {
                (Some(now), Some(t0), Some(ms)) => {
                    !req.spec.allow_partial
                        && now.duration_since(t0) >= Duration::from_millis(ms)
                }
                _ => false,
            };
            if expired {
                let queue_s = match (dequeued, req.submitted) {
                    (Some(d), Some(t)) => d.duration_since(t).as_secs_f64(),
                    _ => 0.0,
                };
                let ch = metrics
                    .get(name)
                    .cloned()
                    .unwrap_or_else(|| Arc::new(CollHandles::resolve(name)));
                send_deadline_failure(
                    &resp_tx,
                    &flight,
                    &WorkItem {
                        req,
                        q_proj: Vec::new(),
                        batch_size: bs,
                        collection: Arc::clone(coll),
                        index: coll.index(),
                        deadline: None,
                        queue_s,
                        project_s: 0.0,
                        obs: ch,
                    },
                    telem,
                );
                continue;
            }
            match groups.iter_mut().find(|(c, _)| c.name() == name) {
                Some((_, reqs)) => reqs.push(req),
                None => groups.push((Arc::clone(coll), vec![req])),
            }
        }
        for (coll, reqs) in groups {
            // ONE serve-index snapshot per group: the projection below
            // uses this snapshot's model, and the same `Arc` ships in
            // every WorkItem, so a concurrent hot-swap can never pair
            // an old-model projection with a new index
            let index = coll.index();
            // project the group as one matmul: Q (B, D) x A^T -> (B, d).
            // The projection model is frozen even on live shards, so
            // batching is mutation-oblivious.
            let queries: Vec<Vec<f32>> = reqs.iter().map(|r| r.query.clone()).collect();
            let t_proj = if telem { Some(Instant::now()) } else { None };
            let projected: Vec<Vec<f32>> = match pjrt.as_mut() {
                Some(p) => {
                    use crate::index::builder::BatchProjector;
                    p.project(&index.model().a, &queries)
                }
                None => {
                    let qm = rows_to_matrix(&queries);
                    let proj: Matrix = qm.matmul_nt(&index.model().a); // (B, d)
                    (0..queries.len()).map(|i| proj.row(i).to_vec()).collect()
                }
            };
            let project_s = t_proj.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
            if telem {
                obs::handles().batcher_project.record_seconds(project_s);
            }
            // a request's share of its group's one matmul
            let project_share = project_s / reqs.len().max(1) as f64;
            let ch = metrics
                .get(coll.name())
                .cloned()
                .unwrap_or_else(|| Arc::new(CollHandles::resolve(coll.name())));
            for (req, q_proj) in reqs.into_iter().zip(projected.into_iter()) {
                let queue_s = match (dequeued, req.submitted) {
                    (Some(d), Some(t)) => d.duration_since(t).as_secs_f64(),
                    _ => 0.0,
                };
                if telem {
                    obs::handles().batcher_queue_wait.record_seconds(queue_s);
                }
                let deadline = match (req.submitted, req.spec.timeout_ms) {
                    (Some(t0), Some(ms)) => Some(t0 + Duration::from_millis(ms)),
                    _ => None,
                };
                if work_tx
                    .send(WorkItem {
                        req,
                        q_proj,
                        batch_size: bs,
                        collection: Arc::clone(&coll),
                        index: Arc::clone(&index),
                        deadline,
                        queue_s,
                        project_s: project_share,
                        obs: Arc::clone(&ch),
                    })
                    .is_err()
                {
                    return;
                }
            }
        }
    }
}

/// Pending-insert-log bound for the ingest lane: once this many inserts
/// accumulate in a shard since its last consolidation, the lane folds
/// that shard's log even with zero tombstones (insert-only workloads
/// must not grow the journal — and every snapshot's MUTLOG section —
/// without bound).
const INGEST_LOG_FOLD: usize = 65_536;

/// The ingest lane: apply mutations in submission order, routed to the
/// owning collection (and within it, to the owning shard by id hash);
/// rejections are counted, never fatal. After each applied mutation the
/// collection consolidates AT MOST ONE due shard
/// ([`ShardedIndex::consolidate_one`]) — staggered compaction, on this
/// thread, so the search workers never pay for it (searches proceed
/// concurrently through the rewiring phase and block only for the
/// final compaction swap).
fn ingest_loop(
    mut_rx: Receiver<(Arc<Collection>, Mutation)>,
    stats: Arc<IngestStats>,
    consolidate_threshold: f64,
) {
    // DEADLINE: blocking recv is the ingest lane's idle state;
    // quiesce/shutdown drop the sender, which ends the loop with Err.
    while let Ok((coll, m)) = mut_rx.recv() {
        let telem = obs::enabled();
        // snapshot the serve index once per mutation: a concurrent
        // hot-swap must not move the index between the apply and the
        // consolidation bookkeeping below
        let index = coll.index();
        let applied = match m {
            Mutation::Insert { ext_id, vector } => match index.insert(ext_id, &vector) {
                Ok(_) => {
                    // ORDERING: Relaxed — stat counter (reporting only).
                    stats.inserts.fetch_add(1, Ordering::Relaxed);
                    obs::handles().ingest_inserts.inc();
                    true
                }
                Err(e) => {
                    eprintln!("ingest[{}]: {e}", coll.name());
                    false
                }
            },
            Mutation::Delete { ext_id } => match index.delete(ext_id) {
                Ok(_) => {
                    // ORDERING: Relaxed — stat counter (reporting only).
                    stats.deletes.fetch_add(1, Ordering::Relaxed);
                    obs::handles().ingest_deletes.inc();
                    true
                }
                Err(e) => {
                    eprintln!("ingest[{}]: {e}", coll.name());
                    false
                }
            },
        };
        coll.finish_mutation();
        if !applied {
            // ORDERING: Relaxed — stat counter (reporting only).
            stats.errors.fetch_add(1, Ordering::Relaxed);
            obs::handles().ingest_errors.inc();
            continue;
        }
        // the log-size bound is independent of the tombstone trigger: a
        // disabled threshold must not disable the memory bound
        if let Some((_shard, report)) =
            index.consolidate_one(consolidate_threshold, INGEST_LOG_FOLD)
        {
            let nanos = (report.seconds * 1e9) as u64;
            // ORDERING: Relaxed — stat counters (reporting only).
            stats.consolidations.fetch_add(1, Ordering::Relaxed);
            // ORDERING: Relaxed — stat counter (reporting only).
            stats.consolidate_nanos.fetch_add(nanos, Ordering::Relaxed);
            let h = obs::handles();
            h.ingest_consolidations.inc();
            h.ingest_consolidate.record_seconds(report.seconds);
        }
        if telem {
            // worst live-shard tombstone fraction, after the (possible)
            // consolidation — this is the gauge operators alert on
            obs::handles()
                .ingest_tombstone
                .set(index.max_tombstone_fraction());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, ProjectionKind, Similarity};
    use crate::index::builder::IndexBuilder;
    use crate::index::query::VectorIndex;
    use crate::shard::{ShardSpec, TenantQuota};
    use crate::util::rng::Rng;

    fn build_index_sim(n: usize, dd: usize, d: usize, sim: Similarity) -> Arc<LeanVecIndex> {
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dd).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let mut gp = GraphParams::for_similarity(sim);
        gp.max_degree = 12;
        gp.build_window = 30;
        Arc::new(
            IndexBuilder::new()
                .projection(ProjectionKind::Id)
                .target_dim(d)
                .graph_params(gp)
                .build(&rows, None, sim),
        )
    }

    fn build_index(n: usize, dd: usize, d: usize) -> Arc<LeanVecIndex> {
        build_index_sim(n, dd, d, Similarity::InnerProduct)
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn serves_all_requests() {
        let index = build_index(300, 16, 8);
        let engine = Engine::start(
            index,
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
        );
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let q: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
            engine.submit(q, 5).unwrap();
        }
        let responses = engine.drain(50);
        assert_eq!(responses.len(), 50);
        for r in &responses {
            assert_eq!(r.ids.len(), 5);
            assert!(r.latency_s >= 0.0);
            assert!(r.batch_size >= 1);
        }
        engine.shutdown();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn run_workload_reports_recall_one() {
        // self-queries under L2 (self is always the true top-1; under IP
        // a higher-norm vector could legitimately outscore it)
        let index = build_index_sim(200, 12, 12, Similarity::L2); // d == D
        let queries: Vec<Vec<f32>> = (0..20u32).map(|i| index.secondary.decode(i)).collect();
        let truth: Vec<Vec<u32>> = (0..20u32).map(|i| vec![i]).collect();
        let (responses, report) = Engine::run_workload(
            index,
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
            &queries,
            1,
            Some(&truth),
        );
        assert_eq!(responses.len(), 20);
        assert!(report.recall_at_k >= 0.95, "{}", report.recall_at_k);
        assert!(report.metrics.qps > 0.0);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn shutdown_joins_cleanly() {
        let index = build_index(100, 8, 4);
        let engine = Engine::start(index, EngineConfig::default());
        engine.submit(vec![0.0; 8], 3).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(50));
        let rest = engine.shutdown();
        // the one response may have been drained here or not at all
        assert!(rest.len() <= 1);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn run_batch_direct_matches_engine_and_is_worker_count_invariant() {
        let index = build_index(250, 16, 8);
        let mut rng = Rng::new(13);
        let queries: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let params = SearchParams::default();
        let direct1 = Engine::run_batch_direct(&index, &queries, 5, params, 1);
        let direct3 = Engine::run_batch_direct(&index, &queries, 5, params, 3);
        assert_eq!(direct1, direct3, "results depend on worker count");
        // agrees with the channel-based engine
        let (mut responses, _) = Engine::run_workload(
            Arc::clone(&index),
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
            &queries,
            5,
            None,
        );
        responses.sort_by_key(|r| r.id);
        for (r, (ids, _)) in responses.iter().zip(direct1.iter()) {
            assert_eq!(&r.ids, ids);
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn engine_from_snapshot_matches_in_memory_engine() {
        let index = build_index(200, 16, 8);
        let path = std::env::temp_dir().join(format!(
            "leanvec-engine-snap-{}.leanvec",
            std::process::id()
        ));
        index
            .save(&path, &crate::index::persist::SnapshotMeta::default())
            .unwrap();
        let (engine, _meta) = Engine::start_from_snapshot(&path, |meta| EngineConfig {
            workers: 2,
            search: meta.search_defaults,
            ..EngineConfig::default()
        })
        .unwrap();
        let mut rng = Rng::new(17);
        let queries: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        for q in &queries {
            engine.submit(q.clone(), 5).unwrap();
        }
        let mut responses = engine.drain(queries.len());
        responses.sort_by_key(|r| r.id);
        engine.shutdown();
        for (r, q) in responses.iter().zip(queries.iter()) {
            let direct = index.search_one(&Query::new(q).k(5));
            assert_eq!(r.ids, direct.ids);
            assert_eq!(r.scores, direct.scores);
            assert_eq!(r.stats, direct.stats, "served stats match direct stats");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn responses_match_direct_search() {
        let index = build_index(250, 16, 8);
        let mut rng = Rng::new(11);
        let q: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        let direct = index.search_one(&Query::new(&q).k(5));
        let (responses, _) = Engine::run_workload(
            Arc::clone(&index),
            EngineConfig {
                workers: 1,
                ..EngineConfig::default()
            },
            &[q],
            5,
            None,
        );
        assert_eq!(responses[0].ids, direct.ids);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn live_engine_ingest_lane_applies_mutations_and_consolidates() {
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<f32>> = (0..300)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let mut gp = GraphParams::for_similarity(Similarity::L2);
        gp.max_degree = 12;
        gp.build_window = 30;
        let built = IndexBuilder::new()
            .projection(ProjectionKind::Id)
            .target_dim(8)
            .graph_params(gp)
            .build(&rows, None, Similarity::L2);
        let live = Arc::new(crate::mutate::LiveIndex::from_index(built));
        let mut engine = Engine::start_live(
            Arc::clone(&live),
            EngineConfig {
                workers: 2,
                consolidate_threshold: 0.05,
                ..EngineConfig::default()
            },
        );
        // mutations and searches interleaved on a running engine
        for i in 0..30u32 {
            engine.submit_delete(i).unwrap();
        }
        for i in 0..30u32 {
            let v: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
            engine.submit_insert(1000 + i, v).unwrap();
        }
        for q in rows.iter().take(20) {
            engine.submit(q.clone(), 5).unwrap();
        }
        let responses = engine.drain(20);
        assert_eq!(responses.len(), 20);
        for r in &responses {
            assert_eq!(r.ids.len(), 5);
        }
        engine.quiesce_mutations();
        let stats = engine.ingest_stats();
        assert_eq!(stats.inserts, 30);
        assert_eq!(stats.deletes, 30);
        assert_eq!(stats.errors, 0);
        assert!(stats.consolidations >= 1, "5% threshold crossed: {stats:?}");
        assert!(stats.consolidate_seconds >= 0.0);
        assert_eq!(live.live_len(), 300);
        // with the lane quiesced, deleted ids can never surface again
        let r = live.search_one(&Query::new(&rows[0]).k(10).window(60));
        assert!(
            r.ids.iter().all(|&id| id >= 30),
            "deleted id returned: {:?}",
            r.ids
        );
        engine.shutdown();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn frozen_engine_has_no_ingest_lane() {
        let index = build_index(100, 8, 4);
        let engine = Engine::start(index, EngineConfig::default());
        assert!(engine.live_index().is_none());
        let stats = engine.ingest_stats();
        assert_eq!(stats.inserts + stats.deletes + stats.errors, 0);
        // mutations are rejected with an error, not a panic
        assert_eq!(
            engine.submit_delete(0),
            Err(EngineError::NotLive {
                collection: DEFAULT_COLLECTION.to_string()
            })
        );
        engine.shutdown();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn per_request_spec_overrides_engine_defaults() {
        let index = build_index(400, 16, 8);
        // deliberately tiny engine-wide window so the override is visible
        let engine = Engine::start(
            Arc::clone(&index),
            EngineConfig {
                workers: 1,
                search: SearchParams {
                    window: 5,
                    rerank_window: 5,
                },
                ..EngineConfig::default()
            },
        );
        let mut rng = Rng::new(23);
        let q: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        engine.submit(q.clone(), 5).unwrap(); // engine defaults
        engine
            .submit_spec(
                q.clone(),
                QuerySpec::top_k(5).with_window(80).with_rerank_window(120),
            )
            .unwrap();
        let mut responses = engine.drain(2);
        responses.sort_by_key(|r| r.id);
        engine.shutdown();
        // the overridden request must match a direct search at its own
        // params, not the engine-wide ones
        let wide = index.search_one(&Query::new(&q).k(5).window(80).rerank_window(120));
        assert_eq!(responses[1].ids, wide.ids);
        assert_eq!(responses[1].stats, wide.stats);
        let narrow = index.search_one(&Query::new(&q).k(5).window(5));
        assert_eq!(responses[0].ids, narrow.ids);
        // wider window scores strictly more vectors
        assert!(responses[1].stats.primary_scored > responses[0].stats.primary_scored);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn engine_routes_requests_by_collection_name() {
        // two collections over DIFFERENT data; responses must come from
        // the one named in the spec
        let mut rng = Rng::new(41);
        let rows_a: Vec<Vec<f32>> = (0..150)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let rows_b: Vec<Vec<f32>> = (0..150)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let configure = |b: IndexBuilder| {
            let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
            gp.max_degree = 12;
            gp.build_window = 30;
            b.projection(ProjectionKind::Id).target_dim(8).graph_params(gp)
        };
        let sharded_a = ShardedIndex::build(
            &rows_a,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(1),
            1,
            configure,
        );
        let sharded_b = ShardedIndex::build(
            &rows_b,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(2),
            1,
            configure,
        );
        // keep plain handles for the direct-search oracle
        let oracle_a = ShardedIndex::build(
            &rows_a,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(1),
            1,
            configure,
        );
        let oracle_b = ShardedIndex::build(
            &rows_b,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(2),
            1,
            configure,
        );
        let mut registry = CollectionRegistry::new();
        registry.register(Collection::new("tenant-a", sharded_a));
        registry.register(Collection::new("tenant-b", sharded_b));
        let engine = Engine::start_collections(
            registry,
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
        );
        let q: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        let ra = engine
            .submit_spec(q.clone(), QuerySpec::top_k(5).with_collection("tenant-a"))
            .unwrap();
        let rb = engine
            .submit_spec(q.clone(), QuerySpec::top_k(5).with_collection("tenant-b"))
            .unwrap();
        // the default collection is not registered on this engine
        assert_eq!(
            engine.submit(q.clone(), 5),
            Err(EngineError::UnknownCollection(
                DEFAULT_COLLECTION.to_string()
            ))
        );
        assert_eq!(
            engine.submit_spec(q.clone(), QuerySpec::top_k(5).with_collection("ghost")),
            Err(EngineError::UnknownCollection("ghost".to_string()))
        );
        let mut responses = engine.drain(2);
        responses.sort_by_key(|r| r.id);
        engine.shutdown();
        let direct_a = oracle_a.search_one(&Query::new(&q).k(5));
        let direct_b = oracle_b.search_one(&Query::new(&q).k(5));
        let by_id = |id: u64| responses.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(ra).ids, direct_a.ids, "tenant-a served from a's data");
        assert_eq!(by_id(rb).ids, direct_b.ids, "tenant-b served from b's data");
        assert_ne!(direct_a.ids, direct_b.ids, "collections hold different data");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn quota_rejections_surface_as_errors_and_recover() {
        let index = build_index(150, 16, 8);
        let mut registry = CollectionRegistry::new();
        registry.register(
            Collection::new(DEFAULT_COLLECTION, ShardedIndex::from_single(index))
                .with_quota(TenantQuota {
                    max_inflight: 1,
                    max_pending_mutations: 0,
                }),
        );
        let engine = Engine::start_collections(
            registry,
            EngineConfig {
                workers: 1,
                ..EngineConfig::default()
            },
        );
        let q = vec![0.5f32; 16];
        engine.submit(q.clone(), 3).unwrap();
        // quota admits one in-flight search; keep submitting until the
        // first drains — every rejection must be the typed error
        let mut rejections = 0u32;
        loop {
            match engine.submit(q.clone(), 3) {
                Ok(_) => break,
                Err(EngineError::QuotaExceeded { collection }) => {
                    assert_eq!(collection, DEFAULT_COLLECTION);
                    rejections += 1;
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        let drained = engine.drain(2);
        assert_eq!(drained.len(), 2);
        let counters = engine.collection(DEFAULT_COLLECTION).unwrap().admission();
        assert_eq!(counters.submitted.load(Ordering::Relaxed), 2);
        assert_eq!(counters.rejected.load(Ordering::Relaxed) > 0, rejections > 0);
        engine.shutdown();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn quiesced_engine_rejects_mutations_with_error() {
        let index = build_index(120, 8, 4);
        let live = Arc::new(crate::mutate::LiveIndex::from_index(
            Arc::try_unwrap(index).expect("sole owner"),
        ));
        let mut engine = Engine::start_live(live, EngineConfig::default());
        engine.submit_insert(500, vec![0.1; 8]).unwrap();
        engine.quiesce_mutations();
        assert_eq!(
            engine.submit_insert(501, vec![0.1; 8]),
            Err(EngineError::Stopped)
        );
        assert_eq!(engine.submit_delete(500), Err(EngineError::Stopped));
        // searches still work after the mutation lane closed
        engine.submit(vec![0.1; 8], 3).unwrap();
        assert_eq!(engine.drain(1).len(), 1);
        assert_eq!(engine.ingest_stats().inserts, 1);
        engine.shutdown();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn sharded_live_engine_staggers_consolidation_across_shards() {
        let mut rng = Rng::new(7);
        let rows: Vec<Vec<f32>> = (0..400)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let configure = |b: IndexBuilder| {
            let mut gp = GraphParams::for_similarity(Similarity::L2);
            gp.max_degree = 12;
            gp.build_window = 30;
            b.projection(ProjectionKind::Id).target_dim(8).graph_params(gp)
        };
        let sharded = ShardedIndex::build_live(
            &rows,
            None,
            Similarity::L2,
            ShardSpec::new(3),
            1,
            configure,
        );
        let mut registry = CollectionRegistry::new();
        registry.register(Collection::new(DEFAULT_COLLECTION, sharded));
        let mut engine = Engine::start_collections(
            registry,
            EngineConfig {
                workers: 2,
                consolidate_threshold: 0.05,
                ..EngineConfig::default()
            },
        );
        for i in 0..80u32 {
            engine.submit_delete(i).unwrap();
        }
        for q in rows.iter().take(10) {
            engine.submit(q.clone(), 5).unwrap();
        }
        assert_eq!(engine.drain(10).len(), 10);
        engine.quiesce_mutations();
        let stats = engine.ingest_stats();
        assert_eq!(stats.deletes, 80);
        assert_eq!(stats.errors, 0);
        assert!(stats.consolidations >= 1, "{stats:?}");
        let coll = engine.collection(DEFAULT_COLLECTION).unwrap();
        // staggered passes keep every shard's fraction bounded near the
        // threshold (one due shard compacts per mutation, so the final
        // mutation may leave at most one shard marginally over it), and
        // no deleted id is ever served
        let ix = coll.index();
        assert!(ix.max_tombstone_fraction() < 0.10, "shards kept compacted");
        let r = ix.search_one(&Query::new(&rows[0]).k(10).window(60));
        assert!(r.ids.iter().all(|&id| id >= 80), "deleted id served: {:?}", r.ids);
        engine.shutdown();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn expired_deadline_resolves_to_exactly_one_error_response() {
        let index = build_index(200, 16, 8);
        let engine = Engine::start(
            index,
            EngineConfig {
                workers: 1,
                ..EngineConfig::default()
            },
        );
        let q = vec![0.5f32; 16];
        // a 0 ms deadline has always expired by the time the batcher
        // (or worker) looks: deterministic deadline failure
        let id = engine
            .submit_spec(q.clone(), QuerySpec::top_k(5).with_timeout_ms(0))
            .unwrap();
        let responses = engine.drain(1);
        assert_eq!(responses.len(), 1, "expired requests still respond");
        let r = &responses[0];
        assert_eq!(r.id, id);
        assert_eq!(r.error, Some(EngineError::DeadlineExceeded));
        assert!(!r.is_ok());
        assert!(r.ids.is_empty() && r.scores.is_empty());
        // the engine is healthy afterwards: normal requests still serve
        engine.submit(q.clone(), 5).unwrap();
        let ok = engine.drain(1);
        assert_eq!(ok.len(), 1);
        assert!(ok[0].is_ok());
        assert_eq!(ok[0].ids.len(), 5);
        // and with allow_partial the same expired deadline yields a
        // usable (partial) answer instead of an error
        engine
            .submit_spec(
                q.clone(),
                QuerySpec::top_k(5).with_timeout_ms(0).with_allow_partial(),
            )
            .unwrap();
        let partial = engine.drain(1);
        assert_eq!(partial.len(), 1);
        assert!(partial[0].is_ok(), "{:?}", partial[0].error);
        assert!(partial[0].partial, "deadline tripped mid-search");
        // admission bookkeeping: every path released its slot
        let adm = engine.collection(DEFAULT_COLLECTION).unwrap().admission();
        assert_eq!(adm.inflight.load(Ordering::Acquire), 0);
        engine.shutdown();
    }

    #[test]
    fn shed_policy_trips_on_depth_and_wait() {
        let p = QueuePressure::default();
        let off = ShedPolicy::default();
        assert!(!off.enabled());
        assert_eq!(off.should_shed(&p), None, "disabled policy never sheds");

        let by_depth = ShedPolicy {
            max_queue_depth: 4,
            max_queue_wait_ms: 0,
        };
        assert!(by_depth.enabled());
        p.depth.store(3, Ordering::Release);
        assert_eq!(by_depth.should_shed(&p), None, "under the bound");
        p.depth.store(4, Ordering::Release);
        assert!(by_depth.should_shed(&p).is_some(), "at the bound");

        let by_wait = ShedPolicy {
            max_queue_depth: 0,
            max_queue_wait_ms: 10,
        };
        p.depth.store(0, Ordering::Release);
        p.wait_nanos.store(50_000_000, Ordering::Release); // 50 ms
        assert_eq!(
            by_wait.should_shed(&p),
            None,
            "stale wait with an empty queue never sheds"
        );
        p.depth.store(1, Ordering::Release);
        let hint = by_wait.should_shed(&p).expect("over the wait budget");
        assert_eq!(hint, 50, "retry hint is the measured wait");
        p.wait_nanos.store(0, Ordering::Release);
        assert_eq!(by_wait.should_shed(&p), None, "wait cleared");
        // the hint is never 0 even when the measured wait rounds to it
        p.wait_nanos.store(100, Ordering::Release); // 100 ns
        p.depth.store(100, Ordering::Release);
        let both = ShedPolicy {
            max_queue_depth: 4,
            max_queue_wait_ms: 0,
        };
        assert_eq!(both.should_shed(&p), Some(1));
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn overload_shedding_rejects_at_admission_with_retry_hint() {
        let index = build_index(150, 16, 8);
        let engine = Engine::start(
            index,
            EngineConfig {
                workers: 1,
                shed: ShedPolicy {
                    max_queue_depth: 2,
                    max_queue_wait_ms: 0,
                },
                ..EngineConfig::default()
            },
        );
        let q = vec![0.5f32; 16];
        // simulate a backed-up queue (the depth gauge is exactly what
        // submit_spec consults)
        engine.pressure.depth.fetch_add(5, Ordering::AcqRel);
        match engine.submit(q.clone(), 3) {
            Err(EngineError::Overloaded { retry_after_ms }) => {
                assert!(retry_after_ms >= 1, "hint never reads as 'now'");
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // shed before quota: no admission slot was consumed
        let adm = engine.collection(DEFAULT_COLLECTION).unwrap().admission();
        assert_eq!(adm.inflight.load(Ordering::Acquire), 0);
        assert_eq!(adm.submitted.load(Ordering::Relaxed), 0);
        // pressure released -> admission recovers
        engine.pressure.depth.fetch_sub(5, Ordering::AcqRel);
        engine.submit(q, 3).unwrap();
        assert_eq!(engine.drain(1).len(), 1);
        engine.shutdown();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn swap_collection_replaces_serve_index_without_dropping_queries() {
        // two indexes over DIFFERENT data, same dimensionality
        let index_a = build_index(150, 16, 8);
        let index_b = {
            let mut rng = Rng::new(77);
            let rows: Vec<Vec<f32>> = (0..150)
                .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
                .collect();
            let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
            gp.max_degree = 12;
            gp.build_window = 30;
            Arc::new(
                IndexBuilder::new()
                    .projection(ProjectionKind::Id)
                    .target_dim(8)
                    .graph_params(gp)
                    .build(&rows, None, Similarity::InnerProduct),
            )
        };
        let path = std::env::temp_dir().join(format!(
            "leanvec-swap-test-{}.leanvec",
            std::process::id()
        ));
        index_b
            .save(&path, &crate::index::persist::SnapshotMeta::default())
            .unwrap();
        let engine = Engine::start(
            Arc::clone(&index_a),
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
        );
        let q = vec![0.5f32; 16];
        engine.submit(q.clone(), 5).unwrap();
        let before = engine.drain(1);
        assert_eq!(before[0].ids, index_a.search_one(&Query::new(&q).k(5)).ids);

        let report = engine.swap_collection(DEFAULT_COLLECTION, &path).unwrap();
        assert_eq!(report.collection, DEFAULT_COLLECTION);
        assert_eq!(report.shards, 1);
        assert!(report.drained, "no queries in flight -> immediate drain");

        // queries submitted after the swap are answered by the NEW data
        engine.submit(q.clone(), 5).unwrap();
        let after = engine.drain(1);
        assert_eq!(after[0].ids, index_b.search_one(&Query::new(&q).k(5)).ids);
        assert_ne!(before[0].ids, after[0].ids, "snapshots hold different data");

        // a dimension-incompatible snapshot is refused and the engine
        // keeps serving the current index
        let bad = build_index(100, 12, 6);
        let bad_path = std::env::temp_dir().join(format!(
            "leanvec-swap-bad-{}.leanvec",
            std::process::id()
        ));
        bad.save(&bad_path, &crate::index::persist::SnapshotMeta::default())
            .unwrap();
        match engine.swap_collection(DEFAULT_COLLECTION, &bad_path) {
            Err(EngineError::SwapFailed { collection, reason }) => {
                assert_eq!(collection, DEFAULT_COLLECTION);
                assert!(reason.contains("dimension"), "{reason}");
            }
            other => panic!("expected SwapFailed, got {other:?}"),
        }
        engine.submit(q.clone(), 5).unwrap();
        assert!(engine.drain(1)[0].is_ok(), "old index still serving");
        // unknown collections are their own error class, not SwapFailed
        assert_eq!(
            engine.swap_collection("ghost", &path),
            Err(EngineError::UnknownCollection("ghost".to_string()))
        );
        engine.shutdown();
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&bad_path).ok();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn live_collections_refuse_to_hot_swap() {
        let index = build_index(120, 8, 4);
        let live = Arc::new(crate::mutate::LiveIndex::from_index(
            Arc::try_unwrap(index).expect("sole owner"),
        ));
        let engine = Engine::start_live(live, EngineConfig::default());
        let path = std::env::temp_dir().join("leanvec-swap-never-read.leanvec");
        match engine.swap_collection(DEFAULT_COLLECTION, &path) {
            Err(EngineError::SwapFailed { reason, .. }) => {
                assert!(reason.contains("live"), "{reason}");
            }
            other => panic!("expected SwapFailed, got {other:?}"),
        }
        engine.shutdown();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn admission_counters_never_leak_under_concurrent_error_storm() {
        // satellite invariant: whatever mix of success, quota rejection,
        // and deadline failure a submission storm produces, every
        // admitted request resolves exactly once and the in-flight gauge
        // returns to zero — no slot leaks on any error path.
        let index = build_index(200, 16, 8);
        let mut registry = CollectionRegistry::new();
        registry.register(
            Collection::new(DEFAULT_COLLECTION, ShardedIndex::from_single(index))
                .with_quota(TenantQuota {
                    max_inflight: 4,
                    max_pending_mutations: 0,
                }),
        );
        let engine = Engine::start_collections(
            registry,
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
        );
        let admitted = AtomicUsize::new(0);
        let quota_rejected = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for t in 0..4 {
                let engine = &engine;
                let admitted = &admitted;
                let quota_rejected = &quota_rejected;
                scope.spawn(move || {
                    let q = vec![0.25f32 * (t + 1) as f32; 16];
                    for i in 0..50 {
                        // every third request carries an already-expired
                        // deadline: the failure path runs under load too
                        let spec = if i % 3 == 0 {
                            QuerySpec::top_k(3).with_timeout_ms(0)
                        } else {
                            QuerySpec::top_k(3)
                        };
                        match engine.submit_spec(q.clone(), spec) {
                            Ok(_) => {
                                admitted.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(EngineError::QuotaExceeded { .. }) => {
                                quota_rejected.fetch_add(1, Ordering::Relaxed);
                                std::thread::yield_now();
                            }
                            Err(e) => panic!("unexpected error: {e}"),
                        }
                    }
                });
            }
        });
        let n = admitted.load(Ordering::Relaxed);
        assert!(n > 0, "storm admitted nothing");
        let responses = engine.drain(n);
        assert_eq!(responses.len(), n, "every admitted request resolves once");
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "no duplicate resolutions");
        let adm = engine.collection(DEFAULT_COLLECTION).unwrap().admission();
        assert_eq!(adm.inflight.load(Ordering::Acquire), 0, "slots all released");
        assert_eq!(adm.submitted.load(Ordering::Relaxed) as usize, n);
        assert_eq!(
            adm.rejected.load(Ordering::Relaxed) as usize,
            quota_rejected.load(Ordering::Relaxed)
        );
        // the storm's deadline failures are visible as typed errors
        assert!(
            responses.iter().any(|r| !r.is_ok()),
            "some 0 ms deadlines must have expired"
        );
        engine.shutdown();
    }

    #[test]
    fn engine_is_send_and_sync() {
        // concurrent submitters share &Engine across threads (and the
        // chaos battery leans on it); losing Sync is an API break
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Engine>();
    }
}
