//! The serving engine: batcher thread + worker pool over a shared
//! [`LeanVecIndex`].

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::{Metrics, ServeReport};
use super::protocol::{QuerySpec, Request, Response};
use crate::index::leanvec_index::{LeanVecIndex, SearchParams};
use crate::index::query::Query;
use crate::graph::beam::SearchCtx;
use crate::leanvec::model::rows_to_matrix;
use crate::linalg::Matrix;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// How the batcher projects query batches.
#[derive(Clone, Debug)]
pub enum QueryProjectorKind {
    /// native matmul on the batcher thread
    Native,
    /// PJRT `project_q` artifact from this directory (the runtime is
    /// constructed *on the batcher thread* — PJRT handles are not Send)
    Pjrt(std::path::PathBuf),
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
    pub search: SearchParams,
    pub projector: QueryProjectorKind,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            batch: BatchPolicy::default(),
            search: SearchParams::default(),
            projector: QueryProjectorKind::Native,
        }
    }
}

/// A running engine. Submit requests, then `drain` responses.
pub struct Engine {
    req_tx: Option<Sender<Request>>,
    resp_rx: Receiver<Response>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    started: Instant,
}

/// Work item: one request plus its projected query.
struct WorkItem {
    req: Request,
    q_proj: Vec<f32>,
    batch_size: usize,
}

impl Engine {
    /// Start a serving engine directly from an on-disk index snapshot
    /// (see `crate::index::persist`): the build/serve split. The
    /// training, projection and graph-construction paths are never
    /// touched — the process goes from snapshot bytes to answering
    /// queries.
    ///
    /// `cfg` receives the snapshot's metadata before the engine starts,
    /// so the recommended serving parameters it carries are usable:
    ///
    /// ```ignore
    /// let (engine, _meta) = Engine::start_from_snapshot(path, |meta| EngineConfig {
    ///     search: meta.search_defaults,
    ///     ..EngineConfig::default()
    /// })?;
    /// ```
    pub fn start_from_snapshot<F>(
        path: &std::path::Path,
        cfg: F,
    ) -> Result<(Engine, crate::index::persist::SnapshotMeta), crate::index::persist::SnapshotError>
    where
        F: FnOnce(&crate::index::persist::SnapshotMeta) -> EngineConfig,
    {
        let (index, meta) = LeanVecIndex::load(path)?;
        let cfg = cfg(&meta);
        Ok((Engine::start(Arc::new(index), cfg), meta))
    }

    pub fn start(index: Arc<LeanVecIndex>, cfg: EngineConfig) -> Engine {
        let (req_tx, req_rx) = channel::<Request>();
        let (work_tx, work_rx) = channel::<WorkItem>();
        let (resp_tx, resp_rx) = channel::<Response>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        // --- batcher thread: batch, project, fan out
        let bindex = Arc::clone(&index);
        let bcfg = cfg.clone();
        let batcher = std::thread::Builder::new()
            .name("leanvec-batcher".into())
            .spawn(move || {
                batcher_loop(bindex, bcfg, req_rx, work_tx);
            })
            .expect("spawn batcher");

        // --- workers: search + rerank
        let workers = (0..cfg.workers.max(1))
            .map(|w| {
                let windex = Arc::clone(&index);
                let wrx = Arc::clone(&work_rx);
                let wtx = resp_tx.clone();
                let search = cfg.search;
                std::thread::Builder::new()
                    .name(format!("leanvec-search-{w}"))
                    .spawn(move || {
                        let mut ctx = SearchCtx::new(windex.len());
                        loop {
                            let item = { wrx.lock().unwrap().recv() };
                            let item = match item {
                                Ok(i) => i,
                                Err(_) => break,
                            };
                            // per-request spec wins over the engine-wide
                            // defaults; the allow-list becomes a filter
                            // predicate pushed into traversal
                            let result = {
                                let spec = &item.req.spec;
                                let params = resolve_spec(spec, search);
                                let base = Query::new(&item.req.query)
                                    .k(spec.k)
                                    .window(params.window)
                                    .rerank_window(params.rerank_window);
                                match spec.allow.as_ref() {
                                    // the set was built once at spec
                                    // construction; here it is only read
                                    Some(allow) => {
                                        let pred = |id: u32| allow.contains(&id);
                                        windex.search_prepared(
                                            &mut ctx,
                                            &item.q_proj,
                                            &base.filter(&pred),
                                        )
                                    }
                                    None => windex.search_prepared(
                                        &mut ctx,
                                        &item.q_proj,
                                        &base,
                                    ),
                                }
                            };
                            let latency_s = item
                                .req
                                .submitted
                                .map(|t| t.elapsed().as_secs_f64())
                                .unwrap_or(0.0);
                            let _ = wtx.send(Response {
                                id: item.req.id,
                                ids: result.ids,
                                scores: result.scores,
                                stats: result.stats,
                                latency_s,
                                batch_size: item.batch_size,
                            });
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();

        Engine {
            req_tx: Some(req_tx),
            resp_rx,
            batcher: Some(batcher),
            workers,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// Submit one query with engine-default knobs; returns its request
    /// id.
    pub fn submit(&self, query: Vec<f32>, k: usize) -> u64 {
        self.submit_spec(query, QuerySpec::top_k(k))
    }

    /// Submit one query with per-request knobs (window / rerank-window
    /// overrides, allow-list filter); returns its request id.
    pub fn submit_spec(&self, query: Vec<f32>, spec: QuerySpec) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request::with_spec(id, query, spec);
        req.submitted = Some(Instant::now());
        self.req_tx
            .as_ref()
            .expect("engine running")
            .send(req)
            .expect("batcher alive");
        id
    }

    /// Blockingly collect `n` responses.
    pub fn drain(&self, n: usize) -> Vec<Response> {
        (0..n)
            .map(|_| self.resp_rx.recv().expect("workers alive"))
            .collect()
    }

    /// Stop accepting requests, join all threads.
    pub fn shutdown(mut self) -> Vec<Response> {
        drop(self.req_tx.take());
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // collect any leftover responses
        let mut rest = Vec::new();
        while let Ok(r) = self.resp_rx.try_recv() {
            rest.push(r);
        }
        rest
    }

    pub fn uptime(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Direct parallel batch path (no channels): project the whole
    /// batch as ONE matmul on the calling thread — the same
    /// amortization the batcher thread performs — then fan the searches
    /// out across `workers` threads with pooled contexts, the same
    /// chunking discipline as the parallel index builder. Returns
    /// `(ids, scores)` per query, in query order, identical to serial
    /// per-query trait searches for every worker count.
    pub fn run_batch_direct(
        index: &LeanVecIndex,
        queries: &[Vec<f32>],
        k: usize,
        params: SearchParams,
        workers: usize,
    ) -> Vec<(Vec<u32>, Vec<f32>)> {
        if queries.is_empty() {
            return Vec::new();
        }
        // batched projection: Q (B, D) x A^T -> (B, d)
        let qm = rows_to_matrix(queries);
        let proj: Matrix = qm.matmul_nt(&index.model.a);
        index.batch_fan_out(queries.len(), workers, |ctx, i| {
            let query = Query::new(&queries[i])
                .k(k)
                .window(params.window)
                .rerank_window(params.rerank_window);
            let r = index.search_prepared(ctx, proj.row(i), &query);
            (r.ids, r.scores)
        })
    }

    /// Convenience: run a closed-loop workload and report (used by the
    /// e2e example and the serving benches).
    pub fn run_workload(
        index: Arc<LeanVecIndex>,
        cfg: EngineConfig,
        queries: &[Vec<f32>],
        k: usize,
        truth: Option<&[Vec<u32>]>,
    ) -> (Vec<Response>, ServeReport) {
        let engine = Engine::start(index, cfg);
        let t0 = Instant::now();
        for q in queries {
            engine.submit(q.clone(), k);
        }
        let mut responses = engine.drain(queries.len());
        let wall = t0.elapsed().as_secs_f64();
        let mut leftovers = engine.shutdown();
        responses.append(&mut leftovers);
        responses.sort_by_key(|r| r.id);
        let report = match truth {
            Some(t) => ServeReport::new(&responses, t, k, wall),
            None => ServeReport {
                metrics: Metrics::from_responses(&responses, wall),
                recall_at_k: f64::NAN,
                k,
            },
        };
        (responses, report)
    }
}

/// Resolve a request's [`QuerySpec`] against the engine-wide defaults
/// via the one shared rule ([`crate::index::query::resolve_params`]).
/// The results are clamped to >= 1 so a malformed spec degrades
/// instead of panicking the worker.
fn resolve_spec(spec: &QuerySpec, defaults: SearchParams) -> SearchParams {
    let p = crate::index::query::resolve_params(spec.window, spec.rerank_window, defaults);
    SearchParams {
        window: p.window.max(1),
        rerank_window: p.rerank_window.max(1),
    }
}

fn batcher_loop(
    index: Arc<LeanVecIndex>,
    cfg: EngineConfig,
    req_rx: Receiver<Request>,
    work_tx: Sender<WorkItem>,
) {
    let batcher = Batcher::new(cfg.batch);
    // PJRT runtime (if requested) must be constructed on this thread.
    let mut pjrt = match &cfg.projector {
        QueryProjectorKind::Pjrt(dir) => match crate::runtime::executor::open_shared(dir) {
            Ok(rt) => Some(crate::runtime::PjrtProjector::new(rt)),
            Err(e) => {
                eprintln!("engine: pjrt projector unavailable ({e}); using native");
                None
            }
        },
        QueryProjectorKind::Native => None,
    };

    while let Some(batch) = batcher.next_batch(&req_rx) {
        let bs = batch.len();
        // project the whole batch as one matmul: (d, D) x (D, B)
        let queries: Vec<Vec<f32>> = batch.iter().map(|r| r.query.clone()).collect();
        let projected: Vec<Vec<f32>> = match pjrt.as_mut() {
            Some(p) => {
                use crate::index::builder::BatchProjector;
                p.project(&index.model.a, &queries)
            }
            None => {
                // single matmul on the batcher thread: Q (B, D) x A^T
                let qm = rows_to_matrix(&queries);
                let proj: Matrix = qm.matmul_nt(&index.model.a); // (B, d)
                (0..bs).map(|i| proj.row(i).to_vec()).collect()
            }
        };
        for (req, q_proj) in batch.into_iter().zip(projected.into_iter()) {
            if work_tx
                .send(WorkItem {
                    req,
                    q_proj,
                    batch_size: bs,
                })
                .is_err()
            {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, ProjectionKind, Similarity};
    use crate::index::builder::IndexBuilder;
    use crate::index::query::VectorIndex;
    use crate::util::rng::Rng;

    fn build_index_sim(n: usize, dd: usize, d: usize, sim: Similarity) -> Arc<LeanVecIndex> {
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dd).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let mut gp = GraphParams::for_similarity(sim);
        gp.max_degree = 12;
        gp.build_window = 30;
        Arc::new(
            IndexBuilder::new()
                .projection(ProjectionKind::Id)
                .target_dim(d)
                .graph_params(gp)
                .build(&rows, None, sim),
        )
    }

    fn build_index(n: usize, dd: usize, d: usize) -> Arc<LeanVecIndex> {
        build_index_sim(n, dd, d, Similarity::InnerProduct)
    }

    #[test]
    fn serves_all_requests() {
        let index = build_index(300, 16, 8);
        let engine = Engine::start(
            index,
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
        );
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let q: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
            engine.submit(q, 5);
        }
        let responses = engine.drain(50);
        assert_eq!(responses.len(), 50);
        for r in &responses {
            assert_eq!(r.ids.len(), 5);
            assert!(r.latency_s >= 0.0);
            assert!(r.batch_size >= 1);
        }
        engine.shutdown();
    }

    #[test]
    fn run_workload_reports_recall_one() {
        // self-queries under L2 (self is always the true top-1; under IP
        // a higher-norm vector could legitimately outscore it)
        let index = build_index_sim(200, 12, 12, Similarity::L2); // d == D
        let queries: Vec<Vec<f32>> = (0..20u32).map(|i| index.secondary.decode(i)).collect();
        let truth: Vec<Vec<u32>> = (0..20u32).map(|i| vec![i]).collect();
        let (responses, report) = Engine::run_workload(
            index,
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
            &queries,
            1,
            Some(&truth),
        );
        assert_eq!(responses.len(), 20);
        assert!(report.recall_at_k >= 0.95, "{}", report.recall_at_k);
        assert!(report.metrics.qps > 0.0);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let index = build_index(100, 8, 4);
        let engine = Engine::start(index, EngineConfig::default());
        engine.submit(vec![0.0; 8], 3);
        std::thread::sleep(std::time::Duration::from_millis(50));
        let rest = engine.shutdown();
        // the one response may have been drained here or not at all
        assert!(rest.len() <= 1);
    }

    #[test]
    fn run_batch_direct_matches_engine_and_is_worker_count_invariant() {
        let index = build_index(250, 16, 8);
        let mut rng = Rng::new(13);
        let queries: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let params = SearchParams::default();
        let direct1 = Engine::run_batch_direct(&index, &queries, 5, params, 1);
        let direct3 = Engine::run_batch_direct(&index, &queries, 5, params, 3);
        assert_eq!(direct1, direct3, "results depend on worker count");
        // agrees with the channel-based engine
        let (mut responses, _) = Engine::run_workload(
            Arc::clone(&index),
            EngineConfig {
                workers: 2,
                ..EngineConfig::default()
            },
            &queries,
            5,
            None,
        );
        responses.sort_by_key(|r| r.id);
        for (r, (ids, _)) in responses.iter().zip(direct1.iter()) {
            assert_eq!(&r.ids, ids);
        }
    }

    #[test]
    fn engine_from_snapshot_matches_in_memory_engine() {
        let index = build_index(200, 16, 8);
        let path = std::env::temp_dir().join(format!(
            "leanvec-engine-snap-{}.leanvec",
            std::process::id()
        ));
        index
            .save(&path, &crate::index::persist::SnapshotMeta::default())
            .unwrap();
        let (engine, _meta) = Engine::start_from_snapshot(&path, |meta| EngineConfig {
            workers: 2,
            search: meta.search_defaults,
            ..EngineConfig::default()
        })
        .unwrap();
        let mut rng = Rng::new(17);
        let queries: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        for q in &queries {
            engine.submit(q.clone(), 5);
        }
        let mut responses = engine.drain(queries.len());
        responses.sort_by_key(|r| r.id);
        engine.shutdown();
        for (r, q) in responses.iter().zip(queries.iter()) {
            let direct = index.search_one(&Query::new(q).k(5));
            assert_eq!(r.ids, direct.ids);
            assert_eq!(r.scores, direct.scores);
            assert_eq!(r.stats, direct.stats, "served stats match direct stats");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn responses_match_direct_search() {
        let index = build_index(250, 16, 8);
        let mut rng = Rng::new(11);
        let q: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        let direct = index.search_one(&Query::new(&q).k(5));
        let (responses, _) = Engine::run_workload(
            Arc::clone(&index),
            EngineConfig {
                workers: 1,
                ..EngineConfig::default()
            },
            &[q],
            5,
            None,
        );
        assert_eq!(responses[0].ids, direct.ids);
    }

    #[test]
    fn per_request_spec_overrides_engine_defaults() {
        let index = build_index(400, 16, 8);
        // deliberately tiny engine-wide window so the override is visible
        let engine = Engine::start(
            Arc::clone(&index),
            EngineConfig {
                workers: 1,
                search: SearchParams {
                    window: 5,
                    rerank_window: 5,
                },
                ..EngineConfig::default()
            },
        );
        let mut rng = Rng::new(23);
        let q: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        engine.submit(q.clone(), 5); // engine defaults
        engine.submit_spec(
            q.clone(),
            QuerySpec::top_k(5).with_window(80).with_rerank_window(120),
        );
        let mut responses = engine.drain(2);
        responses.sort_by_key(|r| r.id);
        engine.shutdown();
        // the overridden request must match a direct search at its own
        // params, not the engine-wide ones
        let wide = index.search_one(&Query::new(&q).k(5).window(80).rerank_window(120));
        assert_eq!(responses[1].ids, wide.ids);
        assert_eq!(responses[1].stats, wide.stats);
        let narrow = index.search_one(&Query::new(&q).k(5).window(5));
        assert_eq!(responses[0].ids, narrow.ids);
        // wider window scores strictly more vectors
        assert!(responses[1].stats.primary_scored > responses[0].stats.primary_scored);
    }
}
