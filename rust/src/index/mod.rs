//! Search indices: the LeanVec search-and-rerank index (the paper's
//! system), the flat exhaustive baseline/oracle, and an IVF-PQ baseline
//! (FAISS-IVFPQfs stand-in).

pub mod builder;
pub mod flat;
pub mod ivfpq;
pub mod leanvec_index;

pub use builder::{IndexBuilder, SearchIndex};
pub use flat::FlatIndex;
pub use ivfpq::{IvfPqIndex, IvfPqParams};
pub use leanvec_index::{LeanVecIndex, SearchParams};
