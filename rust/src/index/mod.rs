//! Search indices: the LeanVec search-and-rerank index (the paper's
//! system), the flat exhaustive baseline/oracle, an IVF-PQ baseline
//! (FAISS-IVFPQfs stand-in), and the versioned snapshot layer
//! ([`persist`]) that round-trips a built index to disk.
//!
//! All of them speak one API ([`query`]): a [`Query`] builder in, a
//! [`SearchResult`] out, through the [`VectorIndex`] trait.

pub mod builder;
pub mod flat;
pub mod ivfpq;
pub mod leanvec_index;
pub mod persist;
pub mod query;

pub use builder::{IndexBuilder, SearchIndex};
pub use flat::FlatIndex;
pub use ivfpq::{IvfPqIndex, IvfPqParams};
pub use leanvec_index::{LeanVecIndex, SearchParams};
pub use persist::{MmapPolicy, SnapshotError, SnapshotMeta, Tier};
pub use query::{Query, QueryStats, SearchResult, VectorIndex};
