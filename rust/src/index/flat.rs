//! Flat (exhaustive) index — the accuracy oracle, the ground-truth
//! generator, and the brute-force baseline in Fig. 11. Speaks the
//! unified [`VectorIndex`] API (including filtered search, which makes
//! it the exact oracle for filtered queries too); the tuple-returning
//! [`FlatIndex::search`] shorthand stays for ground-truth call sites.

use crate::config::Similarity;
use crate::graph::beam::SearchCtx;
use crate::index::query::{Query, QueryStats, SearchResult, VectorIndex};
use crate::quant::{F32Store, ScoreStore};

pub struct FlatIndex {
    store: F32Store,
    sim: Similarity,
}

impl FlatIndex {
    pub fn new(rows: &[Vec<f32>], sim: Similarity) -> FlatIndex {
        FlatIndex {
            store: F32Store::from_rows(rows),
            sim,
        }
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.len() == 0
    }

    /// Exact score ("bigger is better") of one database vector.
    pub fn score_one(&self, q: &[f32], id: u32) -> f32 {
        let pq = self.store.prepare(q, self.sim);
        self.store.score(&pq, id)
    }

    /// Exact top-k by full scan — oracle shorthand for
    /// `VectorIndex::search`. Returns (ids, scores) best-first.
    pub fn search(&self, q: &[f32], k: usize) -> (Vec<u32>, Vec<f32>) {
        let r = VectorIndex::search(self, &mut SearchCtx::new(0), &Query::new(q).k(k));
        (r.ids, r.scores)
    }
}

impl VectorIndex for FlatIndex {
    /// Exact top-k by *blocked* full scan; `window`/`rerank_window` are
    /// irrelevant and ignored. Filtered-out ids are skipped before
    /// scoring, so the result is the exact filtered oracle. The scan
    /// gathers passing ids in fixed-size blocks and scores each block
    /// through [`crate::quant::ScoreStore::score_block`] (dispatched
    /// SIMD kernels + row prefetch); the selection update runs in id
    /// order, so results are identical to the per-id scan.
    fn search(&self, _ctx: &mut SearchCtx, query: &Query) -> SearchResult {
        // ids scored per `score_block` call (amortizes the call and
        // keeps the prefetch pipeline fed without outgrowing L1)
        const SCAN_BLOCK: usize = 128;
        let pq = self.store.prepare(query.vector(), self.sim);
        let n = self.store.len();
        let k = query.top_k().min(n);
        let filter = query.filter_fn();
        let mut filtered = 0usize;
        let mut scored = 0usize;
        // bounded selection: keep a sorted top-k vector (k is small)
        let mut top: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
        let mut block: Vec<u32> = Vec::with_capacity(SCAN_BLOCK);
        let mut scores: Vec<f32> = Vec::with_capacity(SCAN_BLOCK);
        let mut start = 0usize;
        while start < n {
            let end = (start + SCAN_BLOCK).min(n);
            block.clear();
            for id in start as u32..end as u32 {
                if let Some(f) = filter {
                    if !f(id) {
                        filtered += 1;
                        continue;
                    }
                }
                block.push(id);
            }
            scores.clear();
            self.store.score_block(&pq, &block, &mut scores);
            scored += block.len();
            for (&id, &s) in block.iter().zip(scores.iter()) {
                if top.len() < k {
                    top.push((s, id));
                    // total_cmp: a NaN score must never panic mid-serve
                    top.sort_by(|a, b| b.0.total_cmp(&a.0));
                } else if k > 0 && s > top[k - 1].0 {
                    top[k - 1] = (s, id);
                    let mut i = k - 1;
                    while i > 0 && top[i].0 > top[i - 1].0 {
                        top.swap(i, i - 1);
                        i -= 1;
                    }
                }
            }
            start = end;
        }
        SearchResult {
            ids: top.iter().map(|&(_, id)| id).collect(),
            scores: top.iter().map(|&(s, _)| s).collect(),
            stats: QueryStats {
                primary_scored: scored,
                reranked: 0,
                bytes_touched: scored * self.store.bytes_per_vector(),
                hops: 0,
                filtered,
                deleted_skipped: 0,
            },
            ..SearchResult::default()
        }
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn dim(&self) -> usize {
        self.store.dim()
    }

    fn sim(&self) -> Similarity {
        self.sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::{dot, l2_sq};
    use crate::util::rng::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    #[test]
    fn matches_naive_argsort_ip() {
        let rs = rows(100, 8, 1);
        let idx = FlatIndex::new(&rs, Similarity::InnerProduct);
        let q: Vec<f32> = rows(1, 8, 2).pop().unwrap();
        let (ids, scores) = idx.search(&q, 10);
        let mut want: Vec<u32> = (0..100).collect();
        want.sort_by(|&a, &b| {
            dot(&q, &rs[b as usize])
                .partial_cmp(&dot(&q, &rs[a as usize]))
                .unwrap()
        });
        assert_eq!(ids, want[..10].to_vec());
        for w in scores.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn matches_naive_argsort_l2() {
        let rs = rows(80, 6, 3);
        let idx = FlatIndex::new(&rs, Similarity::L2);
        let q: Vec<f32> = rows(1, 6, 4).pop().unwrap();
        let (ids, _) = idx.search(&q, 5);
        let mut want: Vec<u32> = (0..80).collect();
        want.sort_by(|&a, &b| {
            l2_sq(&q, &rs[a as usize])
                .partial_cmp(&l2_sq(&q, &rs[b as usize]))
                .unwrap()
        });
        assert_eq!(ids, want[..5].to_vec());
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let rs = rows(5, 4, 5);
        let idx = FlatIndex::new(&rs, Similarity::InnerProduct);
        let (ids, _) = idx.search(&rs[0], 50);
        assert_eq!(ids.len(), 5);
    }

    #[test]
    fn self_query_is_top1_l2() {
        let rs = rows(50, 8, 6);
        let idx = FlatIndex::new(&rs, Similarity::L2);
        for probe in [0usize, 17, 49] {
            let (ids, _) = idx.search(&rs[probe], 1);
            assert_eq!(ids[0], probe as u32);
        }
    }
}
