//! Index construction front-end + the unified index enum used by the
//! experiment harness.

use crate::config::{BuildParams, Compression, GraphParams, ProjectionKind, Similarity};
use crate::graph::beam::SearchCtx;
use crate::graph::hnsw::{HnswGraph, HnswParams};
use crate::graph::vamana::VamanaBuilder;
use crate::index::flat::FlatIndex;
use crate::index::ivfpq::IvfPqIndex;
use crate::index::leanvec_index::{make_store, make_store_threads, BuildBreakdown, LeanVecIndex};
use crate::index::query::{Query, SearchResult, VectorIndex};
use crate::leanvec::model::{train_projection, LeanVecModel, TrainBackends};
use crate::linalg::matrix::normalize;
use crate::linalg::Matrix;

/// Pluggable batch projector (`rows -> B rows`): native matvec by
/// default; the runtime swaps in the PJRT `project_db` artifact.
pub trait BatchProjector {
    fn project(&mut self, p: &Matrix, rows: &[Vec<f32>]) -> Vec<Vec<f32>>;
    fn name(&self) -> &'static str;
}

/// Native projector.
pub struct NativeProjector;

impl BatchProjector for NativeProjector {
    fn project(&mut self, p: &Matrix, rows: &[Vec<f32>]) -> Vec<Vec<f32>> {
        rows.iter().map(|r| p.matvec(r)).collect()
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Cosine similarity operates on unit vectors: return a normalized copy
/// of `rows` (then treat as inner product), `None` for the other
/// similarities.
fn cosine_normalized(rows: &[Vec<f32>], sim: Similarity) -> Option<Vec<Vec<f32>>> {
    if sim != Similarity::Cosine {
        return None;
    }
    Some(
        rows.iter()
            .map(|r| {
                let mut v = r.clone();
                normalize(&mut v);
                v
            })
            .collect(),
    )
}

/// Builder for [`LeanVecIndex`].
pub struct IndexBuilder {
    projection: ProjectionKind,
    target_dim: usize,
    primary: Compression,
    secondary: Compression,
    graph_params: Option<GraphParams>,
    /// max rows used to estimate K_X (subsampling is safe — Fig. 15/16)
    train_subsample: usize,
    seed: u64,
    backends: Option<TrainBackends>,
    projector: Option<Box<dyn BatchProjector>>,
    /// pre-trained model overrides the learner (e.g. shared across
    /// ablation arms)
    model: Option<LeanVecModel>,
    /// construction threading (see `config::BuildParams`)
    build: BuildParams,
}

impl Default for IndexBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl IndexBuilder {
    pub fn new() -> IndexBuilder {
        IndexBuilder {
            projection: ProjectionKind::OodEigSearch,
            target_dim: 0,
            primary: Compression::Lvq8,
            secondary: Compression::F16,
            graph_params: None,
            train_subsample: 20_000,
            seed: 0xACE,
            backends: None,
            projector: None,
            model: None,
            build: BuildParams::default(),
        }
    }

    pub fn projection(mut self, kind: ProjectionKind) -> Self {
        self.projection = kind;
        self
    }

    /// `0` means no reduction (d = D).
    pub fn target_dim(mut self, d: usize) -> Self {
        self.target_dim = d;
        self
    }

    pub fn primary(mut self, c: Compression) -> Self {
        self.primary = c;
        self
    }

    pub fn secondary(mut self, c: Compression) -> Self {
        self.secondary = c;
        self
    }

    pub fn graph_params(mut self, p: GraphParams) -> Self {
        self.graph_params = Some(p);
        self
    }

    pub fn train_subsample(mut self, n: usize) -> Self {
        self.train_subsample = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn backends(mut self, b: TrainBackends) -> Self {
        self.backends = Some(b);
        self
    }

    pub fn projector(mut self, p: Box<dyn BatchProjector>) -> Self {
        self.projector = Some(p);
        self
    }

    pub fn model(mut self, m: LeanVecModel) -> Self {
        self.model = Some(m);
        self
    }

    /// Construction worker threads for graph build, quantization and
    /// database projection. `1` (default) = serial reference build,
    /// `0` = all cores. See `config::BuildParams` for the determinism
    /// contract.
    pub fn build_threads(mut self, threads: usize) -> Self {
        self.build.build_threads = threads;
        self
    }

    /// Phase (1) of [`IndexBuilder::build`] alone: train (or pass
    /// through) the projection model over `rows` without building an
    /// index. The sharded builder ([`crate::shard::ShardedIndex`])
    /// trains one model over the *full* corpus and hands a clone to
    /// every per-shard build via [`IndexBuilder::model`], so a single
    /// batched query projection `A q` serves all shards.
    pub fn train_model(
        mut self,
        rows: &[Vec<f32>],
        learn_queries: Option<&[Vec<f32>]>,
        sim: Similarity,
    ) -> LeanVecModel {
        assert!(!rows.is_empty());
        let owned_rows = cosine_normalized(rows, sim);
        let rows: &[Vec<f32>] = owned_rows.as_deref().unwrap_or(rows);
        self.resolve_model(rows, learn_queries)
    }

    /// Train the projection, or take the pre-supplied model. `rows` must
    /// already be cosine-normalized when applicable.
    fn resolve_model(
        &mut self,
        rows: &[Vec<f32>],
        learn_queries: Option<&[Vec<f32>]>,
    ) -> LeanVecModel {
        let dd = rows[0].len();
        let d = if self.target_dim == 0 { dd } else { self.target_dim };
        match self.model.take() {
            Some(m) => {
                assert_eq!(m.input_dim(), dd);
                m
            }
            None if d >= dd => LeanVecModel::identity(dd),
            None => {
                let sub = self.train_subsample.min(rows.len());
                let train_rows = &rows[..sub];
                let mut default_backends = TrainBackends::default();
                let backends = self.backends.as_mut().unwrap_or(&mut default_backends);
                train_projection(
                    self.projection,
                    train_rows,
                    learn_queries,
                    d,
                    backends,
                    self.seed,
                )
            }
        }
    }

    /// Build the index over `rows`; `learn_queries` is required for the
    /// OOD learners. Cosine similarity normalizes a copy of the data.
    pub fn build(
        mut self,
        rows: &[Vec<f32>],
        learn_queries: Option<&[Vec<f32>]>,
        sim: Similarity,
    ) -> LeanVecIndex {
        assert!(!rows.is_empty());
        let dd = rows[0].len();
        let threads = self.build.resolved_threads();
        let mut breakdown = BuildBreakdown::default();

        // cosine -> normalize once, then treat as IP
        let owned_rows = cosine_normalized(rows, sim);
        let rows: &[Vec<f32>] = owned_rows.as_deref().unwrap_or(rows);

        // --- (1) train the projection
        let t = std::time::Instant::now();
        let model = self.resolve_model(rows, learn_queries);
        breakdown.train_seconds = t.elapsed().as_secs_f64();

        // --- (2) project the database (chunked across build threads
        //         unless a custom projector, e.g. PJRT, was installed)
        let t = std::time::Instant::now();
        let projected: Vec<Vec<f32>> = if model.target_dim() == dd && model.kind == ProjectionKind::None {
            rows.to_vec()
        } else {
            match self.projector.as_deref_mut() {
                Some(p) => p.project(&model.b, rows),
                None => model.project_database_threads(rows, threads),
            }
        };
        breakdown.project_seconds = t.elapsed().as_secs_f64();

        // --- (3) quantize primary + secondary stores (per-vector work,
        //         chunked across build threads; bit-identical to serial)
        let t = std::time::Instant::now();
        let primary = make_store_threads(&projected, self.primary, threads);
        let secondary = make_store_threads(rows, self.secondary, threads);
        breakdown.quantize_seconds = t.elapsed().as_secs_f64();

        // --- (4) build the graph over the primary store
        let graph_sim = if sim == Similarity::Cosine {
            Similarity::InnerProduct
        } else {
            sim
        };
        let gp = self
            .graph_params
            .unwrap_or_else(|| GraphParams::for_similarity(graph_sim));
        let graph = VamanaBuilder::new(gp, graph_sim)
            .with_threads(threads)
            .build(primary.as_ref());
        breakdown.graph_seconds = graph.build_seconds;

        LeanVecIndex {
            model,
            primary,
            secondary,
            graph,
            sim: graph_sim,
            primary_compression: self.primary,
            secondary_compression: self.secondary,
            build_breakdown: breakdown,
            backing: None,
        }
    }
}

/// Unified index for the experiment harness (Fig. 7/8 comparisons).
/// Every arm answers through the [`VectorIndex`] trait, so the harness
/// sweeps one API: for the IVF-PQ arm the stored `nprobe` fills in when
/// a query leaves `window` unset, and the HNSW arm reads `window` as
/// `ef`.
pub enum SearchIndex {
    LeanVec(LeanVecIndex),
    Flat(FlatIndex),
    IvfPq(IvfPqIndex, usize), // (index, default nprobe)
    Hnsw(HnswGraph, Box<dyn crate::quant::ScoreStore>),
}

impl SearchIndex {
    pub fn name(&self) -> &'static str {
        match self {
            SearchIndex::LeanVec(_) => "leanvec",
            SearchIndex::Flat(_) => "flat",
            SearchIndex::IvfPq(_, _) => "ivfpq",
            SearchIndex::Hnsw(_, _) => "hnsw",
        }
    }
}

impl VectorIndex for SearchIndex {
    fn search(&self, ctx: &mut SearchCtx, query: &Query) -> SearchResult {
        match self {
            SearchIndex::LeanVec(ix) => ix.search(ctx, query),
            SearchIndex::Flat(ix) => VectorIndex::search(ix, ctx, query),
            SearchIndex::IvfPq(ix, nprobe) => {
                VectorIndex::search(ix, ctx, &query.with_default_window(*nprobe))
            }
            SearchIndex::Hnsw(g, store) => {
                let ef = query
                    .effective(crate::index::leanvec_index::SearchParams::default())
                    .window;
                let pq = store.prepare(query.vector(), g.sim);
                let cands = g.search_filtered(ctx, store.as_ref(), &pq, ef, query.filter_fn());
                let take = query.top_k().min(cands.len());
                let ids: Vec<u32> = cands[..take].iter().map(|c| c.id).collect();
                let scores: Vec<f32> = cands[..take].iter().map(|c| c.score).collect();
                SearchResult {
                    ids,
                    scores,
                    stats: crate::index::query::QueryStats {
                        primary_scored: ctx.stats.scored,
                        reranked: 0,
                        bytes_touched: ctx.stats.scored * store.bytes_per_vector(),
                        hops: ctx.stats.hops,
                        filtered: ctx.stats.filtered,
                        deleted_skipped: 0,
                    },
                    ..SearchResult::default()
                }
            }
        }
    }

    fn len(&self) -> usize {
        match self {
            SearchIndex::LeanVec(ix) => ix.len(),
            SearchIndex::Flat(ix) => ix.len(),
            SearchIndex::IvfPq(ix, _) => ix.len(),
            SearchIndex::Hnsw(_, store) => store.len(),
        }
    }

    fn dim(&self) -> usize {
        match self {
            SearchIndex::LeanVec(ix) => VectorIndex::dim(ix),
            SearchIndex::Flat(ix) => VectorIndex::dim(ix),
            SearchIndex::IvfPq(ix, _) => VectorIndex::dim(ix),
            SearchIndex::Hnsw(_, store) => store.dim(),
        }
    }

    fn sim(&self) -> Similarity {
        match self {
            SearchIndex::LeanVec(ix) => VectorIndex::sim(ix),
            SearchIndex::Flat(ix) => VectorIndex::sim(ix),
            SearchIndex::IvfPq(ix, _) => VectorIndex::sim(ix),
            SearchIndex::Hnsw(g, _) => g.sim,
        }
    }
}

/// Convenience constructor for the HNSW baseline arm.
pub fn build_hnsw_baseline(
    rows: &[Vec<f32>],
    sim: Similarity,
    compression: Compression,
    seed: u64,
) -> SearchIndex {
    let store = make_store(rows, compression);
    let g = HnswGraph::build(store.as_ref(), &HnswParams::default(), sim, seed);
    SearchIndex::Hnsw(g, store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn builds_all_projection_kinds() {
        let x = rows(250, 16, 1);
        let q = rows(50, 16, 2);
        for kind in [
            ProjectionKind::None,
            ProjectionKind::Id,
            ProjectionKind::OodEigSearch,
            ProjectionKind::Random,
        ] {
            let ix = IndexBuilder::new()
                .projection(kind)
                .target_dim(if kind == ProjectionKind::None { 0 } else { 8 })
                .build(&x, Some(&q), Similarity::InnerProduct);
            assert_eq!(ix.len(), 250, "{kind:?}");
            let ids = ix.search_one(&Query::new(&q[0]).k(5).window(20)).ids;
            assert_eq!(ids.len(), 5);
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn build_breakdown_accounted() {
        let x = rows(200, 12, 3);
        let ix = IndexBuilder::new()
            .projection(ProjectionKind::Id)
            .target_dim(6)
            .build(&x, None, Similarity::L2);
        let b = ix.build_breakdown;
        assert!(b.total() > 0.0);
        assert!(b.graph_seconds > 0.0);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn threaded_build_quantization_identical_and_recall_close() {
        let x = rows(800, 16, 9);
        let build = |threads: usize| {
            IndexBuilder::new()
                .projection(ProjectionKind::Id)
                .target_dim(8)
                .seed(55)
                .build_threads(threads)
                .build(&x, None, Similarity::L2)
        };
        let serial = build(1);
        let parallel = build(4);
        // quantization + projection are bit-identical: decode must agree
        for id in [0u32, 17, 399, 799] {
            assert_eq!(serial.primary.decode(id), parallel.primary.decode(id));
            assert_eq!(serial.secondary.decode(id), parallel.secondary.decode(id));
        }
        // graphs differ (round-based schedule) but search quality holds:
        // count self-recall over probe queries
        let hits = |ix: &LeanVecIndex| {
            (0..40u32)
                .filter(|&i| {
                    let q = ix.secondary.decode(i);
                    ix.search_one(&Query::new(&q).k(1).window(20)).ids.first() == Some(&i)
                })
                .count()
        };
        let (hs, hp) = (hits(&serial), hits(&parallel));
        assert!(hp + 2 >= hs, "parallel self-recall {hp}/40 vs serial {hs}/40");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn build_threads_one_reproduces_default_build() {
        let x = rows(400, 12, 10);
        let a = IndexBuilder::new()
            .projection(ProjectionKind::Id)
            .target_dim(6)
            .seed(77)
            .build(&x, None, Similarity::InnerProduct);
        let b = IndexBuilder::new()
            .projection(ProjectionKind::Id)
            .target_dim(6)
            .seed(77)
            .build_threads(1)
            .build(&x, None, Similarity::InnerProduct);
        for i in 0..400u32 {
            assert_eq!(a.graph.adj.neighbors(i), b.graph.adj.neighbors(i));
            assert_eq!(a.primary.decode(i), b.primary.decode(i));
        }
        assert_eq!(a.graph.medoid, b.graph.medoid);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn cosine_normalizes() {
        let x = rows(150, 8, 4);
        let ix = IndexBuilder::new()
            .projection(ProjectionKind::None)
            .target_dim(0)
            .build(&x, None, Similarity::Cosine);
        // secondary store holds normalized vectors
        let v = ix.secondary.decode(0);
        let n: f32 = v.iter().map(|x| x * x).sum();
        assert!((n - 1.0).abs() < 0.01, "{n}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn unified_enum_search_shapes() {
        let x = rows(300, 16, 5);
        let lv = IndexBuilder::new()
            .projection(ProjectionKind::Id)
            .target_dim(8)
            .build(&x, None, Similarity::L2);
        let flat = FlatIndex::new(&x, Similarity::L2);
        let ivf = IvfPqIndex::build(
            &x,
            crate::index::ivfpq::IvfPqParams {
                nlist: 8,
                m: 4,
                ksub: 32,
                kmeans_iters: 4,
            },
            Similarity::L2,
            6,
        );
        let hnsw = build_hnsw_baseline(&x, Similarity::L2, Compression::F16, 7);
        for ix in [
            SearchIndex::LeanVec(lv),
            SearchIndex::Flat(flat),
            SearchIndex::IvfPq(ivf, 4),
            hnsw,
        ] {
            let r = ix.search_one(&Query::new(&x[0]).k(5).window(20));
            assert_eq!(r.ids.len(), 5, "{}", ix.name());
            assert_eq!(r.ids.len(), r.scores.len(), "{}", ix.name());
            assert_eq!(ix.len(), 300, "{}", ix.name());
            assert_eq!(VectorIndex::dim(&ix), 16, "{}", ix.name());
        }
    }
}
