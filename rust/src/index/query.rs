//! The unified query API: one typed [`Query`] builder, one
//! [`SearchResult`] shape, and one [`VectorIndex`] trait that every
//! index in the crate speaks — the LeanVec search-and-rerank index, the
//! flat oracle, the IVF-PQ baseline, and the [`SearchIndex`] harness
//! wrapper all answer the same `search(ctx, &Query)` call.
//!
//! The builder carries the *split-buffer* knobs SVS ships for LeanVec:
//! [`Query::window`] is the greedy-search buffer width L (drives
//! traversal cost), [`Query::rerank_window`] is how many candidates are
//! retained for secondary re-ranking — and it **may exceed** `window`:
//! the traversal buffer then keeps extra unexpanded candidates purely
//! for the re-rank stage, decoupling search effort from re-rank depth.
//!
//! Queries can also carry a filter predicate ([`Query::filter`]); it is
//! pushed into graph traversal and the flat/IVF scans, so filtered-out
//! ids are never re-ranked and never returned, and the traversal still
//! navigates *through* them (connectivity is preserved).
//!
//! [`SearchIndex`]: crate::index::builder::SearchIndex

use crate::config::Similarity;
use crate::graph::beam::{CtxPool, SearchCtx};
use crate::index::leanvec_index::SearchParams;
use crate::util::threadpool::{parallel_map, resolve_threads};

/// A filter predicate over database ids: `true` keeps the id. Must be
/// `Sync` so batch search can evaluate it from worker threads.
pub type FilterFn<'a> = &'a (dyn Fn(u32) -> bool + Sync);

/// One typed search request: the query vector plus every per-request
/// knob. Built fluently:
///
/// ```ignore
/// let q = Query::new(&v).k(10).window(80).rerank_window(120);
/// let filtered = Query::new(&v).k(10).filter(&|id| id % 2 == 0);
/// ```
///
/// Unset knobs fall back to [`SearchParams::default()`] at search time
/// (for IVF-PQ, `window` is interpreted as `nprobe`). Layers that own
/// richer defaults apply them by *setting* the knobs before searching:
/// the serving engine resolves each request's `QuerySpec` against
/// `EngineConfig.search`, and the CLI resolves its flags against the
/// snapshot-recommended `SnapshotMeta::search_defaults` — a library
/// user serving from a snapshot should do the same
/// (`Query::new(&q).window(meta.search_defaults.window)...`).
/// `window` and `rerank_window` are validated at construction: zero is
/// rejected immediately rather than producing an empty traversal deep
/// in the stack.
#[derive(Clone, Copy)]
pub struct Query<'a> {
    vector: &'a [f32],
    k: usize,
    window: Option<usize>,
    rerank_window: Option<usize>,
    rerank: bool,
    filter: Option<FilterFn<'a>>,
}

impl<'a> Query<'a> {
    /// A query for `vector` with `k = 10` and index-default knobs.
    pub fn new(vector: &'a [f32]) -> Query<'a> {
        Query {
            vector,
            k: 10,
            window: None,
            rerank_window: None,
            rerank: true,
            filter: None,
        }
    }

    /// Number of results to return.
    pub fn k(mut self, k: usize) -> Query<'a> {
        self.k = k;
        self
    }

    /// Greedy-search buffer width L (IVF-PQ reads it as `nprobe`).
    /// Panics on zero — a zero window is always a caller bug.
    pub fn window(mut self, window: usize) -> Query<'a> {
        assert!(window > 0, "Query::window must be >= 1");
        self.window = Some(window);
        self
    }

    /// How many candidates to re-rank with the secondary store. May
    /// exceed [`Query::window`] (split-buffer semantics: the traversal
    /// buffer retains up to this many candidates, but only the top
    /// `window` drive expansion). Panics on zero.
    pub fn rerank_window(mut self, rerank_window: usize) -> Query<'a> {
        assert!(rerank_window > 0, "Query::rerank_window must be >= 1");
        self.rerank_window = Some(rerank_window);
        self
    }

    /// Skip secondary re-ranking (the Fig. 11 ablation arm): results
    /// come straight from the primary traversal, scores are primary
    /// scores.
    pub fn no_rerank(mut self) -> Query<'a> {
        self.rerank = false;
        self
    }

    /// Attach a filter predicate; ids failing it are never re-ranked
    /// and never returned ([`QueryStats::filtered`] counts them).
    pub fn filter(mut self, pred: FilterFn<'a>) -> Query<'a> {
        self.filter = Some(pred);
        self
    }

    /// The query vector.
    pub fn vector(&self) -> &'a [f32] {
        self.vector
    }

    /// Requested result count.
    pub fn top_k(&self) -> usize {
        self.k
    }

    /// The filter predicate, if any.
    pub fn filter_fn(&self) -> Option<FilterFn<'a>> {
        self.filter
    }

    /// Whether secondary re-ranking is enabled (default: yes).
    pub fn wants_rerank(&self) -> bool {
        self.rerank
    }

    /// The raw `window` override, if set.
    pub fn window_override(&self) -> Option<usize> {
        self.window
    }

    /// The raw `rerank_window` override, if set.
    pub fn rerank_window_override(&self) -> Option<usize> {
        self.rerank_window
    }

    /// This query with its filter predicate replaced (or cleared). Used
    /// by the sharded scatter-gather layer to substitute a predicate in
    /// the shard's local id namespace for the caller's external-id one;
    /// every other knob travels unchanged.
    pub(crate) fn replace_filter(mut self, pred: Option<FilterFn<'a>>) -> Query<'a> {
        self.filter = pred;
        self
    }

    /// This query with `window` defaulted to `w` when unset (the
    /// [`SearchIndex`] IVF-PQ arm injects its per-index `nprobe` here).
    ///
    /// [`SearchIndex`]: crate::index::builder::SearchIndex
    pub fn with_default_window(mut self, w: usize) -> Query<'a> {
        if self.window.is_none() && w > 0 {
            self.window = Some(w);
        }
        self
    }

    /// Resolve the effective `(window, rerank_window)` against an
    /// index's serving defaults — see [`resolve_params`] for the rule.
    pub fn effective(&self, defaults: SearchParams) -> SearchParams {
        resolve_params(self.window, self.rerank_window, defaults)
    }
}

/// THE resolution rule for optional search-knob overrides, shared by
/// [`Query::effective`], the serving engine's per-request `QuerySpec`
/// resolution, and the CLI's `--window`/`--rerank-window` flags so the
/// three can never drift apart: an explicit `window` without an
/// explicit `rerank_window` couples the two (the common case); fully
/// unset takes both defaults verbatim.
pub fn resolve_params(
    window: Option<usize>,
    rerank_window: Option<usize>,
    defaults: SearchParams,
) -> SearchParams {
    let effective_window = window.unwrap_or(defaults.window);
    let effective_rerank = rerank_window.unwrap_or(match window {
        Some(w) => w,
        None => defaults.rerank_window,
    });
    SearchParams {
        window: effective_window,
        rerank_window: effective_rerank,
    }
}

impl std::fmt::Debug for Query<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Query")
            .field("dim", &self.vector.len())
            .field("k", &self.k)
            .field("window", &self.window)
            .field("rerank_window", &self.rerank_window)
            .field("rerank", &self.rerank)
            .field("filtered", &self.filter.is_some())
            .finish()
    }
}

/// Per-query traffic/latency accounting (drives Fig. 1's bandwidth
/// model). Returned inside every [`SearchResult`] and echoed through
/// the serving [`Response`] for observability.
///
/// [`Response`]: crate::coordinator::protocol::Response
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// vectors scored during primary traversal / scan
    pub primary_scored: usize,
    /// candidates re-scored with the secondary store
    pub reranked: usize,
    /// bytes of vector data read (primary + re-rank traffic)
    pub bytes_touched: usize,
    /// graph hops (nodes expanded); coarse cells probed for IVF-PQ
    pub hops: usize,
    /// ids encountered but excluded by the query's filter predicate
    pub filtered: usize,
    /// tombstoned ids the traversal routed *through* but never returned
    /// (always 0 on a frozen index; populated by the live mutable index,
    /// [`crate::mutate::LiveIndex`])
    pub deleted_skipped: usize,
}

impl QueryStats {
    /// Accumulate another query's counters into this one. The sharded
    /// scatter-gather merge sums per-shard stats with this, so a fanned-
    /// out query reports the *total* traffic it caused across shards;
    /// the metrics layer uses it to aggregate run totals. Saturating:
    /// a long soak's running totals pin at `usize::MAX` instead of
    /// silently wrapping back toward zero.
    pub fn merge(&mut self, other: &QueryStats) {
        self.primary_scored = self.primary_scored.saturating_add(other.primary_scored);
        self.reranked = self.reranked.saturating_add(other.reranked);
        self.bytes_touched = self.bytes_touched.saturating_add(other.bytes_touched);
        self.hops = self.hops.saturating_add(other.hops);
        self.filtered = self.filtered.saturating_add(other.filtered);
        self.deleted_skipped = self.deleted_skipped.saturating_add(other.deleted_skipped);
    }
}

/// What every search returns: ids and scores best-first, plus the
/// traffic accounting. Replaces the positional `(Vec<u32>, Vec<f32>,
/// QueryStats)` tuples the per-index entry points used to return.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SearchResult {
    /// result ids, best first
    pub ids: Vec<u32>,
    /// matching scores ("bigger is better" for every similarity)
    pub scores: Vec<f32>,
    /// per-query accounting
    pub stats: QueryStats,
    /// true when the answer is missing contributions it should have
    /// had: one or more scatter shards failed (panic, poisoned state)
    /// and the merge proceeded over the survivors. Degraded results are
    /// valid, best-first answers over the shards that responded.
    pub degraded: bool,
    /// how many shards failed to contribute (0 on a clean query)
    pub shards_failed: usize,
}

/// The uniform search interface every index implements. `Sync` is a
/// supertrait so the default batch fan-out can share `&self` across
/// worker threads.
pub trait VectorIndex: Sync {
    /// Answer one query with a reusable [`SearchCtx`] (the hot path:
    /// steady-state searches allocate nothing beyond the result).
    fn search(&self, ctx: &mut SearchCtx, query: &Query) -> SearchResult;

    /// Number of indexed vectors.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Input (full, unprojected) dimensionality queries must have.
    fn dim(&self) -> usize;

    /// Similarity the scores express.
    fn sim(&self) -> Similarity;

    /// Convenience: answer one query with a fresh context (allocates).
    fn search_one(&self, query: &Query) -> SearchResult {
        // size 0: the graph paths grow the visited array lazily via
        // `ctx.ensure`, and the scan paths never touch the context
        let mut ctx = SearchCtx::new(0);
        self.search(&mut ctx, query)
    }

    /// Parallel closed-loop batch search across `threads` workers
    /// (0 = all cores), each drawing a pooled [`SearchCtx`]. Results
    /// are in query order and identical to sequential [`VectorIndex::search`]
    /// calls for every thread count.
    fn search_batch(&self, queries: &[Query<'_>], threads: usize) -> Vec<SearchResult>
    where
        Self: Sized,
    {
        let threads = resolve_threads(threads);
        // size 0: graph searches grow their visited arrays lazily
        // (`ctx.ensure`), scan indexes never touch the contexts
        let pool = CtxPool::new(threads, 0);
        parallel_map(queries.len(), threads, |i| {
            let mut ctx = pool.acquire();
            self.search(&mut ctx, &queries[i])
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accumulates_knobs() {
        let v = vec![0.0f32; 4];
        let pred = |id: u32| id < 2;
        let q = Query::new(&v)
            .k(5)
            .window(30)
            .rerank_window(90)
            .filter(&pred);
        assert_eq!(q.top_k(), 5);
        assert!(q.wants_rerank());
        let eff = q.effective(SearchParams::default());
        assert_eq!(eff.window, 30);
        assert_eq!(eff.rerank_window, 90, "split buffer: rerank > window");
        assert!(q.filter_fn().unwrap()(1));
        assert!(!q.filter_fn().unwrap()(3));
    }

    #[test]
    fn effective_defaults_resolve() {
        let v = vec![0.0f32; 4];
        let d = SearchParams {
            window: 64,
            rerank_window: 128,
        };
        // fully unset -> both defaults
        let eff = Query::new(&v).effective(d);
        assert_eq!((eff.window, eff.rerank_window), (64, 128));
        // explicit window couples rerank to it
        let eff = Query::new(&v).window(20).effective(d);
        assert_eq!((eff.window, eff.rerank_window), (20, 20));
        // explicit rerank only: window stays default
        let eff = Query::new(&v).rerank_window(200).effective(d);
        assert_eq!((eff.window, eff.rerank_window), (64, 200));
        // with_default_window does not override an explicit window
        let q = Query::new(&v).window(9).with_default_window(99);
        assert_eq!(q.window_override(), Some(9));
        assert_eq!(Query::new(&v).with_default_window(99).window_override(), Some(99));
    }

    #[test]
    fn stats_merge_sums_every_field() {
        let mut a = QueryStats {
            primary_scored: 10,
            reranked: 4,
            bytes_touched: 1_000,
            hops: 7,
            filtered: 2,
            deleted_skipped: 1,
        };
        let b = QueryStats {
            primary_scored: 3,
            reranked: 5,
            bytes_touched: 250,
            hops: 11,
            filtered: 6,
            deleted_skipped: 9,
        };
        a.merge(&b);
        assert_eq!(a.primary_scored, 13);
        assert_eq!(a.reranked, 9);
        assert_eq!(a.bytes_touched, 1_250);
        assert_eq!(a.hops, 18);
        assert_eq!(a.filtered, 8);
        assert_eq!(a.deleted_skipped, 10);
    }

    #[test]
    fn stats_merge_identity_and_accumulation() {
        // merging the default (all-zero) stats is a no-op
        let mut a = QueryStats {
            primary_scored: 1,
            reranked: 2,
            bytes_touched: 3,
            hops: 4,
            filtered: 5,
            deleted_skipped: 6,
        };
        let before = a;
        a.merge(&QueryStats::default());
        assert_eq!(a, before);
        // folding n copies multiplies every counter by n
        let unit = a;
        let mut total = QueryStats::default();
        for _ in 0..4 {
            total.merge(&unit);
        }
        assert_eq!(total.primary_scored, 4 * unit.primary_scored);
        assert_eq!(total.reranked, 4 * unit.reranked);
        assert_eq!(total.bytes_touched, 4 * unit.bytes_touched);
        assert_eq!(total.hops, 4 * unit.hops);
        assert_eq!(total.filtered, 4 * unit.filtered);
        assert_eq!(total.deleted_skipped, 4 * unit.deleted_skipped);
    }

    #[test]
    fn stats_merge_saturates_instead_of_wrapping() {
        // regression: long-soak totals used to wrap via `+=`
        let mut a = QueryStats {
            primary_scored: usize::MAX - 1,
            reranked: usize::MAX,
            bytes_touched: usize::MAX - 100,
            hops: 5,
            filtered: usize::MAX,
            deleted_skipped: usize::MAX - 3,
        };
        let b = QueryStats {
            primary_scored: 10,
            reranked: 1,
            bytes_touched: 200,
            hops: 1,
            filtered: usize::MAX,
            deleted_skipped: 7,
        };
        a.merge(&b);
        assert_eq!(a.primary_scored, usize::MAX);
        assert_eq!(a.reranked, usize::MAX);
        assert_eq!(a.bytes_touched, usize::MAX);
        assert_eq!(a.hops, 6, "unsaturated fields still add exactly");
        assert_eq!(a.filtered, usize::MAX);
        assert_eq!(a.deleted_skipped, usize::MAX);
    }

    #[test]
    #[should_panic(expected = "window must be >= 1")]
    fn zero_window_rejected_at_construction() {
        let v = vec![0.0f32; 2];
        let _ = Query::new(&v).window(0);
    }

    #[test]
    #[should_panic(expected = "rerank_window must be >= 1")]
    fn zero_rerank_window_rejected_at_construction() {
        let v = vec![0.0f32; 2];
        let _ = Query::new(&v).rerank_window(0);
    }
}
