//! Versioned binary snapshots of a whole [`LeanVecIndex`].
//!
//! A snapshot round-trips everything the index needs to serve queries —
//! the Vamana adjacency (CSR-packed), both compressed stores with all
//! their derived per-vector constants, the LeanVec projection pair, and
//! the build/search/provenance metadata — so a process can
//! [`LeanVecIndex::load`] and answer queries **bit-identically** to the
//! process that built the index, without ever touching the training
//! path. This is the build/serve split: `repro build` writes a
//! snapshot once, any number of `repro search`/`repro serve` processes
//! read it.
//!
//! # File layout (see `docs/SNAPSHOT_FORMAT.md` for the byte-level spec)
//!
//! ```text
//! magic "LEANVEC\0" | version u32 | section count u32
//! section table: per section { tag[8] | offset u64 | len u64 | crc32 }
//! section payloads, concatenated in table order
//! ```
//!
//! The section table is the forward-compatibility seam: readers locate
//! sections by tag and ignore tags they do not understand, so new
//! sections can be appended without a version bump; removing or
//! reshaping an existing section requires bumping [`FORMAT_VERSION`],
//! which old readers reject loudly ([`SnapshotError::UnsupportedVersion`]).
//! Every payload is CRC-32-checked before it is parsed, so corruption
//! surfaces as [`SnapshotError::ChecksumMismatch`] rather than as a
//! garbled index.
//!
//! Snapshots are byte-deterministic: saving the same index twice
//! produces identical files (nothing time- or environment-dependent is
//! written outside the metadata the caller passes in).

use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::config::{BuildParams, Compression, ProjectionKind, Similarity};
use crate::data::io::{bin, crc32};
use crate::graph::vamana::VamanaGraph;
use crate::index::leanvec_index::{BuildBreakdown, LeanVecIndex, SearchParams};
use crate::leanvec::model::LeanVecModel;
use crate::quant::read_store_src;
use crate::util::json::Json;
use crate::util::mmap::{Advice, Mmap, SectionSrc};

/// First 8 bytes of every snapshot.
pub const MAGIC: [u8; 8] = *b"LEANVEC\0";

/// Current snapshot format version for *frozen* indexes. Bump only for
/// incompatible layout changes; appending new sections does NOT require
/// a bump.
pub const FORMAT_VERSION: u32 = 1;

/// Format version written for *live* snapshots (`mutate::persist_live`):
/// ones carrying tombstones, a non-identity id map, or a pending insert
/// log. The bump is deliberate — a live snapshot *reshapes the meaning*
/// of the store/graph sections (some rows are dead, result ids go
/// through the id map), so a version-1 reader that would silently serve
/// deleted rows must reject the file loudly instead
/// ([`SnapshotError::UnsupportedVersion`]), exactly per the PR 2
/// versioning contract.
pub const FORMAT_VERSION_LIVE: u32 = 2;

/// JSON metadata: params, provenance, build breakdown.
pub const SECTION_META: [u8; 8] = *b"META\0\0\0\0";
/// The LeanVec projection pair `(A, B)`.
pub const SECTION_MODEL: [u8; 8] = *b"MODEL\0\0\0";
/// The primary (traversal) store.
pub const SECTION_PRIMARY: [u8; 8] = *b"PRIMARY\0";
/// The secondary (re-ranking) store.
pub const SECTION_SECONDARY: [u8; 8] = *b"SECSTORE";
/// The Vamana graph, CSR-packed.
pub const SECTION_GRAPH: [u8; 8] = *b"GRAPH\0\0\0";
/// Live index only: tombstone bitmap (see `docs/SNAPSHOT_FORMAT.md`).
pub const SECTION_TOMBS: [u8; 8] = *b"TOMBS\0\0\0";
/// Live index only: internal-slot -> external-id map.
pub const SECTION_IDMAP: [u8; 8] = *b"IDMAP\0\0\0";
/// Live index only: mutation journal + pending insert log.
pub const SECTION_MUTLOG: [u8; 8] = *b"MUTLOG\0\0";

/// Everything that can go wrong reading or writing a snapshot. Old
/// readers meeting new files, bit rot, and partial writes all map to
/// distinct variants so operators can tell them apart.
#[derive(Debug)]
pub enum SnapshotError {
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// The file does not start with [`MAGIC`] — not a snapshot at all.
    BadMagic,
    /// The file's format version is one this reader does not speak.
    UnsupportedVersion { found: u32, supported: u32 },
    /// The file ends before the named structure is complete.
    Truncated(String),
    /// A section's payload does not match its recorded CRC-32.
    ChecksumMismatch { section: String },
    /// A section this reader requires is absent from the table.
    MissingSection(String),
    /// A payload passed its checksum but is internally inconsistent.
    Corrupt(String),
    /// An error loading one shard of a sharded collection, tagged with
    /// the shard file's name so the operator knows *which* file to
    /// restore.
    Shard {
        file: String,
        source: Box<SnapshotError>,
    },
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "snapshot i/o error: {e}"),
            SnapshotError::BadMagic => write!(f, "not a LeanVec snapshot (bad magic)"),
            SnapshotError::UnsupportedVersion { found, supported } => write!(
                f,
                "snapshot format version {found} not supported (this reader speaks {supported})"
            ),
            SnapshotError::Truncated(what) => write!(f, "snapshot truncated: {what}"),
            SnapshotError::ChecksumMismatch { section } => {
                write!(f, "snapshot section '{section}' failed its checksum")
            }
            SnapshotError::MissingSection(tag) => {
                write!(f, "snapshot is missing required section '{tag}'")
            }
            SnapshotError::Corrupt(what) => write!(f, "snapshot corrupt: {what}"),
            SnapshotError::Shard { file, source } => {
                write!(f, "shard '{file}': {source}")
            }
        }
    }
}

impl std::error::Error for SnapshotError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SnapshotError::Io(e) => Some(e),
            SnapshotError::Shard { source, .. } => Some(source.as_ref()),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> SnapshotError {
        match e.kind() {
            std::io::ErrorKind::UnexpectedEof => SnapshotError::Truncated(e.to_string()),
            std::io::ErrorKind::InvalidData => SnapshotError::Corrupt(e.to_string()),
            _ => SnapshotError::Io(e),
        }
    }
}

/// One tagged, checksummed payload. The raw-section API is public so
/// tools (and the forward-compatibility tests) can read, extend and
/// rewrite snapshots without understanding every payload.
///
/// Payloads are owned (`Vec<u8>`) rather than borrowed from the file
/// buffer so sections can be edited and re-written; the zero-copy
/// serve path ([`LeanVecIndex::load_mmap`]) bypasses this type and
/// borrows section windows straight from the mapping instead.
pub struct RawSection {
    /// 8-byte tag, NUL-padded ASCII (e.g. [`SECTION_META`]).
    pub tag: [u8; 8],
    /// The section payload, exactly as stored.
    pub bytes: Vec<u8>,
    /// Alignment anchor: byte offset *within the payload* of the
    /// section's dominant typed array. The writer pads the section's
    /// file offset so `file_offset + anchor` is 64-byte aligned,
    /// letting `load_mmap` reinterpret that array in place. `0` (align
    /// the payload start) is always safe — sections read back from a
    /// file or written by pre-alignment callers use it.
    pub anchor: usize,
}

impl RawSection {
    /// A section with the default anchor (payload start aligned).
    pub fn new(tag: [u8; 8], bytes: Vec<u8>) -> RawSection {
        RawSection {
            tag,
            bytes,
            anchor: 0,
        }
    }
}

/// Printable form of a section tag (trailing NULs stripped).
pub fn tag_str(tag: &[u8; 8]) -> String {
    let end = tag.iter().position(|&b| b == 0).unwrap_or(8);
    String::from_utf8_lossy(&tag[..end]).into_owned()
}

/// Alignment the writer guarantees for every section's anchor byte
/// (see [`RawSection::anchor`]): one cache line, and a multiple of
/// every scalar alignment the stores use, so `load_mmap` can
/// reinterpret the anchored arrays in place.
pub const SECTION_ALIGN: u64 = 64;

/// Serialize `sections` to `path` with the snapshot header and section
/// table. Returns the number of bytes written.
///
/// Each section's payload is placed so that `offset + anchor` is
/// [`SECTION_ALIGN`]-aligned, with zero bytes padding the gap before
/// it. Readers never see the padding — the section table records exact
/// offsets, and the parser has always tolerated gaps between payloads,
/// so pre-alignment readers parse aligned files unchanged (no format
/// version bump).
///
/// The write is atomic-by-rename: everything is streamed to
/// `<path>.tmp` and renamed over `path` only once complete, so a crash
/// mid-save never destroys an existing good snapshot. Payloads are
/// streamed section by section (never concatenated in memory), so peak
/// memory is the section buffers the caller already holds.
pub fn write_sections(path: &Path, sections: &[RawSection]) -> Result<u64, SnapshotError> {
    write_sections_versioned(path, sections, FORMAT_VERSION)
}

/// [`write_sections`] with an explicit format version — the live
/// snapshot writer stamps [`FORMAT_VERSION_LIVE`] so frozen-only
/// readers reject the file instead of silently serving dead rows.
pub fn write_sections_versioned(
    path: &Path,
    sections: &[RawSection],
    version: u32,
) -> Result<u64, SnapshotError> {
    use std::io::Write;
    const ENTRY: usize = 8 + 8 + 8 + 4; // tag, offset, len, crc
    let header_len = 16 + sections.len() * ENTRY;
    let mut header = Vec::with_capacity(header_len);
    header.extend_from_slice(&MAGIC);
    bin::put_u32(&mut header, version);
    bin::put_u32(&mut header, sections.len() as u32);
    let mut offset = header_len as u64;
    // zero padding before each payload so its anchor lands on a
    // SECTION_ALIGN boundary; deterministic (pure function of the
    // sections), so byte-determinism of snapshots is preserved
    let mut pads = Vec::with_capacity(sections.len());
    for s in sections {
        let anchored = offset + s.anchor as u64;
        let pad = (SECTION_ALIGN - anchored % SECTION_ALIGN) % SECTION_ALIGN;
        pads.push(pad as usize);
        offset += pad;
        header.extend_from_slice(&s.tag);
        bin::put_u64(&mut header, offset);
        bin::put_u64(&mut header, s.bytes.len() as u64);
        bin::put_u32(&mut header, crc32(&s.bytes));
        offset += s.bytes.len() as u64;
    }

    let tmp = {
        let mut os = path.as_os_str().to_owned();
        os.push(".tmp");
        std::path::PathBuf::from(os)
    };
    let zeros = [0u8; SECTION_ALIGN as usize];
    let write_all = || -> std::io::Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(&header)?;
        for (s, &pad) in sections.iter().zip(&pads) {
            w.write_all(&zeros[..pad])?;
            w.write_all(&s.bytes)?;
        }
        w.flush()?;
        // fsync before the rename: without it, a power loss after the
        // rename can leave a zero-length file where the old good
        // snapshot used to be (delayed allocation)
        w.get_ref().sync_all()?;
        Ok(())
    };
    if let Err(e) = write_all() {
        std::fs::remove_file(&tmp).ok();
        return Err(SnapshotError::Io(e));
    }
    std::fs::rename(&tmp, path).map_err(SnapshotError::Io)?;
    Ok(offset)
}

/// Read and verify every section of the snapshot at `path`: magic,
/// version, section table, and each payload's CRC-32. Unknown tags are
/// returned as-is (the forward-compatibility contract); interpreting
/// payloads is the caller's job.
pub fn read_sections(path: &Path) -> Result<Vec<RawSection>, SnapshotError> {
    let buf = std::fs::read(path).map_err(SnapshotError::Io)?;
    parse_sections(&buf)
}

/// [`read_sections`] that also accepts live snapshots: returns the
/// file's format version alongside the sections. Used by
/// `mutate::LiveIndex::load`, which understands both layouts.
pub fn read_sections_any(path: &Path) -> Result<(u32, Vec<RawSection>), SnapshotError> {
    let buf = std::fs::read(path).map_err(SnapshotError::Io)?;
    parse_sections_any(&buf, FORMAT_VERSION_LIVE)
}

/// [`read_sections`] over an in-memory buffer. Accepts only
/// [`FORMAT_VERSION`] — live snapshots are rejected with
/// [`SnapshotError::UnsupportedVersion`] (this is the "old reader"
/// path that must never silently serve a mutated index).
pub fn parse_sections(buf: &[u8]) -> Result<Vec<RawSection>, SnapshotError> {
    let (_v, sections) = parse_sections_any(buf, FORMAT_VERSION)?;
    Ok(sections)
}

/// Location of one verified section within a snapshot buffer — the
/// borrowed core of [`parse_sections_any`], shared with the zero-copy
/// mmap load path (which must not materialize payload copies).
struct SectionLoc {
    tag: [u8; 8],
    offset: usize,
    len: usize,
}

/// Parse header + section table, bounds-check every entry, and verify
/// every payload's CRC-32 **in place** (no copies). Every byte of every
/// payload is checksummed before this returns, which is what lets the
/// mmap path hand out borrowed views afterwards: no mapped section is
/// trusted before its checksum passes.
fn parse_locs(buf: &[u8], max_version: u32) -> Result<(u32, Vec<SectionLoc>), SnapshotError> {
    if buf.len() >= 8 && buf[..8] != MAGIC {
        return Err(SnapshotError::BadMagic);
    }
    if buf.len() < 16 {
        return Err(SnapshotError::Truncated("header".into()));
    }
    let version = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]);
    if version == 0 || version > max_version {
        return Err(SnapshotError::UnsupportedVersion {
            found: version,
            supported: max_version,
        });
    }
    let count = u32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]) as usize;
    const ENTRY: usize = 28;
    let table_end = match count.checked_mul(ENTRY).and_then(|t| t.checked_add(16)) {
        Some(e) if e <= buf.len() => e,
        _ => return Err(SnapshotError::Truncated("section table".into())),
    };
    let mut locs = Vec::with_capacity(count);
    for i in 0..count {
        let e = 16 + i * ENTRY;
        let mut tag = [0u8; 8];
        tag.copy_from_slice(&buf[e..e + 8]);
        // fixed-width copies (the table bound above covers e + ENTRY)
        let mut w8 = [0u8; 8];
        w8.copy_from_slice(&buf[e + 8..e + 16]);
        let offset = u64::from_le_bytes(w8);
        w8.copy_from_slice(&buf[e + 16..e + 24]);
        let len = u64::from_le_bytes(w8);
        let mut w4 = [0u8; 4];
        w4.copy_from_slice(&buf[e + 24..e + 28]);
        let crc = u32::from_le_bytes(w4);
        let end = match offset.checked_add(len) {
            Some(end) if end <= buf.len() as u64 && offset >= table_end as u64 => end,
            _ => {
                return Err(SnapshotError::Truncated(format!(
                    "payload of section '{}'",
                    tag_str(&tag)
                )))
            }
        };
        let bytes = &buf[offset as usize..end as usize];
        if crc32(bytes) != crc {
            return Err(SnapshotError::ChecksumMismatch {
                section: tag_str(&tag),
            });
        }
        locs.push(SectionLoc {
            tag,
            offset: offset as usize,
            len: len as usize,
        });
    }
    Ok((version, locs))
}

/// Parse header + section table + checksummed payloads, accepting any
/// format version up to `max_version`.
fn parse_sections_any(
    buf: &[u8],
    max_version: u32,
) -> Result<(u32, Vec<RawSection>), SnapshotError> {
    let (version, locs) = parse_locs(buf, max_version)?;
    let sections = locs
        .into_iter()
        .map(|l| RawSection::new(l.tag, buf[l.offset..l.offset + l.len].to_vec()))
        .collect();
    Ok((version, sections))
}

/// Snapshot metadata the index itself does not carry: where the data
/// came from and the knobs it was built/should be served with. Stored
/// in the META section as JSON (extensible without a format bump).
#[derive(Clone, Debug, Default)]
pub struct SnapshotMeta {
    /// Dataset name (a `data::synth` generator name for synthetic runs,
    /// or a free-form label for external data). Lets the search CLI
    /// regenerate the matching query set from the snapshot alone.
    pub dataset: String,
    /// Generator/build seed.
    pub seed: u64,
    /// Generator scale factor (synthetic datasets).
    pub scale: f64,
    /// Construction threading the index was built with.
    pub build: BuildParams,
    /// Recommended serving parameters.
    pub search_defaults: SearchParams,
}

/// Index-side facts the META section records alongside the caller's
/// [`SnapshotMeta`]. Grouped so the frozen ([`LeanVecIndex::save`]) and
/// live (`mutate::persist_live`) writers produce byte-identical META
/// for the same state.
pub(crate) struct MetaFacts {
    pub sim: Similarity,
    pub projection: ProjectionKind,
    pub primary: Compression,
    pub secondary: Compression,
    pub n: usize,
    pub input_dim: usize,
    pub target_dim: usize,
    pub breakdown: BuildBreakdown,
}

pub(crate) fn meta_to_json(meta: &SnapshotMeta, facts: &MetaFacts) -> Json {
    let b = facts.breakdown;
    Json::obj(vec![
        ("dataset", Json::str(&meta.dataset)),
        // seed is a string: u64 seeds above 2^53 would lose precision
        // as a JSON number
        ("seed", Json::str(&meta.seed.to_string())),
        ("scale", Json::num(meta.scale)),
        ("build_threads", Json::num(meta.build.build_threads as f64)),
        ("window", Json::num(meta.search_defaults.window as f64)),
        (
            "rerank_window",
            Json::num(meta.search_defaults.rerank_window as f64),
        ),
        ("similarity", Json::str(facts.sim.name())),
        ("projection", Json::str(facts.projection.name())),
        ("primary", Json::str(facts.primary.name())),
        ("secondary", Json::str(facts.secondary.name())),
        ("n", Json::num(facts.n as f64)),
        ("input_dim", Json::num(facts.input_dim as f64)),
        ("target_dim", Json::num(facts.target_dim as f64)),
        (
            "build_breakdown",
            Json::obj(vec![
                ("train_seconds", Json::num(b.train_seconds)),
                ("project_seconds", Json::num(b.project_seconds)),
                ("quantize_seconds", Json::num(b.quantize_seconds)),
                ("graph_seconds", Json::num(b.graph_seconds)),
            ]),
        ),
    ])
}

fn meta_from_json(j: &Json) -> (SnapshotMeta, BuildBreakdown, Option<Similarity>) {
    // lenient by design: META is the extensible section, so absent
    // fields fall back to defaults instead of failing the load
    let num = |key: &str, default: f64| j.get(key).and_then(|v| v.as_f64()).unwrap_or(default);
    let meta = SnapshotMeta {
        dataset: j
            .get("dataset")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string(),
        seed: j
            .get("seed")
            .and_then(|v| v.as_str())
            .and_then(|s| s.parse().ok())
            .unwrap_or(0),
        scale: num("scale", 0.0),
        build: BuildParams {
            build_threads: num("build_threads", 1.0) as usize,
        },
        search_defaults: SearchParams {
            window: num("window", SearchParams::default().window as f64) as usize,
            rerank_window: num(
                "rerank_window",
                SearchParams::default().rerank_window as f64,
            ) as usize,
        },
    };
    let bj = j.get("build_breakdown");
    let bnum = |key: &str| {
        bj.and_then(|b| b.get(key))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    let breakdown = BuildBreakdown {
        train_seconds: bnum("train_seconds"),
        project_seconds: bnum("project_seconds"),
        quantize_seconds: bnum("quantize_seconds"),
        graph_seconds: bnum("graph_seconds"),
    };
    let sim = j
        .get("similarity")
        .and_then(|v| v.as_str())
        .and_then(Similarity::parse);
    (meta, breakdown, sim)
}

/// How one tier of a mapped index is backed (see [`MmapPolicy`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Borrow the tier's arrays straight from the mapping: resident
    /// only while the kernel keeps the pages cached, evictable under
    /// memory pressure.
    Mapped,
    /// Decode the tier into owned heap memory at load time — always
    /// resident, immune to page-cache eviction, costs RAM.
    Resident,
}

/// Per-tier residency policy for [`LeanVecIndex::load_mmap_with`].
///
/// `codes` covers the hot traversal state (primary store + graph
/// adjacency); `rerank` covers the secondary (re-ranking) store, which
/// is usually the bulk of the bytes and the natural candidate to leave
/// on disk. The projection model and metadata are always resident
/// (small, touched every query).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MmapPolicy {
    /// Primary store + graph adjacency.
    pub codes: Tier,
    /// Secondary (re-ranking) store.
    pub rerank: Tier,
}

impl Default for MmapPolicy {
    /// Everything mapped — minimum resident set.
    fn default() -> MmapPolicy {
        MmapPolicy {
            codes: Tier::Mapped,
            rerank: Tier::Mapped,
        }
    }
}

impl MmapPolicy {
    /// Hot tiers resident, re-rank tier mapped: the "big vectors on
    /// disk, small codes in RAM" serving split from the paper.
    pub fn resident_codes() -> MmapPolicy {
        MmapPolicy {
            codes: Tier::Resident,
            rerank: Tier::Mapped,
        }
    }
}

/// Was `LEANVEC_FORCE_MMAP` set (to anything but `0`/empty)? Checked
/// per call — tests toggle it — unlike the once-per-process
/// `LEANVEC_FORCE_SCALAR` pin.
pub(crate) fn force_mmap_requested() -> bool {
    match std::env::var("LEANVEC_FORCE_MMAP") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

impl LeanVecIndex {
    /// Write the whole index to `path` as a versioned snapshot (see the
    /// [`crate::index::persist`] module docs for the format). Returns
    /// bytes written.
    ///
    /// `meta` carries what the index does not: dataset provenance and
    /// the recommended build/search knobs. Pass
    /// [`SnapshotMeta::default()`] when there is nothing to record.
    pub fn save(&self, path: &Path, meta: &SnapshotMeta) -> Result<u64, SnapshotError> {
        let facts = MetaFacts {
            sim: self.sim,
            projection: self.model.kind,
            primary: self.primary_compression,
            secondary: self.secondary_compression,
            n: self.len(),
            input_dim: self.model.input_dim(),
            target_dim: self.model.target_dim(),
            breakdown: self.build_breakdown,
        };
        let sections = core_sections(
            meta,
            &facts,
            &self.model,
            self.primary.as_ref(),
            self.secondary.as_ref(),
            &self.graph,
        );
        write_sections(path, &sections)
    }

    /// Load an index previously written by [`LeanVecIndex::save`].
    ///
    /// The loaded index serves queries **bit-identically** to the one
    /// that was saved: identical neighbor ids, identical scores,
    /// identical [`crate::index::query::QueryStats`]. Fails
    /// loudly — never panics — on a non-snapshot file, an unsupported
    /// format version, truncation, checksum mismatch, or an internally
    /// inconsistent payload.
    pub fn load(path: &Path) -> Result<(LeanVecIndex, SnapshotMeta), SnapshotError> {
        if force_mmap_requested() {
            return Self::load_mmap(path);
        }
        let sections = read_sections(path)?;
        load_core_sections(&sections)
    }

    /// [`LeanVecIndex::load`] off a read-only memory map with the
    /// default policy (everything mapped; see [`MmapPolicy`]).
    pub fn load_mmap(path: &Path) -> Result<(LeanVecIndex, SnapshotMeta), SnapshotError> {
        Self::load_mmap_with(path, MmapPolicy::default())
    }

    /// Load a snapshot by memory-mapping it and borrowing the large
    /// arrays (codes, adjacency, per-vector constants) directly from
    /// the mapping — startup does no bulk decode and the resident set
    /// is whatever the kernel keeps cached, so an index larger than RAM
    /// can serve.
    ///
    /// Semantics are identical to [`LeanVecIndex::load`]: same ids,
    /// same score bits, same [`crate::index::query::QueryStats`], same
    /// typed errors on damaged files. Every section's CRC-32 is
    /// verified (one sequential pass over the file) **before** any
    /// mapped bytes are trusted. Arrays whose mapped position is
    /// misaligned for their element type — pre-alignment snapshots, or
    /// the occasional small tail array — are decoded into owned memory
    /// instead, with a warning to stderr; correctness is unaffected.
    pub fn load_mmap_with(
        path: &Path,
        policy: MmapPolicy,
    ) -> Result<(LeanVecIndex, SnapshotMeta), SnapshotError> {
        let snap = load_mmap_any(path, policy, FORMAT_VERSION)?;
        Ok((snap.index, snap.meta))
    }
}

/// A snapshot loaded off a memory map: the core index (with its
/// `backing` set), plus owned copies of any non-core sections — the
/// shard loader reads its small live-layout extras (TOMBS/IDMAP/MUTLOG)
/// from there.
pub(crate) struct MappedSnapshot {
    pub version: u32,
    pub index: LeanVecIndex,
    pub meta: SnapshotMeta,
    /// Sections other than the five core ones, owned (they are small).
    pub extra: Vec<RawSection>,
}

/// The shared body of [`LeanVecIndex::load_mmap_with`] and the sharded
/// directory loader, which must also accept pristine live-stamped shard
/// files (`max_version = FORMAT_VERSION_LIVE`).
pub(crate) fn load_mmap_any(
    path: &Path,
    policy: MmapPolicy,
    max_version: u32,
) -> Result<MappedSnapshot, SnapshotError> {
    let map = Arc::new(Mmap::open(path).map_err(SnapshotError::Io)?);
    // one sequential pass: table parse + every section's CRC — no
    // mapped byte is trusted before its checksum passes
    map.advise(Advice::Sequential);
    let (version, locs) = parse_locs(map.as_slice(), max_version)?;
    // serving touches rows in graph order, not file order
    map.advise(Advice::Random);
    let fallbacks = Arc::new(AtomicUsize::new(0));
    let views: Vec<SectionView<'_>> = locs
        .iter()
        .map(|l| {
            let tier = if l.tag == SECTION_PRIMARY || l.tag == SECTION_GRAPH {
                policy.codes
            } else if l.tag == SECTION_SECONDARY {
                policy.rerank
            } else {
                Tier::Resident
            };
            let src = match tier {
                Tier::Mapped => Some(SectionSrc {
                    map: Arc::clone(&map),
                    base: l.offset,
                    fallbacks: Arc::clone(&fallbacks),
                }),
                Tier::Resident => None,
            };
            SectionView {
                tag: l.tag,
                bytes: &map.as_slice()[l.offset..l.offset + l.len],
                src,
            }
        })
        .collect();
    let (mut index, meta) = load_core_views(&views)?;
    drop(views);
    // ORDERING: Relaxed — diagnostic counter bumped during the (single
    // logical) load above; no synchronization rides on it.
    let fell = fallbacks.load(Ordering::Relaxed);
    if fell > 0 {
        eprintln!(
            "leanvec: load_mmap({}): {fell} array(s) decoded to owned memory \
             (misaligned in file — pre-alignment snapshot or small tail array); \
             results are unaffected",
            path.display()
        );
    }
    const CORE: [[u8; 8]; 5] = [
        SECTION_META,
        SECTION_MODEL,
        SECTION_PRIMARY,
        SECTION_SECONDARY,
        SECTION_GRAPH,
    ];
    let extra = locs
        .iter()
        .filter(|l| !CORE.contains(&l.tag))
        .map(|l| RawSection::new(l.tag, map.as_slice()[l.offset..l.offset + l.len].to_vec()))
        .collect();
    index.backing = Some(map);
    Ok(MappedSnapshot {
        version,
        index,
        meta,
        extra,
    })
}

/// Serialize the five core sections shared by frozen and live
/// snapshots (META, MODEL, PRIMARY, SECSTORE, GRAPH), in table order.
pub(crate) fn core_sections(
    meta: &SnapshotMeta,
    facts: &MetaFacts,
    model: &LeanVecModel,
    primary: &dyn crate::quant::ScoreStore,
    secondary: &dyn crate::quant::ScoreStore,
    graph: &VamanaGraph,
) -> Vec<RawSection> {
    let mut model_bytes = Vec::new();
    model.write_bytes(&mut model_bytes);
    let mut primary_bytes = Vec::new();
    let primary_anchor = primary.write_bytes(&mut primary_bytes);
    let mut secondary_bytes = Vec::new();
    let secondary_anchor = secondary.write_bytes(&mut secondary_bytes);
    let mut graph_bytes = Vec::new();
    let graph_anchor = graph.write_bytes(&mut graph_bytes);
    vec![
        RawSection::new(
            SECTION_META,
            meta_to_json(meta, facts).to_pretty().into_bytes(),
        ),
        RawSection::new(SECTION_MODEL, model_bytes),
        RawSection {
            tag: SECTION_PRIMARY,
            bytes: primary_bytes,
            anchor: primary_anchor,
        },
        RawSection {
            tag: SECTION_SECONDARY,
            bytes: secondary_bytes,
            anchor: secondary_anchor,
        },
        RawSection {
            tag: SECTION_GRAPH,
            bytes: graph_bytes,
            anchor: graph_anchor,
        },
    ]
}

/// One section of a snapshot as the core loader consumes it: the
/// payload bytes plus, when the bytes live in a memory map the loaded
/// index may borrow from, the mapping context for zero-copy views.
pub(crate) struct SectionView<'a> {
    pub tag: [u8; 8],
    pub bytes: &'a [u8],
    pub src: Option<SectionSrc>,
}

/// Parse + cross-validate the five core sections into a
/// [`LeanVecIndex`] — the shared body of [`LeanVecIndex::load`] and the
/// live loader (`mutate::persist_live`), which layers the live sections
/// on top.
pub(crate) fn load_core_sections(
    sections: &[RawSection],
) -> Result<(LeanVecIndex, SnapshotMeta), SnapshotError> {
    let views: Vec<SectionView<'_>> = sections
        .iter()
        .map(|s| SectionView {
            tag: s.tag,
            bytes: s.bytes.as_slice(),
            src: None,
        })
        .collect();
    load_core_views(&views)
}

/// [`load_core_sections`] over borrowed section windows: the owned path
/// passes `src: None` everywhere (every array decoded to heap), the
/// mmap path attaches a [`SectionSrc`] to the sections whose tier the
/// [`MmapPolicy`] maps, and the store/graph readers borrow any suitably
/// aligned array in place.
pub(crate) fn load_core_views(
    sections: &[SectionView<'_>],
) -> Result<(LeanVecIndex, SnapshotMeta), SnapshotError> {
    {
        let find = |tag: [u8; 8]| -> Result<&SectionView<'_>, SnapshotError> {
            sections
                .iter()
                .find(|s| s.tag == tag)
                .ok_or_else(|| SnapshotError::MissingSection(tag_str(&tag)))
        };

        // META: JSON, parsed leniently (the extensible section)
        let meta_bytes = find(SECTION_META)?.bytes;
        let meta_text = std::str::from_utf8(meta_bytes)
            .map_err(|_| SnapshotError::Corrupt("META is not UTF-8".into()))?;
        let meta_json = Json::parse(meta_text)
            .map_err(|e| SnapshotError::Corrupt(format!("META json: {e}")))?;
        let (meta, breakdown, meta_sim) = meta_from_json(&meta_json);

        // MODEL (always resident: small, touched every query)
        let model = LeanVecModel::read_bytes(&mut bin::Cursor::new(find(SECTION_MODEL)?.bytes))?;

        // stores: payloads are self-describing (leading compression code)
        let primary_view = find(SECTION_PRIMARY)?;
        let secondary_view = find(SECTION_SECONDARY)?;
        let store_kind = |bytes: &[u8], which: &str| -> Result<Compression, SnapshotError> {
            bytes
                .first()
                .copied()
                .and_then(Compression::from_code)
                .ok_or_else(|| SnapshotError::Corrupt(format!("{which} store kind byte")))
        };
        let primary_compression = store_kind(primary_view.bytes, "primary")?;
        let secondary_compression = store_kind(secondary_view.bytes, "secondary")?;
        let primary = read_store_src(
            &mut bin::Cursor::new(primary_view.bytes),
            primary_view.src.as_ref(),
        )?;
        let secondary = read_store_src(
            &mut bin::Cursor::new(secondary_view.bytes),
            secondary_view.src.as_ref(),
        )?;

        // GRAPH
        let graph_view = find(SECTION_GRAPH)?;
        let graph = VamanaGraph::read_bytes_src(
            &mut bin::Cursor::new(graph_view.bytes),
            graph_view.src.as_ref(),
        )?;

        // cross-section consistency: every section describes the same
        // collection or the snapshot is rejected
        let n = primary.len();
        if secondary.len() != n || graph.adj.len_nodes() != n {
            return Err(SnapshotError::Corrupt(format!(
                "section sizes disagree: primary {n}, secondary {}, graph {}",
                secondary.len(),
                graph.adj.len_nodes()
            )));
        }
        if model.target_dim() != primary.dim() || model.input_dim() != secondary.dim() {
            return Err(SnapshotError::Corrupt(format!(
                "model dims ({} -> {}) disagree with stores ({} primary, {} secondary)",
                model.input_dim(),
                model.target_dim(),
                primary.dim(),
                secondary.dim()
            )));
        }
        if let Some(ms) = meta_sim {
            if ms != graph.sim {
                return Err(SnapshotError::Corrupt(
                    "META similarity disagrees with graph section".into(),
                ));
            }
        }

        let sim = graph.sim;
        Ok((
            LeanVecIndex {
                model,
                primary,
                secondary,
                graph,
                sim,
                primary_compression,
                secondary_compression,
                build_breakdown: breakdown,
                backing: None,
            },
            meta,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn raw_sections_roundtrip_and_preserve_unknown_tags() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("leanvec-persist-raw-{}.snap", std::process::id()));
        let sections = [
            RawSection::new(SECTION_META, b"{}".to_vec()),
            RawSection::new(*b"FUTURE\0\0", vec![1, 2, 3, 4, 5]),
        ];
        write_sections(&path, &sections).unwrap();
        let back = read_sections(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].tag, SECTION_META);
        assert_eq!(back[1].tag, *b"FUTURE\0\0");
        assert_eq!(back[1].bytes, vec![1, 2, 3, 4, 5]);
        std::fs::remove_file(&path).ok();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn parse_rejects_bad_magic_version_and_crc() {
        let mut buf = Vec::new();
        // build a valid one-section snapshot in memory
        buf.extend_from_slice(&MAGIC);
        bin::put_u32(&mut buf, FORMAT_VERSION);
        bin::put_u32(&mut buf, 1);
        let payload = b"hello".to_vec();
        buf.extend_from_slice(&SECTION_META);
        bin::put_u64(&mut buf, (16 + 28) as u64);
        bin::put_u64(&mut buf, payload.len() as u64);
        bin::put_u32(&mut buf, crc32(&payload));
        buf.extend_from_slice(&payload);
        assert!(parse_sections(&buf).is_ok());

        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(matches!(parse_sections(&bad), Err(SnapshotError::BadMagic)));

        let mut bad = buf.clone();
        bad[8] = 99;
        assert!(matches!(
            parse_sections(&bad),
            Err(SnapshotError::UnsupportedVersion { found: 99, .. })
        ));

        let mut bad = buf.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        assert!(matches!(
            parse_sections(&bad),
            Err(SnapshotError::ChecksumMismatch { .. })
        ));

        for cut in [4usize, 12, 20, buf.len() - 1] {
            assert!(parse_sections(&buf[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn tag_str_strips_padding() {
        assert_eq!(tag_str(&SECTION_META), "META");
        assert_eq!(tag_str(&SECTION_SECONDARY), "SECSTORE");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn writer_aligns_every_anchor_to_64() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("leanvec-persist-align-{}.snap", std::process::id()));
        // awkward lengths and anchors on purpose
        let sections = [
            RawSection::new(SECTION_META, b"{\"k\":1}".to_vec()),
            RawSection {
                tag: *b"A\0\0\0\0\0\0\0",
                bytes: vec![7u8; 129],
                anchor: 13,
            },
            RawSection {
                tag: *b"B\0\0\0\0\0\0\0",
                bytes: vec![9u8; 65],
                anchor: 61,
            },
        ];
        write_sections(&path, &sections).unwrap();
        let buf = std::fs::read(&path).unwrap();
        let (_v, locs) = parse_locs(&buf, FORMAT_VERSION).unwrap();
        assert_eq!(locs.len(), 3);
        for (loc, s) in locs.iter().zip(&sections) {
            assert_eq!(
                (loc.offset + s.anchor) as u64 % SECTION_ALIGN,
                0,
                "section '{}' anchor not aligned",
                tag_str(&loc.tag)
            );
            assert_eq!(&buf[loc.offset..loc.offset + loc.len], &s.bytes[..]);
        }
        // and the owned reader sees identical payloads through the padding
        let back = read_sections(&path).unwrap();
        for (b, s) in back.iter().zip(&sections) {
            assert_eq!(b.bytes, s.bytes);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn aligned_writer_is_deterministic() {
        let dir = std::env::temp_dir();
        let p1 = dir.join(format!("leanvec-persist-det1-{}.snap", std::process::id()));
        let p2 = dir.join(format!("leanvec-persist-det2-{}.snap", std::process::id()));
        let sections = [
            RawSection::new(SECTION_META, b"{}".to_vec()),
            RawSection {
                tag: SECTION_PRIMARY,
                bytes: vec![3u8; 100],
                anchor: 21,
            },
        ];
        write_sections(&p1, &sections).unwrap();
        write_sections(&p2, &sections).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }
}
