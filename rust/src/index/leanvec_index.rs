//! The LeanVec index: Vamana graph over dimensionality-reduced +
//! LVQ-quantized *primary* vectors, re-ranked with full-dimensional
//! *secondary* vectors (Fig. 1b).
//!
//! Search = (1) project the query once (`A q` — negligible, Section 2),
//! (2) traverse the graph scoring primaries, (3) re-rank the top
//! `rerank_window` candidates with the secondary store, (4) return top-k.

use crate::config::{Compression, Similarity};
use crate::graph::beam::SearchCtx;
use crate::graph::vamana::VamanaGraph;
use crate::leanvec::model::LeanVecModel;
use crate::quant::{Lvq4x8Store, LvqStore, PreparedQuery, ScoreStore, F16Store, F32Store};

/// Runtime search knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchParams {
    /// graph search-buffer width L
    pub window: usize,
    /// candidates re-scored with the secondary store (>= k)
    pub rerank_window: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams {
            window: 50,
            rerank_window: 50,
        }
    }
}

/// Per-query traffic/latency accounting (drives Fig. 1's bandwidth
/// model).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueryStats {
    pub primary_scored: usize,
    pub reranked: usize,
    pub bytes_touched: usize,
    pub hops: usize,
}

/// Build a store of the requested compression over rows.
pub fn make_store(rows: &[Vec<f32>], compression: Compression) -> Box<dyn ScoreStore> {
    make_store_threads(rows, compression, 1)
}

/// [`make_store`] with the encoding fanned out across `threads` workers
/// (0 = all cores). Encoding is per-row work, so the stores are
/// bit-identical to the serial build. F32 stays serial — it is a copy,
/// not a computation.
pub fn make_store_threads(
    rows: &[Vec<f32>],
    compression: Compression,
    threads: usize,
) -> Box<dyn ScoreStore> {
    match compression {
        Compression::F32 => Box::new(F32Store::from_rows(rows)),
        Compression::F16 => Box::new(F16Store::from_rows_threads(rows, threads)),
        Compression::Lvq8 => Box::new(LvqStore::new_threads(rows, 8, threads)),
        Compression::Lvq4 => Box::new(LvqStore::new_threads(rows, 4, threads)),
        Compression::Lvq4x8 => Box::new(Lvq4x8Store::new_threads(rows, threads)),
    }
}

/// The LeanVec search-and-rerank index. Built once by
/// [`crate::index::IndexBuilder`]; round-trips to disk whole via
/// [`LeanVecIndex::save`]/[`LeanVecIndex::load`]
/// (`crate::index::persist`), after which the loaded copy serves
/// bit-identical results to the built one.
pub struct LeanVecIndex {
    /// Projection pair `(A, B)`: queries traverse through `A q`,
    /// database vectors were stored as `B x`.
    pub model: LeanVecModel,
    /// Traversal store over projected + quantized vectors.
    pub primary: Box<dyn ScoreStore>,
    /// Re-ranking store over full-dimensional vectors.
    pub secondary: Box<dyn ScoreStore>,
    /// Vamana graph over the primary store.
    pub graph: VamanaGraph,
    /// Similarity the scores express (cosine is normalized to IP at
    /// build time, so this is never [`Similarity::Cosine`]).
    pub sim: Similarity,
    /// Compression of [`LeanVecIndex::primary`].
    pub primary_compression: Compression,
    /// Compression of [`LeanVecIndex::secondary`].
    pub secondary_compression: Compression,
    /// wall-clock seconds: projection training + database projection +
    /// quantization + graph build (Fig. 6 decomposition)
    pub build_breakdown: BuildBreakdown,
}

/// Wall-clock decomposition of one index build (Fig. 6). Persisted in
/// the snapshot META section as build provenance.
#[derive(Clone, Copy, Debug, Default)]
pub struct BuildBreakdown {
    /// projection training (phase 1)
    pub train_seconds: f64,
    /// database projection (phase 2)
    pub project_seconds: f64,
    /// primary + secondary store encoding (phase 3)
    pub quantize_seconds: f64,
    /// Vamana graph construction (phase 4)
    pub graph_seconds: f64,
}

impl BuildBreakdown {
    pub fn total(&self) -> f64 {
        self.train_seconds + self.project_seconds + self.quantize_seconds + self.graph_seconds
    }
}

impl LeanVecIndex {
    pub fn len(&self) -> usize {
        self.primary.len()
    }

    pub fn is_empty(&self) -> bool {
        self.primary.len() == 0
    }

    /// Search with a fresh context (convenience; allocates).
    pub fn search(&self, q: &[f32], k: usize, window: usize) -> (Vec<u32>, Vec<f32>) {
        let mut ctx = SearchCtx::new(self.len());
        let params = SearchParams {
            window,
            rerank_window: window.max(k),
        };
        let (ids, scores, _) = self.search_with_ctx(&mut ctx, q, k, params);
        (ids, scores)
    }

    /// Hot-path search with a reusable context. Returns (ids, scores,
    /// stats), best-first.
    pub fn search_with_ctx(
        &self,
        ctx: &mut SearchCtx,
        q: &[f32],
        k: usize,
        params: SearchParams,
    ) -> (Vec<u32>, Vec<f32>, QueryStats) {
        // (1) project the query once
        let q_proj = self.model.project_query(q);
        let pq = self.primary.prepare(&q_proj, self.sim);
        // (2) graph traversal over primaries
        let cands = self.graph.search(ctx, self.primary.as_ref(), &pq, params.window);
        let take = params.rerank_window.max(k).min(cands.len());
        let ids: Vec<u32> = cands[..take].iter().map(|c| c.id).collect();
        let stats = QueryStats {
            primary_scored: ctx.stats.scored,
            reranked: take,
            // rerank traffic uses rerank_bytes_per_vector: two-level
            // secondaries read their residual bytes during re-scoring
            bytes_touched: ctx.stats.scored * self.primary.bytes_per_vector()
                + take * self.secondary.rerank_bytes_per_vector(),
            hops: ctx.stats.hops,
        };
        // (3) re-rank with secondary vectors in the original space
        let (ids, scores) = self.rerank(q, &ids, k);
        (ids, scores, stats)
    }

    /// Search with an externally projected query (the coordinator
    /// projects whole batches at once — natively or through the PJRT
    /// `project_q` artifact — then fans the searches out to workers).
    pub fn search_projected(
        &self,
        ctx: &mut SearchCtx,
        q_proj: &[f32],
        q_orig: &[f32],
        k: usize,
        params: SearchParams,
    ) -> (Vec<u32>, Vec<f32>, QueryStats) {
        let pq = self.primary.prepare(q_proj, self.sim);
        let cands = self.graph.search(ctx, self.primary.as_ref(), &pq, params.window);
        let take = params.rerank_window.max(k).min(cands.len());
        let ids: Vec<u32> = cands[..take].iter().map(|c| c.id).collect();
        let stats = QueryStats {
            primary_scored: ctx.stats.scored,
            reranked: take,
            bytes_touched: ctx.stats.scored * self.primary.bytes_per_vector()
                + take * self.secondary.rerank_bytes_per_vector(),
            hops: ctx.stats.hops,
        };
        let (ids, scores) = self.rerank(q_orig, &ids, k);
        (ids, scores, stats)
    }

    /// Re-score `ids` with the secondary store and return the top-k.
    /// Uses `score_rerank`, so a two-level secondary contributes its
    /// residual level here (full-accuracy re-ranking).
    pub fn rerank(&self, q: &[f32], ids: &[u32], k: usize) -> (Vec<u32>, Vec<f32>) {
        let pq: PreparedQuery = self.secondary.prepare(q, self.sim);
        let mut scored: Vec<(f32, u32)> = ids
            .iter()
            .map(|&id| (self.secondary.score_rerank(&pq, id), id))
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        scored.truncate(k);
        (
            scored.iter().map(|&(_, id)| id).collect(),
            scored.iter().map(|&(s, _)| s).collect(),
        )
    }

    /// Primary-only search (no re-ranking) — the Fig. 11 ablation arm.
    pub fn search_no_rerank(
        &self,
        ctx: &mut SearchCtx,
        q: &[f32],
        k: usize,
        window: usize,
    ) -> Vec<u32> {
        let q_proj = self.model.project_query(q);
        let pq = self.primary.prepare(&q_proj, self.sim);
        let cands = self.graph.search(ctx, self.primary.as_ref(), &pq, window);
        cands.iter().take(k).map(|c| c.id).collect()
    }

    /// Shared parallel fan-out for batch search: run `f(ctx, i)` for
    /// every index in `0..n` across `threads` workers (0 = all cores),
    /// each drawing a reusable [`SearchCtx`] from a pool — the same
    /// chunking discipline as the parallel builder. Used by
    /// [`LeanVecIndex::search_batch`] and the coordinator's direct
    /// batch path; results are in index order and identical for every
    /// thread count.
    pub(crate) fn batch_fan_out<T, F>(&self, n: usize, threads: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&mut SearchCtx, usize) -> T + Sync,
    {
        let threads = crate::util::threadpool::resolve_threads(threads);
        let pool = crate::graph::beam::CtxPool::new(threads, self.len());
        crate::util::threadpool::parallel_map(n, threads, |i| {
            let mut ctx = pool.acquire();
            f(&mut *ctx, i)
        })
    }

    /// Parallel closed-loop batch search over raw (unprojected)
    /// queries. Results are identical to per-query
    /// [`LeanVecIndex::search_with_ctx`] calls for every thread count.
    pub fn search_batch(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        params: SearchParams,
        threads: usize,
    ) -> Vec<(Vec<u32>, Vec<f32>)> {
        self.batch_fan_out(queries.len(), threads, |ctx, i| {
            let (ids, scores, _) = self.search_with_ctx(ctx, &queries[i], k, params);
            (ids, scores)
        })
    }

    /// Compression ratio of the primary representation vs FP16 full-D
    /// (the Fig. 1 headline number, e.g. 9.6x for rqa-768 at d=160).
    pub fn primary_compression_vs_fp16(&self) -> f64 {
        let full_fp16 = self.model.input_dim() * 2;
        full_fp16 as f64 / self.primary.bytes_per_vector() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, ProjectionKind};
    use crate::index::builder::IndexBuilder;
    use crate::index::flat::FlatIndex;
    use crate::util::rng::Rng;

    /// low-rank data so a d=8 projection preserves structure
    fn lowrank_rows(n: usize, dd: usize, rank: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let basis: Vec<Vec<f32>> = (0..rank)
            .map(|_| (0..dd).map(|_| rng.gaussian_f32()).collect())
            .collect();
        (0..n)
            .map(|_| {
                let coef: Vec<f32> = (0..rank).map(|_| rng.gaussian_f32()).collect();
                let mut v = vec![0.0f32; dd];
                for (c, b) in coef.iter().zip(basis.iter()) {
                    for (x, &bv) in v.iter_mut().zip(b.iter()) {
                        *x += c * bv;
                    }
                }
                for x in v.iter_mut() {
                    *x += 0.01 * rng.gaussian_f32();
                }
                v
            })
            .collect()
    }

    fn build_small(rows: &[Vec<f32>], d: usize) -> LeanVecIndex {
        let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
        gp.max_degree = 16;
        gp.build_window = 40;
        IndexBuilder::new()
            .projection(ProjectionKind::Id)
            .target_dim(d)
            .graph_params(gp)
            .build(rows, None, Similarity::InnerProduct)
    }

    #[test]
    fn recall_with_rerank_beats_no_rerank() {
        let rows = lowrank_rows(500, 32, 6, 1);
        let index = build_small(&rows, 8);
        let flat = FlatIndex::new(&rows, Similarity::InnerProduct);
        let mut rng = Rng::new(42);
        let mut ctx = SearchCtx::new(rows.len());
        let trials = 30;
        let (mut hit_rr, mut hit_nr) = (0usize, 0usize);
        for _ in 0..trials {
            let q: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
            let (truth, _) = flat.search(&q, 10);
            let (ids, _, _) = index.search_with_ctx(
                &mut ctx,
                &q,
                10,
                SearchParams {
                    window: 50,
                    rerank_window: 50,
                },
            );
            hit_rr += truth.iter().filter(|t| ids.contains(t)).count();
            let ids_nr = index.search_no_rerank(&mut ctx, &q, 10, 50);
            hit_nr += truth.iter().filter(|t| ids_nr.contains(t)).count();
        }
        let (r_rr, r_nr) = (
            hit_rr as f64 / (trials * 10) as f64,
            hit_nr as f64 / (trials * 10) as f64,
        );
        assert!(r_rr >= r_nr - 0.02, "rerank {r_rr} vs none {r_nr}");
        assert!(r_rr >= 0.8, "rerank recall {r_rr}");
    }

    #[test]
    fn stats_populate() {
        let rows = lowrank_rows(200, 16, 4, 2);
        let index = build_small(&rows, 6);
        let mut ctx = SearchCtx::new(rows.len());
        let (_, _, stats) = index.search_with_ctx(
            &mut ctx,
            &rows[0],
            5,
            SearchParams {
                window: 20,
                rerank_window: 20,
            },
        );
        assert!(stats.primary_scored > 0);
        assert!(stats.reranked > 0);
        assert!(stats.bytes_touched > 0);
        assert!(stats.hops > 0);
    }

    #[test]
    fn bytes_touched_counts_residual_for_two_level_secondary() {
        let rows = lowrank_rows(200, 16, 4, 7);
        let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
        gp.max_degree = 16;
        gp.build_window = 40;
        let build = |secondary| {
            IndexBuilder::new()
                .projection(ProjectionKind::Id)
                .target_dim(6)
                .secondary(secondary)
                .graph_params(gp)
                .build(&rows, None, Similarity::InnerProduct)
        };
        let two_level = build(crate::config::Compression::Lvq4x8);
        let one_level = build(crate::config::Compression::Lvq4);
        let params = SearchParams {
            window: 20,
            rerank_window: 20,
        };
        let mut ctx = SearchCtx::new(rows.len());
        let (_, _, s2) = two_level.search_with_ctx(&mut ctx, &rows[0], 5, params);
        let (_, _, s1) = one_level.search_with_ctx(&mut ctx, &rows[0], 5, params);
        // identical traversal-layer compression; the two-level secondary
        // must report strictly more rerank traffic (its residual bytes)
        assert_eq!(
            two_level.secondary.bytes_per_vector(),
            one_level.secondary.bytes_per_vector()
        );
        assert!(
            two_level.secondary.rerank_bytes_per_vector()
                > one_level.secondary.rerank_bytes_per_vector()
        );
        assert!(s2.reranked > 0 && s1.reranked > 0);
        // same primary store + seed -> identical traversal; the byte
        // accounting must therefore differ by exactly the rerank traffic
        assert!(s2.bytes_touched > s1.bytes_touched);
    }

    #[test]
    fn search_batch_matches_sequential_search() {
        let rows = lowrank_rows(300, 16, 4, 8);
        let index = build_small(&rows, 6);
        let mut rng = Rng::new(31);
        let queries: Vec<Vec<f32>> = (0..24)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let params = SearchParams {
            window: 30,
            rerank_window: 30,
        };
        let mut ctx = SearchCtx::new(rows.len());
        let sequential: Vec<Vec<u32>> = queries
            .iter()
            .map(|q| index.search_with_ctx(&mut ctx, q, 5, params).0)
            .collect();
        for threads in [1usize, 3] {
            let batched: Vec<Vec<u32>> = index
                .search_batch(&queries, 5, params, threads)
                .into_iter()
                .map(|(ids, _)| ids)
                .collect();
            assert_eq!(batched, sequential, "threads {threads}");
        }
    }

    #[test]
    fn compression_ratio_reported() {
        let rows = lowrank_rows(150, 32, 4, 3);
        let index = build_small(&rows, 8);
        // full fp16 = 64 B; primary lvq8 at d=8 = 8 + 8 = 16 B -> 4x
        assert!(index.primary_compression_vs_fp16() > 2.0);
    }

    #[test]
    fn scores_descend() {
        let rows = lowrank_rows(150, 16, 4, 4);
        let index = build_small(&rows, 6);
        let (_, scores) = index.search(&rows[3], 10, 30);
        for w in scores.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }
}
