//! The LeanVec index: Vamana graph over dimensionality-reduced +
//! LVQ-quantized *primary* vectors, re-ranked with full-dimensional
//! *secondary* vectors (Fig. 1b).
//!
//! Search = (1) project the query once (`A q` — negligible, Section 2),
//! (2) traverse the graph scoring primaries, (3) re-rank the top
//! `rerank_window` candidates with the secondary store, (4) return
//! top-k. All of it is driven through the unified
//! [`VectorIndex`] trait with a typed [`Query`]; the serving engine
//! enters below the projection step via
//! [`LeanVecIndex::search_prepared`] (it projects whole batches at
//! once).

use crate::config::{Compression, Similarity};
use crate::graph::beam::SearchCtx;
use crate::graph::vamana::VamanaGraph;
use crate::index::query::{Query, QueryStats, SearchResult, VectorIndex};
use crate::leanvec::model::LeanVecModel;
use crate::quant::{Lvq4x8Store, LvqStore, PreparedQuery, ScoreStore, F16Store, F32Store};

/// Engine-level serving defaults: what a [`Query`] resolves against
/// when it does not override the knobs per-request. Persisted in
/// snapshot metadata as the recommended serving parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchParams {
    /// graph search-buffer width L
    pub window: usize,
    /// candidates re-scored with the secondary store (>= k); may exceed
    /// `window` (split-buffer: extra candidates are retained for
    /// re-ranking without widening the traversal)
    pub rerank_window: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams {
            window: 50,
            rerank_window: 50,
        }
    }
}

/// Build a store of the requested compression over rows.
pub fn make_store(rows: &[Vec<f32>], compression: Compression) -> Box<dyn ScoreStore> {
    make_store_threads(rows, compression, 1)
}

/// [`make_store`] with the encoding fanned out across `threads` workers
/// (0 = all cores). Encoding is per-row work, so the stores are
/// bit-identical to the serial build. F32 stays serial — it is a copy,
/// not a computation.
pub fn make_store_threads(
    rows: &[Vec<f32>],
    compression: Compression,
    threads: usize,
) -> Box<dyn ScoreStore> {
    match compression {
        Compression::F32 => Box::new(F32Store::from_rows(rows)),
        Compression::F16 => Box::new(F16Store::from_rows_threads(rows, threads)),
        Compression::Lvq8 => Box::new(LvqStore::new_threads(rows, 8, threads)),
        Compression::Lvq4 => Box::new(LvqStore::new_threads(rows, 4, threads)),
        Compression::Lvq4x8 => Box::new(Lvq4x8Store::new_threads(rows, threads)),
    }
}

/// The LeanVec search-and-rerank index. Built once by
/// [`crate::index::IndexBuilder`]; round-trips to disk whole via
/// [`LeanVecIndex::save`]/[`LeanVecIndex::load`]
/// (`crate::index::persist`), after which the loaded copy serves
/// bit-identical results to the built one.
pub struct LeanVecIndex {
    /// Projection pair `(A, B)`: queries traverse through `A q`,
    /// database vectors were stored as `B x`.
    pub model: LeanVecModel,
    /// Traversal store over projected + quantized vectors.
    pub primary: Box<dyn ScoreStore>,
    /// Re-ranking store over full-dimensional vectors.
    pub secondary: Box<dyn ScoreStore>,
    /// Vamana graph over the primary store.
    pub graph: VamanaGraph,
    /// Similarity the scores express (cosine is normalized to IP at
    /// build time, so this is never [`Similarity::Cosine`]).
    pub sim: Similarity,
    /// Compression of [`LeanVecIndex::primary`].
    pub primary_compression: Compression,
    /// Compression of [`LeanVecIndex::secondary`].
    pub secondary_compression: Compression,
    /// wall-clock seconds: projection training + database projection +
    /// quantization + graph build (Fig. 6 decomposition)
    pub build_breakdown: BuildBreakdown,
    /// The memory map backing any borrowed arrays when the index came
    /// from [`LeanVecIndex::load_mmap`]; `None` for built or
    /// conventionally loaded indexes. Holding it here keeps the mapping
    /// alive exactly as long as the views into it.
    pub backing: Option<std::sync::Arc<crate::util::mmap::Mmap>>,
}

/// Wall-clock decomposition of one index build (Fig. 6). Persisted in
/// the snapshot META section as build provenance.
#[derive(Clone, Copy, Debug, Default)]
pub struct BuildBreakdown {
    /// projection training (phase 1)
    pub train_seconds: f64,
    /// database projection (phase 2)
    pub project_seconds: f64,
    /// primary + secondary store encoding (phase 3)
    pub quantize_seconds: f64,
    /// Vamana graph construction (phase 4)
    pub graph_seconds: f64,
}

impl BuildBreakdown {
    pub fn total(&self) -> f64 {
        self.train_seconds + self.project_seconds + self.quantize_seconds + self.graph_seconds
    }
}

impl LeanVecIndex {
    /// Deep consistency check for the fsck layer: cross-layer size
    /// relations (both stores and the graph agree on the row count,
    /// store dims match the projection model), the graph's structural
    /// invariants, and both stores' internal invariants. Returns a
    /// typed report instead of panicking — `repro fsck` and the
    /// corruption test battery consume the same entry point.
    pub fn check_invariants(&self) -> crate::util::invariants::FsckReport {
        use crate::util::invariants::{FsckReport, Violation};
        let mut report = FsckReport::default();
        let n = self.primary.len();
        if self.secondary.len() != n || self.graph.adj.len_nodes() != n {
            report.violations.push(Violation::new(
                "index",
                "store-len-mismatch",
                format!(
                    "primary {} / secondary {} / graph {} row counts disagree",
                    n,
                    self.secondary.len(),
                    self.graph.adj.len_nodes()
                ),
            ));
        }
        if self.primary.dim() != self.model.target_dim() {
            report.violations.push(Violation::new(
                "index",
                "dim-mismatch",
                format!(
                    "primary store dim {} != model target dim {}",
                    self.primary.dim(),
                    self.model.target_dim()
                ),
            ));
        }
        if self.secondary.dim() != self.model.input_dim() {
            report.violations.push(Violation::new(
                "index",
                "dim-mismatch",
                format!(
                    "secondary store dim {} != model input dim {}",
                    self.secondary.dim(),
                    self.model.input_dim()
                ),
            ));
        }
        self.graph.check_invariants(&mut report.violations);
        for (layer, store) in [
            ("primary-store", &self.primary),
            ("secondary-store", &self.secondary),
        ] {
            let mut tmp = Vec::new();
            store.check_invariants(&mut tmp);
            for mut v in tmp {
                v.layer = layer;
                report.violations.push(v);
            }
            report
                .checked
                .push(format!("{layer}: {} rows x {} dims", store.len(), store.dim()));
        }
        report.checked.push(format!(
            "graph: {n} nodes, max degree {}, medoid {}",
            self.graph.adj.max_degree(),
            self.graph.medoid
        ));
        report
    }

    pub fn len(&self) -> usize {
        self.primary.len()
    }

    pub fn is_empty(&self) -> bool {
        self.primary.len() == 0
    }

    /// Search with an externally projected query vector (the
    /// coordinator projects whole batches as one matmul — natively or
    /// through the PJRT `project_q` artifact — then fans the searches
    /// out to workers). `query.vector()` must be the *original*
    /// full-dimensional vector: re-ranking happens in the original
    /// space. [`VectorIndex::search`] is this plus the per-query
    /// projection.
    pub fn search_prepared(
        &self,
        ctx: &mut SearchCtx,
        q_proj: &[f32],
        query: &Query,
    ) -> SearchResult {
        let k = query.top_k();
        let params = query.effective(SearchParams::default());
        let pq = self.primary.prepare(q_proj, self.sim);
        // stage timers live here, not in simd/: the kernels stay
        // branch-free while the index layer owns the clock reads
        let telem = crate::obs::enabled();
        let t_trav = if telem {
            Some(std::time::Instant::now())
        } else {
            None
        };
        // graph traversal over primaries: retain up to rerank_window
        // candidates (split buffer) while expanding only the window
        let capacity = params.rerank_window.max(k);
        let cands = self.graph.search_filtered(
            ctx,
            self.primary.as_ref(),
            &pq,
            params.window,
            capacity,
            query.filter_fn(),
        );
        if let Some(t) = t_trav {
            crate::obs::handles()
                .index_traversal
                .record_seconds(t.elapsed().as_secs_f64());
        }
        let take = params.rerank_window.max(k).min(cands.len());
        if !query.wants_rerank() {
            // primary-only ablation arm: top-k straight off the traversal
            let take_k = k.min(cands.len());
            let ids: Vec<u32> = cands[..take_k].iter().map(|c| c.id).collect();
            let scores: Vec<f32> = cands[..take_k].iter().map(|c| c.score).collect();
            return SearchResult {
                ids,
                scores,
                stats: QueryStats {
                    primary_scored: ctx.stats.scored,
                    reranked: 0,
                    bytes_touched: ctx.stats.scored * self.primary.bytes_per_vector(),
                    hops: ctx.stats.hops,
                    filtered: ctx.stats.filtered,
                    deleted_skipped: 0,
                },
                ..SearchResult::default()
            };
        }
        let ids: Vec<u32> = cands[..take].iter().map(|c| c.id).collect();
        let stats = QueryStats {
            primary_scored: ctx.stats.scored,
            reranked: take,
            // rerank traffic uses rerank_bytes_per_vector: two-level
            // secondaries read their residual bytes during re-scoring
            bytes_touched: ctx.stats.scored * self.primary.bytes_per_vector()
                + take * self.secondary.rerank_bytes_per_vector(),
            hops: ctx.stats.hops,
            filtered: ctx.stats.filtered,
            deleted_skipped: 0,
        };
        // re-rank with secondary vectors in the original space
        let t_rerank = if telem {
            Some(std::time::Instant::now())
        } else {
            None
        };
        let (ids, scores) = self.rerank(query.vector(), &ids, k);
        if let Some(t) = t_rerank {
            crate::obs::handles()
                .index_rerank
                .record_seconds(t.elapsed().as_secs_f64());
        }
        SearchResult {
            ids,
            scores,
            stats,
            ..SearchResult::default()
        }
    }

    /// Re-score `ids` with the secondary store and return the top-k.
    /// Uses `score_rerank`, so a two-level secondary contributes its
    /// residual level here (full-accuracy re-ranking).
    pub fn rerank(&self, q: &[f32], ids: &[u32], k: usize) -> (Vec<u32>, Vec<f32>) {
        let scored = rerank_top_k(self.secondary.as_ref(), q, self.sim, ids, k);
        (
            scored.iter().map(|&(_, id)| id).collect(),
            scored.iter().map(|&(s, _)| s).collect(),
        )
    }

    /// Shared parallel fan-out for batch search: run `f(ctx, i)` for
    /// every index in `0..n` across `threads` workers (0 = all cores),
    /// each drawing a reusable [`SearchCtx`] from a pool — the same
    /// chunking discipline as the parallel builder. Used by the trait's
    /// batch path and the coordinator's direct batch path; results are
    /// in index order and identical for every thread count.
    pub(crate) fn batch_fan_out<T, F>(&self, n: usize, threads: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&mut SearchCtx, usize) -> T + Sync,
    {
        let threads = crate::util::threadpool::resolve_threads(threads);
        let pool = crate::graph::beam::CtxPool::new(threads, self.len());
        crate::util::threadpool::parallel_map(n, threads, |i| {
            let mut ctx = pool.acquire();
            f(&mut *ctx, i)
        })
    }

    /// Compression ratio of the primary representation vs FP16 full-D
    /// (the Fig. 1 headline number, e.g. 9.6x for rqa-768 at d=160).
    pub fn primary_compression_vs_fp16(&self) -> f64 {
        let full_fp16 = self.model.input_dim() * 2;
        full_fp16 as f64 / self.primary.bytes_per_vector() as f64
    }

    /// Is this index serving any arrays directly off a memory-mapped
    /// snapshot (see [`LeanVecIndex::load_mmap`])?
    pub fn is_mapped(&self) -> bool {
        self.backing.is_some()
    }

    /// Bytes of the snapshot file backing this index's mapped arrays
    /// (0 when not mapped). An upper bound on what the mapping can pin
    /// in page cache; the resident portion at any instant is whatever
    /// the kernel has kept.
    pub fn mapped_bytes(&self) -> usize {
        self.backing.as_ref().map(|m| m.len()).unwrap_or(0)
    }

    /// Ask the kernel to drop any resident pages of the backing mapping
    /// (`madvise(MADV_DONTNEED)`). Purely advisory and always safe —
    /// the mapping is a read-only file view, so dropped pages refault
    /// from disk on next touch. The memory-capped benchmark arm calls
    /// this between batches to emulate serving under page-cache
    /// pressure; a no-op for non-mapped indexes.
    pub fn evict_mapped(&self) {
        if let Some(m) = &self.backing {
            crate::obs::handles().mmap_evictions.inc();
            m.advise(crate::util::mmap::Advice::DontNeed);
            m.advise(crate::util::mmap::Advice::Random);
        }
    }
}

/// THE re-rank ordering rule: re-score `ids` against `store` in the
/// original space (`score_rerank`, so two-level stores contribute their
/// residual), NaN-safe descending sort, truncate to `k`. Returns
/// `(score, id)` pairs best first. One copy shared by the frozen index
/// and the live index ([`crate::mutate::LiveIndex`]) so their
/// tie-breaking can never drift apart.
pub(crate) fn rerank_top_k(
    store: &dyn ScoreStore,
    q: &[f32],
    sim: Similarity,
    ids: &[u32],
    k: usize,
) -> Vec<(f32, u32)> {
    let pq: PreparedQuery = store.prepare(q, sim);
    // one blocked call: the store's override runs the dispatched
    // kernels and prefetches upcoming rows (both levels for LVQ4x8)
    let mut scores: Vec<f32> = Vec::new();
    store.score_rerank_block(&pq, ids, &mut scores);
    debug_assert_eq!(scores.len(), ids.len(), "score_rerank_block contract");
    let mut scored: Vec<(f32, u32)> = scores
        .iter()
        .zip(ids.iter())
        .map(|(&s, &id)| (s, id))
        .collect();
    // total_cmp: a NaN score must never panic the serving thread
    scored.sort_by(|a, b| b.0.total_cmp(&a.0));
    scored.truncate(k);
    scored
}

impl VectorIndex for LeanVecIndex {
    /// Full query path: project once (`A q`), traverse, re-rank.
    fn search(&self, ctx: &mut SearchCtx, query: &Query) -> SearchResult {
        let q_proj = self.model.project_query(query.vector());
        self.search_prepared(ctx, &q_proj, query)
    }

    fn len(&self) -> usize {
        LeanVecIndex::len(self)
    }

    fn dim(&self) -> usize {
        self.model.input_dim()
    }

    fn sim(&self) -> Similarity {
        self.sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, ProjectionKind};
    use crate::index::builder::IndexBuilder;
    use crate::index::flat::FlatIndex;
    use crate::util::rng::Rng;

    /// low-rank data so a d=8 projection preserves structure
    fn lowrank_rows(n: usize, dd: usize, rank: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let basis: Vec<Vec<f32>> = (0..rank)
            .map(|_| (0..dd).map(|_| rng.gaussian_f32()).collect())
            .collect();
        (0..n)
            .map(|_| {
                let coef: Vec<f32> = (0..rank).map(|_| rng.gaussian_f32()).collect();
                let mut v = vec![0.0f32; dd];
                for (c, b) in coef.iter().zip(basis.iter()) {
                    for (x, &bv) in v.iter_mut().zip(b.iter()) {
                        *x += c * bv;
                    }
                }
                for x in v.iter_mut() {
                    *x += 0.01 * rng.gaussian_f32();
                }
                v
            })
            .collect()
    }

    fn build_small(rows: &[Vec<f32>], d: usize) -> LeanVecIndex {
        let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
        gp.max_degree = 16;
        gp.build_window = 40;
        IndexBuilder::new()
            .projection(ProjectionKind::Id)
            .target_dim(d)
            .graph_params(gp)
            .build(rows, None, Similarity::InnerProduct)
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn recall_with_rerank_beats_no_rerank() {
        let rows = lowrank_rows(500, 32, 6, 1);
        let index = build_small(&rows, 8);
        let flat = FlatIndex::new(&rows, Similarity::InnerProduct);
        let mut rng = Rng::new(42);
        let mut ctx = SearchCtx::new(rows.len());
        let trials = 30;
        let (mut hit_rr, mut hit_nr) = (0usize, 0usize);
        for _ in 0..trials {
            let q: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
            let (truth, _) = flat.search(&q, 10);
            let ids = index.search(&mut ctx, &Query::new(&q).k(10).window(50)).ids;
            hit_rr += truth.iter().filter(|t| ids.contains(t)).count();
            let ids_nr = index
                .search(&mut ctx, &Query::new(&q).k(10).window(50).no_rerank())
                .ids;
            hit_nr += truth.iter().filter(|t| ids_nr.contains(t)).count();
        }
        let (r_rr, r_nr) = (
            hit_rr as f64 / (trials * 10) as f64,
            hit_nr as f64 / (trials * 10) as f64,
        );
        assert!(r_rr >= r_nr - 0.02, "rerank {r_rr} vs none {r_nr}");
        assert!(r_rr >= 0.8, "rerank recall {r_rr}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn stats_populate() {
        let rows = lowrank_rows(200, 16, 4, 2);
        let index = build_small(&rows, 6);
        let mut ctx = SearchCtx::new(rows.len());
        let stats = index
            .search(&mut ctx, &Query::new(&rows[0]).k(5).window(20))
            .stats;
        assert!(stats.primary_scored > 0);
        assert!(stats.reranked > 0);
        assert!(stats.bytes_touched > 0);
        assert!(stats.hops > 0);
        assert_eq!(stats.filtered, 0, "no filter attached");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn bytes_touched_counts_residual_for_two_level_secondary() {
        let rows = lowrank_rows(200, 16, 4, 7);
        let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
        gp.max_degree = 16;
        gp.build_window = 40;
        let build = |secondary| {
            IndexBuilder::new()
                .projection(ProjectionKind::Id)
                .target_dim(6)
                .secondary(secondary)
                .graph_params(gp)
                .build(&rows, None, Similarity::InnerProduct)
        };
        let two_level = build(crate::config::Compression::Lvq4x8);
        let one_level = build(crate::config::Compression::Lvq4);
        let mut ctx = SearchCtx::new(rows.len());
        let q = Query::new(&rows[0]).k(5).window(20);
        let s2 = two_level.search(&mut ctx, &q).stats;
        let s1 = one_level.search(&mut ctx, &q).stats;
        // identical traversal-layer compression; the two-level secondary
        // must report strictly more rerank traffic (its residual bytes)
        assert_eq!(
            two_level.secondary.bytes_per_vector(),
            one_level.secondary.bytes_per_vector()
        );
        assert!(
            two_level.secondary.rerank_bytes_per_vector()
                > one_level.secondary.rerank_bytes_per_vector()
        );
        assert!(s2.reranked > 0 && s1.reranked > 0);
        // same primary store + seed -> identical traversal; the byte
        // accounting must therefore differ by exactly the rerank traffic
        assert!(s2.bytes_touched > s1.bytes_touched);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn search_batch_matches_sequential_search() {
        let rows = lowrank_rows(300, 16, 4, 8);
        let index = build_small(&rows, 6);
        let mut rng = Rng::new(31);
        let queries: Vec<Vec<f32>> = (0..24)
            .map(|_| (0..16).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let reqs: Vec<Query> = queries.iter().map(|q| Query::new(q).k(5).window(30)).collect();
        let mut ctx = SearchCtx::new(rows.len());
        let sequential: Vec<Vec<u32>> =
            reqs.iter().map(|q| index.search(&mut ctx, q).ids).collect();
        for threads in [1usize, 3] {
            let batched: Vec<Vec<u32>> = index
                .search_batch(&reqs, threads)
                .into_iter()
                .map(|r| r.ids)
                .collect();
            assert_eq!(batched, sequential, "threads {threads}");
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn compression_ratio_reported() {
        let rows = lowrank_rows(150, 32, 4, 3);
        let index = build_small(&rows, 8);
        // full fp16 = 64 B; primary lvq8 at d=8 = 8 + 8 = 16 B -> 4x
        assert!(index.primary_compression_vs_fp16() > 2.0);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn scores_descend() {
        let rows = lowrank_rows(150, 16, 4, 4);
        let index = build_small(&rows, 6);
        let scores = index.search_one(&Query::new(&rows[3]).k(10).window(30)).scores;
        for w in scores.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn split_buffer_retains_more_than_the_window() {
        let rows = lowrank_rows(400, 16, 4, 9);
        let index = build_small(&rows, 6);
        let mut ctx = SearchCtx::new(rows.len());
        // rerank_window 3x the traversal window: the buffer must retain
        // (and re-rank) more candidates than the window alone holds
        let wide = index
            .search(&mut ctx, &Query::new(&rows[0]).k(5).window(20).rerank_window(60))
            .stats;
        let narrow = index
            .search(&mut ctx, &Query::new(&rows[0]).k(5).window(20))
            .stats;
        assert!(wide.reranked > 20, "split buffer capped at window: {wide:?}");
        assert_eq!(narrow.reranked.min(20), narrow.reranked);
        assert!(wide.reranked > narrow.reranked);
    }
}
