//! IVF-PQ baseline (Jégou et al., 2011; FAISS-IVFPQfs stand-in):
//! k-means coarse quantizer + product quantization with an ADC
//! lookup table per query.
//!
//! Implemented exactly because the paper argues *against* it for graph
//! search: the LUT-gather access pattern is great for inverted lists
//! and poor for random access — Fig. 7 reproduces that comparison.

use crate::config::Similarity;
use crate::graph::beam::SearchCtx;
use crate::index::query::{Query, QueryStats, SearchResult, VectorIndex};
use crate::linalg::matrix::{dot, l2_sq};
use crate::util::rng::Rng;

/// `nprobe` used when a [`Query`] does not set one (via
/// [`Query::window`], which IVF-PQ reads as the probe count).
pub const DEFAULT_NPROBE: usize = 32;

#[derive(Clone, Copy, Debug)]
pub struct IvfPqParams {
    /// number of coarse (IVF) clusters
    pub nlist: usize,
    /// PQ subspaces
    pub m: usize,
    /// centroids per subspace (<= 256 so codes fit a byte)
    pub ksub: usize,
    /// k-means iterations
    pub kmeans_iters: usize,
}

impl Default for IvfPqParams {
    fn default() -> Self {
        IvfPqParams {
            nlist: 64,
            m: 8,
            ksub: 256,
            kmeans_iters: 10,
        }
    }
}

pub struct IvfPqIndex {
    params: IvfPqParams,
    sim: Similarity,
    dim: usize,
    dsub: usize,
    /// (nlist, dim) coarse centroids
    coarse: Vec<Vec<f32>>,
    /// inverted lists of database ids
    lists: Vec<Vec<u32>>,
    /// PQ codebooks: m * ksub * dsub (codebooks trained on residuals)
    codebooks: Vec<f32>,
    /// PQ codes per vector: n * m bytes (indexed by database id)
    codes: Vec<u8>,
    /// coarse assignment per vector
    assign: Vec<u32>,
    pub build_seconds: f64,
}

fn kmeans(rows: &[Vec<f32>], k: usize, iters: usize, seed: u64) -> Vec<Vec<f32>> {
    let n = rows.len();
    let dim = rows[0].len();
    let k = k.min(n);
    let mut rng = Rng::new(seed);
    // k-means++ seeding: first pick uniform, then proportional to the
    // squared distance from the nearest chosen centroid.
    let mut centroids: Vec<Vec<f32>> = Vec::with_capacity(k);
    centroids.push(rows[rng.below(n)].clone());
    let mut d2: Vec<f32> = rows.iter().map(|r| l2_sq(r, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().map(|&d| d as f64).sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.next_f64() * total;
            let mut idx = n - 1;
            for (i, &d) in d2.iter().enumerate() {
                target -= d as f64;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        let c = rows[pick].clone();
        centroids.push(c.clone());
        for (i, r) in rows.iter().enumerate() {
            let d = l2_sq(r, &c);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        // assignment
        for (i, r) in rows.iter().enumerate() {
            let mut best = (0usize, f32::INFINITY);
            for (c, cent) in centroids.iter().enumerate() {
                let d = l2_sq(r, cent);
                if d < best.1 {
                    best = (c, d);
                }
            }
            assign[i] = best.0;
        }
        // update
        let mut sums = vec![vec![0.0f64; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, r) in rows.iter().enumerate() {
            counts[assign[i]] += 1;
            for (s, &x) in sums[assign[i]].iter_mut().zip(r.iter()) {
                *s += x as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty cluster
                centroids[c] = rows[rng.below(n)].clone();
            } else {
                for (j, s) in sums[c].iter().enumerate() {
                    centroids[c][j] = (*s / counts[c] as f64) as f32;
                }
            }
        }
    }
    centroids
}

impl IvfPqIndex {
    pub fn build(rows: &[Vec<f32>], params: IvfPqParams, sim: Similarity, seed: u64) -> IvfPqIndex {
        let t0 = std::time::Instant::now();
        let n = rows.len();
        let dim = rows[0].len();
        assert!(dim % params.m == 0, "dim {dim} not divisible by m {}", params.m);
        let dsub = dim / params.m;
        let ksub = params.ksub.min(256).min(n);

        // --- coarse quantizer
        let train_n = n.min(10_000);
        let coarse = kmeans(&rows[..train_n], params.nlist, params.kmeans_iters, seed);
        let nlist = coarse.len();
        let mut lists: Vec<Vec<u32>> = vec![Vec::new(); nlist];
        let mut assign = vec![0u32; n];
        let mut residuals: Vec<Vec<f32>> = Vec::with_capacity(n);
        for (i, r) in rows.iter().enumerate() {
            let mut best = (0usize, f32::INFINITY);
            for (c, cent) in coarse.iter().enumerate() {
                let d = l2_sq(r, cent);
                if d < best.1 {
                    best = (c, d);
                }
            }
            assign[i] = best.0 as u32;
            lists[best.0].push(i as u32);
            residuals.push(
                r.iter()
                    .zip(coarse[best.0].iter())
                    .map(|(x, c)| x - c)
                    .collect(),
            );
        }

        // --- PQ codebooks on residual subspaces
        let mut codebooks = vec![0.0f32; params.m * ksub * dsub];
        let sub_train = residuals.len().min(5_000);
        for sub in 0..params.m {
            let sub_rows: Vec<Vec<f32>> = residuals[..sub_train]
                .iter()
                .map(|r| r[sub * dsub..(sub + 1) * dsub].to_vec())
                .collect();
            let cents = kmeans(&sub_rows, ksub, params.kmeans_iters, seed ^ sub as u64);
            for (c, cent) in cents.iter().enumerate() {
                let off = (sub * ksub + c) * dsub;
                codebooks[off..off + dsub].copy_from_slice(cent);
            }
        }

        // --- encode all vectors
        let mut codes = vec![0u8; n * params.m];
        for (i, r) in residuals.iter().enumerate() {
            for sub in 0..params.m {
                let seg = &r[sub * dsub..(sub + 1) * dsub];
                let mut best = (0usize, f32::INFINITY);
                for c in 0..ksub {
                    let off = (sub * ksub + c) * dsub;
                    let d = l2_sq(seg, &codebooks[off..off + dsub]);
                    if d < best.1 {
                        best = (c, d);
                    }
                }
                codes[i * params.m + sub] = best.0 as u8;
            }
        }

        IvfPqIndex {
            params: IvfPqParams { ksub, ..params },
            sim,
            dim,
            dsub,
            coarse,
            lists,
            codebooks,
            codes,
            assign,
            build_seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// ADC search probing `nprobe` coarse lists — shorthand for the
    /// [`VectorIndex`] trait call with `window == nprobe`. Returns
    /// (ids, scores) best-first with "bigger is better" scores.
    pub fn search(&self, q: &[f32], k: usize, nprobe: usize) -> (Vec<u32>, Vec<f32>) {
        let r = VectorIndex::search(
            self,
            &mut SearchCtx::new(0),
            &Query::new(q).k(k).window(nprobe.max(1)),
        );
        (r.ids, r.scores)
    }

    pub fn len(&self) -> usize {
        self.assign.len()
    }

    pub fn is_empty(&self) -> bool {
        self.assign.is_empty()
    }

    /// bytes touched per scanned vector (PQ codes only)
    pub fn bytes_per_vector(&self) -> usize {
        self.params.m
    }
}

impl VectorIndex for IvfPqIndex {
    /// ADC search; [`Query::window`] is read as `nprobe` (defaulting to
    /// [`DEFAULT_NPROBE`], clamped to `nlist`). Filtered-out ids are
    /// skipped before the LUT gather, never scored, never returned.
    fn search(&self, _ctx: &mut SearchCtx, query: &Query) -> SearchResult {
        let q = query.vector();
        assert_eq!(q.len(), self.dim);
        let k = query.top_k();
        let nprobe = query
            .window_override()
            .unwrap_or(DEFAULT_NPROBE)
            .clamp(1, self.coarse.len());
        let filter = query.filter_fn();
        // rank coarse cells
        let mut cells: Vec<(f32, usize)> = self
            .coarse
            .iter()
            .enumerate()
            .map(|(c, cent)| {
                let s = match self.sim {
                    Similarity::L2 | Similarity::Cosine => -l2_sq(q, cent),
                    Similarity::InnerProduct => dot(q, cent),
                };
                (s, c)
            })
            .collect();
        cells.sort_by(|a, b| b.0.total_cmp(&a.0));

        let m = self.params.m;
        let ksub = self.params.ksub;
        let mut top: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
        let mut lut = vec![0.0f32; m * ksub];
        let mut filtered = 0usize;
        let mut scored = 0usize;
        for &(_, cell) in cells.iter().take(nprobe) {
            // Build the ADC LUT for this cell: per subspace, the score
            // contribution of each codebook centroid.
            let cent = &self.coarse[cell];
            match self.sim {
                Similarity::L2 | Similarity::Cosine => {
                    // score = -||q - (cent + cb)||^2 accumulated per subspace
                    for sub in 0..m {
                        let qs = &q[sub * self.dsub..(sub + 1) * self.dsub];
                        let cs = &cent[sub * self.dsub..(sub + 1) * self.dsub];
                        for c in 0..ksub {
                            let off = (sub * ksub + c) * self.dsub;
                            let cb = &self.codebooks[off..off + self.dsub];
                            let mut acc = 0.0f32;
                            for j in 0..self.dsub {
                                let diff = qs[j] - (cs[j] + cb[j]);
                                acc += diff * diff;
                            }
                            lut[sub * ksub + c] = -acc;
                        }
                    }
                }
                Similarity::InnerProduct => {
                    for sub in 0..m {
                        let qs = &q[sub * self.dsub..(sub + 1) * self.dsub];
                        let cs = &cent[sub * self.dsub..(sub + 1) * self.dsub];
                        let q_cent = dot(qs, cs);
                        for c in 0..ksub {
                            let off = (sub * ksub + c) * self.dsub;
                            let cb = &self.codebooks[off..off + self.dsub];
                            lut[sub * ksub + c] = q_cent + dot(qs, cb);
                        }
                    }
                }
            }
            // scan the list with LUT gathers, prefetching the next
            // entry's code row (inverted lists gather codes at random
            // row offsets — the prefetch hides that latency)
            let list = &self.lists[cell];
            for (j, &id) in list.iter().enumerate() {
                if let Some(&next) = list.get(j + 1) {
                    crate::simd::prefetch(&self.codes[next as usize * m..]);
                }
                if let Some(f) = filter {
                    if !f(id) {
                        filtered += 1;
                        continue;
                    }
                }
                let code = &self.codes[id as usize * m..id as usize * m + m];
                let mut s = 0.0f32;
                for (sub, &c) in code.iter().enumerate() {
                    s += lut[sub * ksub + c as usize];
                }
                scored += 1;
                if top.len() < k {
                    top.push((s, id));
                    if top.len() == k {
                        // total_cmp: a NaN score must never panic mid-serve
                        top.sort_by(|a, b| b.0.total_cmp(&a.0));
                    }
                } else if k > 0 && s > top[k - 1].0 {
                    top[k - 1] = (s, id);
                    let mut i = k - 1;
                    while i > 0 && top[i].0 > top[i - 1].0 {
                        top.swap(i, i - 1);
                        i -= 1;
                    }
                }
            }
        }
        if top.len() < k {
            top.sort_by(|a, b| b.0.total_cmp(&a.0));
        }
        SearchResult {
            ids: top.iter().map(|&(_, id)| id).collect(),
            scores: top.iter().map(|&(s, _)| s).collect(),
            stats: QueryStats {
                primary_scored: scored,
                reranked: 0,
                bytes_touched: scored * self.params.m,
                hops: nprobe,
                filtered,
                deleted_skipped: 0,
            },
            ..SearchResult::default()
        }
    }

    fn len(&self) -> usize {
        self.assign.len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn sim(&self) -> Similarity {
        self.sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clustered_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..d).map(|_| rng.gaussian_f32() * 3.0).collect())
            .collect();
        (0..n)
            .map(|i| {
                centers[i % 8]
                    .iter()
                    .map(|&x| x + rng.gaussian_f32() * 0.4)
                    .collect()
            })
            .collect()
    }

    fn recall_at_10(index: &IvfPqIndex, rows: &[Vec<f32>], sim: Similarity, nprobe: usize) -> f64 {
        let mut rng = Rng::new(123);
        let trials = 25;
        let mut hits = 0usize;
        for _ in 0..trials {
            let q: Vec<f32> = rows[rng.below(rows.len())]
                .iter()
                .map(|&x| x + rng.gaussian_f32() * 0.05)
                .collect();
            let mut truth: Vec<u32> = (0..rows.len() as u32).collect();
            truth.sort_by(|&a, &b| {
                let (sa, sb) = match sim {
                    Similarity::L2 | Similarity::Cosine => {
                        (-l2_sq(&q, &rows[a as usize]), -l2_sq(&q, &rows[b as usize]))
                    }
                    Similarity::InnerProduct => {
                        (dot(&q, &rows[a as usize]), dot(&q, &rows[b as usize]))
                    }
                };
                sb.total_cmp(&sa)
            });
            let (ids, _) = index.search(&q, 10, nprobe);
            hits += truth[..10].iter().filter(|t| ids.contains(t)).count();
        }
        hits as f64 / (10 * trials) as f64
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn kmeans_reduces_distortion() {
        let rows = clustered_rows(200, 8, 1);
        let cents = kmeans(&rows, 8, 12, 7);
        // mean distance to nearest centroid must be << data scale
        let mean_d: f32 = rows
            .iter()
            .map(|r| {
                cents
                    .iter()
                    .map(|c| l2_sq(r, c))
                    .fold(f32::INFINITY, f32::min)
            })
            .sum::<f32>()
            / rows.len() as f32;
        // within-cluster expectation is 8 dims * 0.4^2 = 1.28; allow 3x
        assert!(mean_d < 4.0, "{mean_d}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn recall_reasonable_l2() {
        let rows = clustered_rows(600, 16, 2);
        let idx = IvfPqIndex::build(
            &rows,
            IvfPqParams {
                nlist: 16,
                m: 4,
                ksub: 64,
                kmeans_iters: 8,
            },
            Similarity::L2,
            3,
        );
        let r = recall_at_10(&idx, &rows, Similarity::L2, 8);
        assert!(r >= 0.6, "recall {r}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn more_probes_more_recall() {
        let rows = clustered_rows(600, 16, 4);
        let idx = IvfPqIndex::build(
            &rows,
            IvfPqParams {
                nlist: 32,
                m: 4,
                ksub: 64,
                kmeans_iters: 8,
            },
            Similarity::L2,
            5,
        );
        let r1 = recall_at_10(&idx, &rows, Similarity::L2, 1);
        let r16 = recall_at_10(&idx, &rows, Similarity::L2, 16);
        assert!(r16 >= r1, "{r16} vs {r1}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn ip_search_runs() {
        let rows = clustered_rows(300, 8, 6);
        let idx = IvfPqIndex::build(
            &rows,
            IvfPqParams {
                nlist: 8,
                m: 2,
                ksub: 32,
                kmeans_iters: 5,
            },
            Similarity::InnerProduct,
            7,
        );
        let r = recall_at_10(&idx, &rows, Similarity::InnerProduct, 8);
        assert!(r >= 0.5, "recall {r}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn every_vector_in_exactly_one_list() {
        let rows = clustered_rows(200, 8, 8);
        let idx = IvfPqIndex::build(&rows, IvfPqParams::default(), Similarity::L2, 9);
        let total: usize = idx.lists.iter().map(|l| l.len()).sum();
        assert_eq!(total, 200);
        let mut seen = vec![false; 200];
        for l in &idx.lists {
            for &id in l {
                assert!(!seen[id as usize]);
                seen[id as usize] = true;
            }
        }
    }
}
