//! PJRT runtime: load + execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` lowers the Layer-1/2 computations to HLO *text*
//! (xla_extension 0.5.1 rejects jax>=0.5 serialized protos — the text
//! parser reassigns instruction ids; see aot.py). This module:
//!
//! * parses `artifacts/manifest.json` ([`artifacts`]),
//! * compiles artifacts on demand through the PJRT CPU client and
//!   caches the executables ([`client`]),
//! * exposes typed executors that plug into the training/build
//!   backends: [`executor::PjrtFwStepper`] (Algorithm 1 step),
//!   [`executor::PjrtTopd`] (Algorithm 2 eigenbasis),
//!   [`executor::PjrtProjector`] (batch `P X`), and
//!   [`executor::PjrtScorer`] (fused LVQ scoring — bench comparison).
//!
//! Python never runs here: the artifacts are self-contained HLO.

pub mod artifacts;
pub mod client;
pub mod executor;

pub use artifacts::{ArtifactSpec, Manifest};
pub use client::PjrtRuntime;
pub use executor::{PjrtFwStepper, PjrtProjector, PjrtScorer, PjrtTopd};

/// Default artifacts directory relative to the repo root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var("LEANVEC_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
