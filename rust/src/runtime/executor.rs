//! Typed executors bridging the PJRT artifacts into the training/build
//! backends. Every executor transparently falls back to the native
//! implementation when the (D, d) shape has no artifact — the build
//! never fails because a shape was not AOT-lowered, it just runs native.

use super::client::{
    f32_from_lit, lit_from_f32s, lit_from_matrix, lit_from_u8, matrix_from_lit, PjrtRuntime,
};
use crate::index::builder::{BatchProjector, NativeProjector};
use crate::leanvec::eigsearch::{NativeTopd, TopdBackend};
use crate::leanvec::fw::{FwStepper, NativeStepper};
use crate::linalg::Matrix;
use crate::util::rng::Rng;
use std::cell::RefCell;
use std::rc::Rc;

/// Shared handle: several executors borrow one runtime.
pub type SharedRuntime = Rc<RefCell<PjrtRuntime>>;

/// Count of PJRT-vs-native dispatches (observability).
#[derive(Clone, Copy, Debug, Default)]
pub struct DispatchStats {
    pub pjrt: usize,
    pub native: usize,
}

// ---------------------------------------------------------------- FW stepper

/// Algorithm-1 BCD step through the `fw_step_D*_d*` artifact.
pub struct PjrtFwStepper {
    rt: SharedRuntime,
    fallback: NativeStepper,
    pub stats: DispatchStats,
}

impl PjrtFwStepper {
    pub fn new(rt: SharedRuntime) -> PjrtFwStepper {
        PjrtFwStepper {
            rt,
            fallback: NativeStepper,
            stats: DispatchStats::default(),
        }
    }

    fn try_pjrt(
        &mut self,
        a: &Matrix,
        b: &Matrix,
        kq: &Matrix,
        kx: &Matrix,
        gamma: f32,
    ) -> anyhow::Result<(Matrix, Matrix, f64)> {
        let (d, dd) = (a.rows, a.cols);
        // prefer the fused jnp lowering on CPU; the pallas lowering is
        // the TPU kernel (interpret HLO — slow here, same numerics)
        let name = {
            let rt = self.rt.borrow();
            if rt.supports("fw_step_xla", dd, d) {
                format!("fw_step_xla_D{dd}_d{d}")
            } else {
                format!("fw_step_D{dd}_d{d}")
            }
        };
        let inputs = vec![
            lit_from_matrix(a)?,
            lit_from_matrix(b)?,
            lit_from_matrix(kq)?,
            lit_from_matrix(kx)?,
            lit_from_f32s(&[gamma])?,
        ];
        let mut rt = self.rt.borrow_mut();
        let out = rt.execute(&name, &inputs)?;
        anyhow::ensure!(out.len() == 3, "fw_step returned {} outputs", out.len());
        let a1 = matrix_from_lit(&out[0], d, dd)?;
        let b1 = matrix_from_lit(&out[1], d, dd)?;
        // artifact reports the loss *without* the constant Tr(Kq Kx)
        // term; add it so callers see the Eq.-8 absolute loss matching
        // the native stepper.
        let partial = f32_from_lit(&out[2])? as f64;
        let constant: f64 = kq
            .data
            .iter()
            .zip(kx.transpose().data.iter())
            .map(|(x, y)| *x as f64 * *y as f64)
            .sum();
        Ok((a1, b1, partial + constant))
    }
}

impl FwStepper for PjrtFwStepper {
    fn step(
        &mut self,
        a: &Matrix,
        b: &Matrix,
        kq: &Matrix,
        kx: &Matrix,
        gamma: f32,
    ) -> (Matrix, Matrix, f64) {
        let supported = {
            let rt = self.rt.borrow();
            rt.supports("fw_step", a.cols, a.rows)
        };
        if supported {
            match self.try_pjrt(a, b, kq, kx, gamma) {
                Ok(r) => {
                    self.stats.pjrt += 1;
                    return r;
                }
                Err(e) => eprintln!("pjrt fw_step failed ({e}); falling back to native"),
            }
        }
        self.stats.native += 1;
        self.fallback.step(a, b, kq, kx, gamma)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// ---------------------------------------------------------------- top-d eigenbasis

/// Algorithm-2 eigenbasis through the `eig_topd_D*_d*` artifact
/// (orthogonal iteration with Newton-Schulz orthonormalization).
pub struct PjrtTopd {
    rt: SharedRuntime,
    fallback: NativeTopd,
    rng: Rng,
    pub stats: DispatchStats,
}

impl PjrtTopd {
    pub fn new(rt: SharedRuntime) -> PjrtTopd {
        PjrtTopd {
            rt,
            fallback: NativeTopd,
            rng: Rng::new(0xE16),
            stats: DispatchStats::default(),
        }
    }

    fn try_pjrt(&mut self, k: &Matrix, d: usize) -> anyhow::Result<Matrix> {
        let dd = k.rows;
        let name = {
            let rt = self.rt.borrow();
            if rt.supports("eig_topd_xla", dd, d) {
                format!("eig_topd_xla_D{dd}_d{d}")
            } else {
                format!("eig_topd_D{dd}_d{d}")
            }
        };
        let v0 = Matrix::randn(dd, d, &mut self.rng);
        let inputs = vec![lit_from_matrix(k)?, lit_from_matrix(&v0)?];
        let out = {
            let mut rt = self.rt.borrow_mut();
            rt.execute(&name, &inputs)?
        };
        let p = matrix_from_lit(&out[0], d, dd)?;
        // The artifact orthonormalizes with Newton-Schulz (matmul-only,
        // LAPACK-free HLO); under strong spectral decay the iterate can
        // carry residual non-orthogonality. One exact QR pass here
        // restores St(D, d) without changing the captured row space.
        Ok(crate::linalg::qr::qr_orthonormal_columns(&p.transpose()).transpose())
    }
}

impl TopdBackend for PjrtTopd {
    fn topd(&mut self, k: &Matrix, d: usize) -> Matrix {
        // Same policy as NativeTopd: subspace iteration (what the
        // artifact implements) is only well-conditioned for d << D;
        // at aggressive ratios the Jacobi fallback is the right tool.
        let supported = d * 3 <= k.rows && {
            let rt = self.rt.borrow();
            rt.supports("eig_topd", k.rows, d)
        };
        if supported {
            match self.try_pjrt(k, d) {
                Ok(p) => {
                    self.stats.pjrt += 1;
                    return p;
                }
                Err(e) => eprintln!("pjrt eig_topd failed ({e}); falling back to native"),
            }
        }
        self.stats.native += 1;
        self.fallback.topd(k, d)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// ---------------------------------------------------------------- batch projector

/// Batched `Y = P X` through the `project_db_D*_d*` artifact. Rows are
/// packed column-wise into the artifact's fixed batch width; the tail
/// batch is zero-padded (exact for matmul).
pub struct PjrtProjector {
    rt: SharedRuntime,
    fallback: NativeProjector,
    pub stats: DispatchStats,
}

impl PjrtProjector {
    pub fn new(rt: SharedRuntime) -> PjrtProjector {
        PjrtProjector {
            rt,
            fallback: NativeProjector,
            stats: DispatchStats::default(),
        }
    }

    fn try_pjrt(&mut self, p: &Matrix, rows: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        let (d, dd) = (p.rows, p.cols);
        let name = format!("project_db_D{dd}_d{d}");
        let batch = {
            let rt = self.rt.borrow();
            rt.spec("project", dd, d)
                .and_then(|s| s.batch)
                .ok_or_else(|| anyhow::anyhow!("no project artifact"))?
        };
        let mut out_rows: Vec<Vec<f32>> = Vec::with_capacity(rows.len());
        let mut chunk = Matrix::zeros(dd, batch);
        let mut start = 0usize;
        while start < rows.len() {
            let take = (rows.len() - start).min(batch);
            chunk.data.iter_mut().for_each(|v| *v = 0.0);
            // columns are vectors: chunk[:, j] = rows[start + j]
            for j in 0..take {
                let r = &rows[start + j];
                for i in 0..dd {
                    chunk.data[i * batch + j] = r[i];
                }
            }
            // the xla Literal is not Clone; re-creating the (cheap)
            // projection literal per batch keeps the loop simple
            let x_lit = lit_from_matrix(&chunk)?;
            let out = {
                let mut rt = self.rt.borrow_mut();
                rt.execute(&name, &[lit_from_matrix(p)?, x_lit])?
            };
            let y = matrix_from_lit(&out[0], d, batch)?;
            for j in 0..take {
                out_rows.push((0..d).map(|i| y.data[i * batch + j]).collect());
            }
            start += take;
        }
        Ok(out_rows)
    }
}

impl BatchProjector for PjrtProjector {
    fn project(&mut self, p: &Matrix, rows: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let supported = {
            let rt = self.rt.borrow();
            rt.supports("project", p.cols, p.rows)
        };
        if supported {
            match self.try_pjrt(p, rows) {
                Ok(r) => {
                    self.stats.pjrt += 1;
                    return r;
                }
                Err(e) => eprintln!("pjrt project failed ({e}); falling back to native"),
            }
        }
        self.stats.native += 1;
        self.fallback.project(p, rows)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// ---------------------------------------------------------------- fused scorer

/// Fused LVQ dequant+dot scoring through the `score_D*_d*` artifact —
/// the Pallas `lvq_dot` kernel executing via PJRT. Used by the runtime
/// bench to compare against the native fused loop (the native loop wins
/// at per-vector granularity, which is *why* L3 keeps scoring native;
/// this executor proves the kernel runs end-to-end from rust).
pub struct PjrtScorer {
    rt: SharedRuntime,
}

impl PjrtScorer {
    pub fn new(rt: SharedRuntime) -> PjrtScorer {
        PjrtScorer { rt }
    }

    /// Score a block of LVQ8 codes against one query.
    /// `codes`: (n, d) u8 row-major with n == artifact batch; `qstats` =
    /// [sum(q), <q, mu>].
    pub fn score_block(
        &mut self,
        big_d: usize,
        codes: &[u8],
        n: usize,
        d: usize,
        delta: &[f32],
        lo: &[f32],
        q: &[f32],
        qstats: [f32; 2],
    ) -> anyhow::Result<Vec<f32>> {
        let name = format!("score_D{big_d}_d{d}");
        let q_col = Matrix::from_vec(d, 1, q.to_vec());
        let inputs = vec![
            lit_from_u8(n, d, codes)?,
            lit_from_f32s(delta)?,
            lit_from_f32s(lo)?,
            lit_from_matrix(&q_col)?,
            lit_from_f32s(&qstats)?,
        ];
        let mut rt = self.rt.borrow_mut();
        let out = rt.execute(&name, &inputs)?;
        out[0]
            .to_vec()
            .map_err(|e| anyhow::anyhow!("score output: {e:?}"))
    }
}

/// Open the default runtime, shared-handle style.
pub fn open_shared(dir: &std::path::Path) -> anyhow::Result<SharedRuntime> {
    Ok(Rc::new(RefCell::new(PjrtRuntime::open(dir)?)))
}
