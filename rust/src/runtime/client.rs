//! PJRT client wrapper: compile HLO-text artifacts once, execute many.

use super::artifacts::{ArtifactSpec, Dtype, Manifest};
use crate::linalg::Matrix;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A live PJRT CPU client plus the compiled-executable cache.
///
/// Not `Send`: the underlying handles are raw pointers. Ownership lives
/// on whichever thread does training/build/batched projection (the
/// coordinator keeps it on the batcher thread).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative executions per artifact (observability/benches)
    pub dispatch_counts: HashMap<String, usize>,
}

impl PjrtRuntime {
    /// Open the CPU PJRT client and read the manifest in `dir`.
    pub fn open(dir: &Path) -> Result<PjrtRuntime> {
        let manifest =
            Manifest::load(dir).with_context(|| format!("loading manifest from {dir:?}"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            manifest,
            cache: HashMap::new(),
            dispatch_counts: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch the cached) executable for `name`.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self
                .manifest
                .by_name(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {:?}: {e:?}", spec.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    /// Execute an artifact with the given input literals. Returns the
    /// decomposed output tuple (aot.py lowers with return_tuple=True).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "artifact '{name}' wants {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        *self.dispatch_counts.entry(name.to_string()).or_insert(0) += 1;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Is this (fn, D, d) combination available?
    pub fn supports(&self, fn_name: &str, big_d: usize, small_d: usize) -> bool {
        self.manifest.find(fn_name, big_d, small_d).is_some()
    }

    pub fn spec(&self, fn_name: &str, big_d: usize, small_d: usize) -> Option<&ArtifactSpec> {
        self.manifest.find(fn_name, big_d, small_d)
    }
}

// ---------------------------------------------------------------- literal <-> native

/// f32 matrix (row-major) -> 2-D literal.
pub fn lit_from_matrix(m: &Matrix) -> Result<xla::Literal> {
    // SAFETY: viewing an f32 slice as bytes: the pointer is valid for
    // `len * 4` bytes (size_of::<f32>() == 4), u8 has alignment 1, and
    // the borrow of `m` outlives `bytes`, which is consumed before
    // return. Every f32 bit pattern is a valid byte sequence.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(m.data.as_ptr() as *const u8, m.data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &[m.rows, m.cols],
        bytes,
    )
    .map_err(|e| anyhow!("f32 literal: {e:?}"))
}

/// f32 slice -> 1-D literal.
pub fn lit_from_f32s(v: &[f32]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        // SAFETY: same argument as `lit_from_matrix` — an f32 slice
        // viewed as `len * 4` bytes, alignment-1 target, borrow
        // consumed before return.
        unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &[v.len()], bytes)
        .map_err(|e| anyhow!("f32 vec literal: {e:?}"))
}

/// u8 codes -> 2-D literal.
pub fn lit_from_u8(rows: usize, cols: usize, data: &[u8]) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, &[rows, cols], data)
        .map_err(|e| anyhow!("u8 literal: {e:?}"))
}

/// literal -> f32 matrix with the given shape.
pub fn matrix_from_lit(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    if v.len() != rows * cols {
        return Err(anyhow!(
            "literal has {} elements, expected {rows}x{cols}",
            v.len()
        ));
    }
    Ok(Matrix::from_vec(rows, cols, v))
}

/// scalar f32 from a rank-0/1 literal.
pub fn f32_from_lit(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar literal: {e:?}"))
}

/// Validate inputs against a spec (defensive: shape bugs surface as
/// clear errors instead of PJRT aborts).
pub fn check_shapes(spec: &ArtifactSpec, inputs: &[xla::Literal]) -> Result<()> {
    for (i, (lit, ts)) in inputs.iter().zip(spec.inputs.iter()).enumerate() {
        let n: usize = ts.shape.iter().product();
        if lit.element_count() != n.max(1) {
            return Err(anyhow!(
                "input {i} of {} has {} elements, expected {:?}",
                spec.name,
                lit.element_count(),
                ts.shape
            ));
        }
        let want = match ts.dtype {
            Dtype::F32 => xla::ElementType::F32,
            Dtype::U8 => xla::ElementType::U8,
        };
        let got = lit.ty().map_err(|e| anyhow!("{e:?}"))?;
        if got != want {
            return Err(anyhow!("input {i} of {}: dtype mismatch", spec.name));
        }
    }
    Ok(())
}
