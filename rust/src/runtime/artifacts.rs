//! Artifact manifest parsing (`artifacts/manifest.json`).

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Tensor dtype in the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U8,
}

impl Dtype {
    fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f32" => Some(Dtype::F32),
            "u8" => Some(Dtype::U8),
            _ => None,
        }
    }
}

/// One input/output tensor description.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    /// logical function: fw_step | eig_topd | project | score_batch
    pub fn_name: String,
    pub big_d: usize,
    pub small_d: usize,
    pub batch: Option<usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> std::io::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Manifest::parse(&text, dir).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, e)
        })
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or("manifest missing 'artifacts'")?;
        let tensor = |j: &Json| -> Result<TensorSpec, String> {
            let shape = j
                .get("shape")
                .and_then(|s| s.as_arr())
                .ok_or("tensor missing shape")?
                .iter()
                .map(|v| v.as_usize().ok_or("bad dim"))
                .collect::<Result<Vec<_>, _>>()?;
            let dtype = j
                .get("dtype")
                .and_then(|d| d.as_str())
                .and_then(Dtype::parse)
                .ok_or("bad dtype")?;
            Ok(TensorSpec { shape, dtype })
        };
        let mut artifacts = Vec::new();
        for a in arts {
            let get_str = |k: &str| a.get(k).and_then(|v| v.as_str()).map(str::to_string);
            let name = get_str("name").ok_or("artifact missing name")?;
            let file = dir.join(get_str("file").ok_or("artifact missing file")?);
            let fn_name = get_str("fn").ok_or("artifact missing fn")?;
            let big_d = a.get("D").and_then(|v| v.as_usize()).ok_or("missing D")?;
            let small_d = a.get("d").and_then(|v| v.as_usize()).ok_or("missing d")?;
            let batch = a.get("batch").and_then(|v| v.as_usize());
            let inputs = a
                .get("inputs")
                .and_then(|v| v.as_arr())
                .ok_or("missing inputs")?
                .iter()
                .map(tensor)
                .collect::<Result<Vec<_>, _>>()?;
            let outputs = a
                .get("outputs")
                .and_then(|v| v.as_arr())
                .ok_or("missing outputs")?
                .iter()
                .map(tensor)
                .collect::<Result<Vec<_>, _>>()?;
            artifacts.push(ArtifactSpec {
                name,
                file,
                fn_name,
                big_d,
                small_d,
                batch,
                inputs,
                outputs,
            });
        }
        Ok(Manifest { artifacts })
    }

    /// Find an artifact by logical function + projection shape.
    pub fn find(&self, fn_name: &str, big_d: usize, small_d: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.fn_name == fn_name && a.big_d == big_d && a.small_d == small_d)
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "artifacts": [
        {"name": "fw_step_D64_d16", "file": "fw_step_D64_d16.hlo.txt",
         "fn": "fw_step", "D": 64, "d": 16,
         "inputs": [{"shape": [16,64], "dtype": "f32"},
                    {"shape": [16,64], "dtype": "f32"},
                    {"shape": [64,64], "dtype": "f32"},
                    {"shape": [64,64], "dtype": "f32"},
                    {"shape": [1], "dtype": "f32"}],
         "outputs": [{"shape": [16,64], "dtype": "f32"},
                     {"shape": [16,64], "dtype": "f32"},
                     {"shape": [], "dtype": "f32"}]},
        {"name": "score_D64_d16", "file": "score_D64_d16.hlo.txt",
         "fn": "score_batch", "D": 64, "d": 16, "batch": 1024,
         "inputs": [{"shape": [1024,16], "dtype": "u8"}],
         "outputs": [{"shape": [1024], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let fw = m.find("fw_step", 64, 16).unwrap();
        assert_eq!(fw.inputs.len(), 5);
        assert_eq!(fw.outputs[2].shape.len(), 0); // scalar loss
        assert_eq!(fw.file, Path::new("/tmp/a/fw_step_D64_d16.hlo.txt"));
        let sc = m.by_name("score_D64_d16").unwrap();
        assert_eq!(sc.batch, Some(1024));
        assert_eq!(sc.inputs[0].dtype, Dtype::U8);
    }

    #[test]
    fn find_misses_gracefully() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert!(m.find("fw_step", 128, 16).is_none());
        assert!(m.by_name("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("[1,2]", Path::new("/tmp")).is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // integration-lite: when `make artifacts` has run, the real
        // manifest must parse and contain the default shape set
        let dir = crate::runtime::default_artifacts_dir();
        if let Ok(m) = Manifest::load(&dir) {
            assert!(m.find("fw_step", 768, 160).is_some());
            assert!(m.find("project", 768, 160).is_some());
        }
    }
}
