//! Shared configuration types + JSON-backed experiment configs.

use crate::util::json::Json;

/// Similarity function. Maximum-inner-product is the native metric
/// (Section 2); Euclidean and cosine are mapped onto it:
/// * Cosine: vectors are L2-normalized at ingestion, then IP == cosine.
/// * L2: ranking by `-||q - x||^2 = 2<q,x> - ||x||^2 - ||q||^2`, so a
///   store only needs `<q,x>` plus per-vector squared norms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Similarity {
    InnerProduct,
    L2,
    Cosine,
}

impl Similarity {
    pub fn parse(s: &str) -> Option<Similarity> {
        match s.to_ascii_lowercase().as_str() {
            "ip" | "inner_product" | "innerproduct" | "mips" => Some(Similarity::InnerProduct),
            "l2" | "euclidean" => Some(Similarity::L2),
            "cos" | "cosine" => Some(Similarity::Cosine),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Similarity::InnerProduct => "inner_product",
            Similarity::L2 => "l2",
            Similarity::Cosine => "cosine",
        }
    }

    /// Stable one-byte wire code used by the snapshot format
    /// (`docs/SNAPSHOT_FORMAT.md`). Never renumber existing variants.
    pub fn code(&self) -> u8 {
        match self {
            Similarity::InnerProduct => 0,
            Similarity::L2 => 1,
            Similarity::Cosine => 2,
        }
    }

    /// Inverse of [`Similarity::code`].
    pub fn from_code(c: u8) -> Option<Similarity> {
        match c {
            0 => Some(Similarity::InnerProduct),
            1 => Some(Similarity::L2),
            2 => Some(Similarity::Cosine),
            _ => None,
        }
    }
}

/// Quantization scheme for a vector store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compression {
    /// 32-bit float (uncompressed reference)
    F32,
    /// 16-bit float (the paper's FP16 baseline / secondary default)
    F16,
    /// LVQ with 8 bits per component
    Lvq8,
    /// LVQ with 4 bits per component
    Lvq4,
    /// two-level LVQ: 4-bit primary + 8-bit residual
    Lvq4x8,
}

impl Compression {
    pub fn parse(s: &str) -> Option<Compression> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Some(Compression::F32),
            "f16" | "fp16" => Some(Compression::F16),
            "lvq8" => Some(Compression::Lvq8),
            "lvq4" => Some(Compression::Lvq4),
            "lvq4x8" => Some(Compression::Lvq4x8),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Compression::F32 => "f32",
            Compression::F16 => "f16",
            Compression::Lvq8 => "lvq8",
            Compression::Lvq4 => "lvq4",
            Compression::Lvq4x8 => "lvq4x8",
        }
    }

    /// Stable one-byte wire code used by the snapshot format
    /// (`docs/SNAPSHOT_FORMAT.md`). Never renumber existing variants.
    pub fn code(&self) -> u8 {
        match self {
            Compression::F32 => 0,
            Compression::F16 => 1,
            Compression::Lvq8 => 2,
            Compression::Lvq4 => 3,
            Compression::Lvq4x8 => 4,
        }
    }

    /// Inverse of [`Compression::code`].
    pub fn from_code(c: u8) -> Option<Compression> {
        match c {
            0 => Some(Compression::F32),
            1 => Some(Compression::F16),
            2 => Some(Compression::Lvq8),
            3 => Some(Compression::Lvq4),
            4 => Some(Compression::Lvq4x8),
            _ => None,
        }
    }
}

/// Projection learner for the primary vectors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionKind {
    /// no dimensionality reduction (d == D)
    None,
    /// LeanVec-ID: PCA on K_X (Section 2.1)
    Id,
    /// LeanVec-OOD via Frank-Wolfe BCD (Algorithm 1)
    OodFrankWolfe,
    /// LeanVec-OOD via eigenvector search (Algorithm 2)
    OodEigSearch,
    /// random orthonormal projection (ablation baseline, Fig. 11)
    Random,
}

impl ProjectionKind {
    pub fn parse(s: &str) -> Option<ProjectionKind> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Some(ProjectionKind::None),
            "id" | "pca" | "leanvec-id" => Some(ProjectionKind::Id),
            "ood" | "fw" | "ood-fw" | "leanvec-ood" | "leanvec-ood-fw" => {
                Some(ProjectionKind::OodFrankWolfe)
            }
            "es" | "ood-es" | "eigsearch" | "leanvec-ood-es" => Some(ProjectionKind::OodEigSearch),
            "random" | "rand" => Some(ProjectionKind::Random),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ProjectionKind::None => "none",
            ProjectionKind::Id => "leanvec-id",
            ProjectionKind::OodFrankWolfe => "leanvec-ood-fw",
            ProjectionKind::OodEigSearch => "leanvec-ood-es",
            ProjectionKind::Random => "random",
        }
    }

    /// Stable one-byte wire code used by the snapshot format
    /// (`docs/SNAPSHOT_FORMAT.md`). Never renumber existing variants.
    pub fn code(&self) -> u8 {
        match self {
            ProjectionKind::None => 0,
            ProjectionKind::Id => 1,
            ProjectionKind::OodFrankWolfe => 2,
            ProjectionKind::OodEigSearch => 3,
            ProjectionKind::Random => 4,
        }
    }

    /// Inverse of [`ProjectionKind::code`].
    pub fn from_code(c: u8) -> Option<ProjectionKind> {
        match c {
            0 => Some(ProjectionKind::None),
            1 => Some(ProjectionKind::Id),
            2 => Some(ProjectionKind::OodFrankWolfe),
            3 => Some(ProjectionKind::OodEigSearch),
            4 => Some(ProjectionKind::Random),
            _ => None,
        }
    }
}

/// Vamana graph-construction parameters (Appendix D defaults).
#[derive(Clone, Copy, Debug)]
pub struct GraphParams {
    /// max out-degree R
    pub max_degree: usize,
    /// construction search window L
    pub build_window: usize,
    /// pruning slack alpha (1.2 for L2, 0.95 for IP per the paper)
    pub alpha: f32,
}

impl GraphParams {
    pub fn for_similarity(sim: Similarity) -> GraphParams {
        GraphParams {
            // Scaled-down defaults (paper: R=128, L=200 at n=1M+; the
            // synthetic datasets here are 10k-200k where R=32..64 is the
            // regime-equivalent choice).
            max_degree: 48,
            build_window: 100,
            alpha: match sim {
                Similarity::L2 | Similarity::Cosine => 1.2,
                Similarity::InnerProduct => 0.95,
            },
        }
    }
}

/// Index-construction threading knobs.
///
/// `build_threads` controls how many worker threads the builder uses
/// across every build phase: Vamana graph construction, LVQ/FP16
/// encoding, and database projection.
///
/// * `1` (the default) — fully serial reference build. Bit-for-bit
///   reproducible: identical adjacency lists and identical codes across
///   runs, and identical to the historical single-threaded builder.
/// * `0` — use `available_parallelism()`.
/// * `n > 1` — batch-synchronous parallel build. Quantization and
///   projection are bit-identical to the serial build (pure per-row
///   work); graph construction inserts nodes in fixed-size rounds whose
///   searches run against a frozen adjacency snapshot, so the resulting
///   graph is deterministic for any thread count > 1 (the round schedule
///   is fixed) but *differs* from the serial graph. The determinism
///   escape hatch is `build_threads = 1`: use it whenever adjacency
///   lists must match the serial reference exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BuildParams {
    /// worker threads for index construction (0 = all cores, 1 = serial)
    pub build_threads: usize,
}

impl Default for BuildParams {
    fn default() -> Self {
        BuildParams { build_threads: 1 }
    }
}

impl BuildParams {
    /// The effective worker count (`0` resolved to the core count).
    pub fn resolved_threads(&self) -> usize {
        crate::util::threadpool::resolve_threads(self.build_threads)
    }
}

/// Persistable run description, serialized next to experiment outputs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: String,
    pub projection: ProjectionKind,
    pub target_dim: usize,
    pub primary: Compression,
    pub secondary: Compression,
    pub graph: GraphParams,
    pub build: BuildParams,
}

impl RunConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(&self.dataset)),
            ("projection", Json::str(self.projection.name())),
            ("target_dim", Json::num(self.target_dim as f64)),
            ("primary", Json::str(self.primary.name())),
            ("secondary", Json::str(self.secondary.name())),
            ("max_degree", Json::num(self.graph.max_degree as f64)),
            ("build_window", Json::num(self.graph.build_window as f64)),
            ("alpha", Json::num(self.graph.alpha as f64)),
            ("build_threads", Json::num(self.build.build_threads as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for sim in [Similarity::InnerProduct, Similarity::L2, Similarity::Cosine] {
            assert_eq!(Similarity::parse(sim.name()), Some(sim));
        }
        for c in [
            Compression::F32,
            Compression::F16,
            Compression::Lvq8,
            Compression::Lvq4,
            Compression::Lvq4x8,
        ] {
            assert_eq!(Compression::parse(c.name()), Some(c));
        }
        assert_eq!(ProjectionKind::parse("pca"), Some(ProjectionKind::Id));
        assert_eq!(Similarity::parse("bogus"), None);
    }

    #[test]
    fn wire_codes_roundtrip() {
        for sim in [Similarity::InnerProduct, Similarity::L2, Similarity::Cosine] {
            assert_eq!(Similarity::from_code(sim.code()), Some(sim));
        }
        for c in [
            Compression::F32,
            Compression::F16,
            Compression::Lvq8,
            Compression::Lvq4,
            Compression::Lvq4x8,
        ] {
            assert_eq!(Compression::from_code(c.code()), Some(c));
        }
        for p in [
            ProjectionKind::None,
            ProjectionKind::Id,
            ProjectionKind::OodFrankWolfe,
            ProjectionKind::OodEigSearch,
            ProjectionKind::Random,
        ] {
            assert_eq!(ProjectionKind::from_code(p.code()), Some(p));
            // canonical names must parse back (snapshot META round-trip)
            assert_eq!(ProjectionKind::parse(p.name()), Some(p));
        }
        assert_eq!(Similarity::from_code(99), None);
        assert_eq!(Compression::from_code(99), None);
        assert_eq!(ProjectionKind::from_code(99), None);
    }

    #[test]
    fn alpha_depends_on_similarity() {
        assert_eq!(GraphParams::for_similarity(Similarity::L2).alpha, 1.2);
        assert_eq!(
            GraphParams::for_similarity(Similarity::InnerProduct).alpha,
            0.95
        );
    }

    #[test]
    fn run_config_serializes() {
        let rc = RunConfig {
            dataset: "rqa-768".into(),
            projection: ProjectionKind::OodFrankWolfe,
            target_dim: 160,
            primary: Compression::Lvq8,
            secondary: Compression::F16,
            graph: GraphParams::for_similarity(Similarity::InnerProduct),
            build: BuildParams { build_threads: 4 },
        };
        let j = rc.to_json();
        assert_eq!(j.get("target_dim").unwrap().as_usize(), Some(160));
        assert_eq!(j.get("projection").unwrap().as_str(), Some("leanvec-ood-fw"));
        assert_eq!(j.get("build_threads").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn build_params_default_is_serial() {
        let b = BuildParams::default();
        assert_eq!(b.build_threads, 1);
        assert_eq!(b.resolved_threads(), 1);
        assert!(BuildParams { build_threads: 0 }.resolved_threads() >= 1);
    }
}
