//! Compressed vector stores: FP32/FP16 and Locally-adaptive Vector
//! Quantization (Aguerrebere et al., 2023) in LVQ4 / LVQ8 / LVQ4x8
//! flavors, all behind one scoring trait used by graph traversal.
//!
//! Every store scores with a *fused* decode+dot: the code bytes are the
//! only per-vector memory traffic, which is the entire point of LVQ —
//! graph search is memory-bandwidth-bound, so score time tracks
//! `bytes_per_vector()`. The dots themselves run through the
//! [`crate::simd`] kernel layer (AVX2/FMA/F16C with runtime dispatch,
//! scalar fallback), and the request path scores in *blocks*
//! ([`ScoreStore::score_block`]) so upcoming code rows can be
//! software-prefetched while the current row computes.

pub mod lvq;
pub mod stores;

pub use lvq::{Lvq4x8Store, LvqStore};
pub use stores::{F16Store, F32Store};

use crate::config::{Compression, Similarity};
use crate::data::io::bin;

/// A prepared query: everything precomputable once per search.
#[derive(Clone, Debug)]
pub struct PreparedQuery {
    /// the (possibly projected) query vector
    pub q: Vec<f32>,
    /// sum of query components (LVQ offset fixup)
    pub q_sum: f32,
    /// `<q, mu>` against the store's global mean (LVQ mean fixup)
    pub q_mu: f32,
    /// similarity the scores should express
    pub sim: Similarity,
}

/// Uniform scoring interface over compressed stores.
///
/// Scores are "bigger is better" for every similarity:
/// IP/cosine -> `<q, x>`; L2 -> `2<q,x> - ||x||^2` (the `||q||^2`
/// constant is dropped as it does not affect ranking).
pub trait ScoreStore: Send + Sync {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn dim(&self) -> usize;
    /// Memory touched per scored vector (codes + per-vector constants).
    fn bytes_per_vector(&self) -> usize;
    fn prepare(&self, q: &[f32], sim: Similarity) -> PreparedQuery;
    fn score(&self, pq: &PreparedQuery, id: u32) -> f32;
    /// Decode (approximately reconstruct) one vector — rerank oracle,
    /// tests, and IVF-PQ training use this.
    fn decode(&self, id: u32) -> Vec<f32>;

    /// Score used during re-ranking. Defaults to [`ScoreStore::score`];
    /// two-level stores override it to include their residual level
    /// (`Lvq4x8Store::score_full`), matching what `decode` reconstructs.
    fn score_rerank(&self, pq: &PreparedQuery, id: u32) -> f32 {
        self.score(pq, id)
    }

    /// Memory touched per *re-ranked* vector — what `score_rerank` /
    /// `decode` actually read. Equal to `bytes_per_vector()` for
    /// single-level stores; two-level stores add their residual bytes,
    /// which graph traversal never touches but re-ranking does (this is
    /// the `QueryStats::bytes_touched` accounting used by Fig. 1).
    fn rerank_bytes_per_vector(&self) -> usize {
        self.bytes_per_vector()
    }

    /// Score a batch of ids, writing one score per id into `out` (in
    /// `ids` order, `out` cleared first). **This is the request-path
    /// entry point**: graph traversal and the flat scan hand whole
    /// neighbor/scan batches here, and every store overrides it to run
    /// the dispatched SIMD kernels with software prefetch of the next
    /// row's code bytes. Each score must equal `score(pq, id)` exactly
    /// (same kernel, same bits). The default is the sequential loop.
    fn score_block(&self, pq: &PreparedQuery, ids: &[u32], out: &mut Vec<f32>) {
        out.clear();
        out.extend(ids.iter().map(|&id| self.score(pq, id)));
    }

    /// Blocked [`ScoreStore::score_rerank`]: the re-rank loop's batch
    /// entry point, same contract as [`ScoreStore::score_block`] but
    /// for the re-ranking score (two-level stores read their residual
    /// level here and prefetch both levels' code rows).
    fn score_rerank_block(&self, pq: &PreparedQuery, ids: &[u32], out: &mut Vec<f32>) {
        out.clear();
        out.extend(ids.iter().map(|&id| self.score_rerank(pq, id)));
    }

    /// Serialize the store's complete state — codes *and* every derived
    /// per-vector constant (scales, offsets, stored norms) — so a store
    /// read back by [`read_store`] scores bit-identically to this one.
    /// The payload is self-describing: it starts with the store's
    /// [`Compression`] wire code. Byte layout: `docs/SNAPSHOT_FORMAT.md`.
    ///
    /// Returns the *alignment anchor*: the byte offset (relative to
    /// where this store's payload begins in `out`) of the raw element
    /// data of the store's dominant typed array. The aligned snapshot
    /// writer pads the section start so this anchor lands on a 64-byte
    /// boundary, which is what lets `load_mmap` borrow that array
    /// straight out of the page cache.
    fn write_bytes(&self, out: &mut Vec<u8>) -> usize;

    /// Issue software prefetch for the code rows of `ids` (the bytes
    /// `score_block` will touch). Beam search calls this for the *next*
    /// hop's neighborhood while the current block computes, so cold
    /// cache lines — and, for mmap-served stores, already-resident page
    /// cache lines — overlap compute. Purely a hint: the default no-op
    /// is always correct.
    fn prefetch_rows(&self, ids: &[u32]) {
        let _ = ids;
    }

    /// Append one vector; its id is the store's previous `len()`.
    ///
    /// The row is encoded with the store's *existing* derived constants
    /// — for LVQ stores the global mean is part of the learned
    /// representation, so new vectors are centered against it and the
    /// mean is never re-estimated (existing codes stay valid; see the
    /// live-index drift note in `docs/ARCHITECTURE.md`). Appending the
    /// same row always produces the same bytes regardless of what else
    /// is stored.
    fn append_row(&mut self, row: &[f32]);

    /// Drop every row not named in `keep` (strictly increasing old
    /// ids): old id `keep[i]` becomes new id `i`. Tombstone
    /// consolidation uses this to compact the store after deletes; the
    /// surviving rows' bytes are moved, never re-encoded, so scores are
    /// bit-identical across a compaction.
    fn compact(&mut self, keep: &[u32]);

    /// Deep self-check for the fsck layer: verify every internal size
    /// relation (row count × stride vs payload lengths) and the
    /// validity of per-vector derived constants (finite norms, strictly
    /// positive LVQ scales), pushing one [`Violation`] per broken
    /// invariant. Must never panic on corrupt state — checkers
    /// re-derive offsets from lengths before touching any array. The
    /// `repro fsck` CLI and the corruption test battery both call this.
    ///
    /// [`Violation`]: crate::util::invariants::Violation
    fn check_invariants(&self, out: &mut Vec<crate::util::invariants::Violation>);
}

/// THE blocked-scoring loop shape shared by every store's
/// `score_block`/`score_rerank_block` override: clear + reserve, issue
/// `prefetch_row(next_id)` for the upcoming row while `score(id)`
/// computes the current one, push in `ids` order. One copy so the
/// prefetch policy (distance, which bytes) can never drift between
/// store kinds.
pub(crate) fn blocked_scores<P, S>(ids: &[u32], out: &mut Vec<f32>, prefetch_row: P, score: S)
where
    P: Fn(u32),
    S: Fn(u32) -> f32,
{
    out.clear();
    out.reserve(ids.len());
    for (i, &id) in ids.iter().enumerate() {
        if let Some(&next) = ids.get(i + 1) {
            prefetch_row(next);
        }
        out.push(score(id));
    }
}

/// Shared compaction helper: retain `keep[i] * stride .. +stride` slices
/// of a flat per-vector buffer, in `keep` order.
pub(crate) fn compact_flat<T: Copy>(data: &mut Vec<T>, stride: usize, keep: &[u32]) {
    let mut out = Vec::with_capacity(keep.len() * stride);
    for &old in keep {
        let i = old as usize * stride;
        out.extend_from_slice(&data[i..i + stride]);
    }
    *data = out;
}

/// [`compact_flat`] for stride-1 per-vector constants.
pub(crate) fn compact_scalars<T: Copy>(data: &mut Vec<T>, keep: &[u32]) {
    compact_flat(data, 1, keep);
}

/// Deserialize a store previously written by [`ScoreStore::write_bytes`]
/// (any variant; the leading [`Compression`] wire code selects the
/// concrete type). Errors with `InvalidData` on an unknown code or
/// internally inconsistent payload, `UnexpectedEof` on truncation.
pub fn read_store(cur: &mut bin::Cursor) -> std::io::Result<Box<dyn ScoreStore>> {
    read_store_src(cur, None)
}

/// [`read_store`] with an optional mmap backing: when `src` is given
/// (and the cursor is iterating the section payload slice of
/// `src.map`), the store's large arrays are *borrowed* from the
/// mapping instead of decoded into owned heap buffers — falling back
/// per array when the file bytes are misaligned for the element type.
pub fn read_store_src(
    cur: &mut bin::Cursor,
    src: Option<&crate::util::mmap::SectionSrc>,
) -> std::io::Result<Box<dyn ScoreStore>> {
    let code = cur.get_u8()?;
    let kind = Compression::from_code(code).ok_or_else(|| {
        std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("unknown store compression code {code}"),
        )
    })?;
    match kind {
        Compression::F32 => Ok(Box::new(F32Store::read_bytes_src(cur, src)?)),
        Compression::F16 => Ok(Box::new(F16Store::read_bytes_src(cur, src)?)),
        Compression::Lvq4 | Compression::Lvq8 => {
            Ok(Box::new(LvqStore::read_bytes_src(cur, kind, src)?))
        }
        Compression::Lvq4x8 => Ok(Box::new(Lvq4x8Store::read_bytes_src(cur, src)?)),
    }
}

/// `InvalidData` error helper shared by the store deserializers.
pub(crate) fn corrupt(what: &str) -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::InvalidData,
        format!("inconsistent store payload: {what}"),
    )
}

/// Shared plumbing: turn an inner product plus stored `||x||^2` into the
/// similarity-specific score.
#[inline]
pub(crate) fn finish_score(ip: f32, norm_sq: f32, sim: Similarity) -> f32 {
    match sim {
        Similarity::InnerProduct | Similarity::Cosine => ip,
        Similarity::L2 => 2.0 * ip - norm_sq,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_score_orders_l2_correctly() {
        // q = 1D point at 0; x1 at 1, x2 at 3: x1 closer
        // score = 2<q,x> - x^2 = -x^2 when q = 0
        let s1 = finish_score(0.0, 1.0, Similarity::L2);
        let s2 = finish_score(0.0, 9.0, Similarity::L2);
        assert!(s1 > s2);
    }

    #[test]
    fn finish_score_ip_passthrough() {
        assert_eq!(finish_score(3.5, 99.0, Similarity::InnerProduct), 3.5);
        assert_eq!(finish_score(3.5, 99.0, Similarity::Cosine), 3.5);
    }
}
