//! Uncompressed (f32) and half-precision (f16) vector stores.

use super::{compact_flat, compact_scalars, corrupt, finish_score, PreparedQuery, ScoreStore};
use crate::config::{Compression, Similarity};
use crate::data::io::bin;
use crate::linalg::matrix::dot;
use crate::util::f16;
use crate::util::mmap::{self, Arr, SectionSrc};
use crate::util::threadpool::parallel_chunked;

/// Plain f32 store — the accuracy reference and the FP32 baseline.
///
/// The arrays are [`Arr`]-backed: owned vectors on the heap path,
/// windows borrowed from a mapped snapshot on the `load_mmap` path.
pub struct F32Store {
    dim: usize,
    data: Arr<f32>,
    norms_sq: Arr<f32>,
}

impl F32Store {
    pub fn from_rows(rows: &[Vec<f32>]) -> F32Store {
        let dim = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(rows.len() * dim);
        let mut norms_sq = Vec::with_capacity(rows.len());
        for r in rows {
            assert_eq!(r.len(), dim);
            norms_sq.push(dot(r, r));
            data.extend_from_slice(r);
        }
        F32Store {
            dim,
            data: data.into(),
            norms_sq: norms_sq.into(),
        }
    }

    /// Build from a flat row-major buffer.
    pub fn from_flat(dim: usize, data: Vec<f32>) -> F32Store {
        assert_eq!(data.len() % dim.max(1), 0);
        let norms_sq: Vec<f32> = data.chunks(dim).map(|r| dot(r, r)).collect();
        F32Store {
            dim,
            data: data.into(),
            norms_sq: norms_sq.into(),
        }
    }

    #[inline]
    pub fn vector(&self, id: u32) -> &[f32] {
        let i = id as usize * self.dim;
        &self.data[i..i + self.dim]
    }

    /// Deserialize a payload written by this store's
    /// [`ScoreStore::write_bytes`] (after the compression code byte).
    pub(crate) fn read_bytes(cur: &mut bin::Cursor) -> std::io::Result<F32Store> {
        Self::read_bytes_src(cur, None)
    }

    /// [`F32Store::read_bytes`], borrowing the arrays from a mapped
    /// snapshot when `src` is given and the bytes are aligned.
    pub(crate) fn read_bytes_src(
        cur: &mut bin::Cursor,
        src: Option<&SectionSrc>,
    ) -> std::io::Result<F32Store> {
        let dim = cur.get_u32()? as usize;
        let data = mmap::get_f32s_arr(cur, src)?;
        let norms_sq = mmap::get_f32s_arr(cur, src)?;
        if data.len() != norms_sq.len() * dim {
            return Err(corrupt("f32 store: data/norms length mismatch"));
        }
        Ok(F32Store {
            dim,
            data,
            norms_sq,
        })
    }
}

impl ScoreStore for F32Store {
    fn len(&self) -> usize {
        self.norms_sq.len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn bytes_per_vector(&self) -> usize {
        self.dim * 4 + 4
    }

    fn prepare(&self, q: &[f32], sim: Similarity) -> PreparedQuery {
        PreparedQuery {
            q: q.to_vec(),
            q_sum: 0.0,
            q_mu: 0.0,
            sim,
        }
    }

    fn score(&self, pq: &PreparedQuery, id: u32) -> f32 {
        let ip = dot(&pq.q, self.vector(id));
        finish_score(ip, self.norms_sq[id as usize], pq.sim)
    }

    /// Blocked scoring with software prefetch of the next row.
    fn score_block(&self, pq: &PreparedQuery, ids: &[u32], out: &mut Vec<f32>) {
        super::blocked_scores(
            ids,
            out,
            |next| crate::simd::prefetch(&self.data[next as usize * self.dim..]),
            |id| self.score(pq, id),
        );
    }

    /// Single-level store: re-rank scoring is traversal scoring.
    fn score_rerank_block(&self, pq: &PreparedQuery, ids: &[u32], out: &mut Vec<f32>) {
        self.score_block(pq, ids, out);
    }

    fn prefetch_rows(&self, ids: &[u32]) {
        for &id in ids {
            let i = id as usize * self.dim;
            crate::simd::prefetch_row(&self.data[i..i + self.dim]);
        }
    }

    fn decode(&self, id: u32) -> Vec<f32> {
        self.vector(id).to_vec()
    }

    fn write_bytes(&self, out: &mut Vec<u8>) -> usize {
        bin::put_u8(out, Compression::F32.code());
        bin::put_u32(out, self.dim as u32);
        let anchor = out.len() + 8; // f32 data begins after the u64 count
        bin::put_f32s(out, &self.data);
        bin::put_f32s(out, &self.norms_sq);
        anchor
    }

    fn append_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim);
        self.norms_sq.make_owned().push(dot(row, row));
        self.data.make_owned().extend_from_slice(row);
    }

    fn compact(&mut self, keep: &[u32]) {
        compact_flat(self.data.make_owned(), self.dim, keep);
        compact_scalars(self.norms_sq.make_owned(), keep);
    }

    fn check_invariants(&self, out: &mut Vec<crate::util::invariants::Violation>) {
        use crate::util::invariants::{check_finite, Violation};
        let n = self.norms_sq.len();
        if self.data.len() != n * self.dim {
            out.push(Violation::new(
                "store",
                "payload-size-mismatch",
                format!(
                    "f32 data has {} elements, want {n} rows x {} dims",
                    self.data.len(),
                    self.dim
                ),
            ));
        }
        check_finite(out, "store", "norms_sq", &self.norms_sq);
    }
}

/// FP16 store — the paper's uncompressed baseline and the default
/// secondary (re-ranking) representation.
pub struct F16Store {
    dim: usize,
    data: Arr<u16>,
    norms_sq: Arr<f32>,
}

impl F16Store {
    pub fn from_rows(rows: &[Vec<f32>]) -> F16Store {
        Self::from_rows_threads(rows, 1)
    }

    /// Parallel-encoding constructor: rows are converted to f16 in
    /// independent chunks (pure per-row work, so the result is
    /// bit-identical to the serial build for every thread count).
    pub fn from_rows_threads(rows: &[Vec<f32>], threads: usize) -> F16Store {
        let threads = crate::util::threadpool::resolve_threads(threads);
        let dim = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(rows.len() * dim);
        let mut norms_sq = Vec::with_capacity(rows.len());
        let parts = parallel_chunked(rows.len(), threads, |start, end| {
            let mut codes = Vec::with_capacity((end - start) * dim);
            let mut norms = Vec::with_capacity(end - start);
            for r in &rows[start..end] {
                assert_eq!(r.len(), dim);
                let enc = f16::encode_slice(r);
                // norm of the *encoded* vector so scoring is self-consistent
                let dec = f16::decode_slice(&enc);
                norms.push(dot(&dec, &dec));
                codes.extend_from_slice(&enc);
            }
            (codes, norms)
        });
        for (codes, norms) in parts {
            data.extend_from_slice(&codes);
            norms_sq.extend_from_slice(&norms);
        }
        F16Store {
            dim,
            data: data.into(),
            norms_sq: norms_sq.into(),
        }
    }

    #[inline]
    fn codes(&self, id: u32) -> &[u16] {
        let i = id as usize * self.dim;
        &self.data[i..i + self.dim]
    }

    /// Deserialize a payload written by this store's
    /// [`ScoreStore::write_bytes`] (after the compression code byte).
    pub(crate) fn read_bytes(cur: &mut bin::Cursor) -> std::io::Result<F16Store> {
        Self::read_bytes_src(cur, None)
    }

    /// [`F16Store::read_bytes`], borrowing the arrays from a mapped
    /// snapshot when `src` is given and the bytes are aligned.
    pub(crate) fn read_bytes_src(
        cur: &mut bin::Cursor,
        src: Option<&SectionSrc>,
    ) -> std::io::Result<F16Store> {
        let dim = cur.get_u32()? as usize;
        let data = mmap::get_u16s_arr(cur, src)?;
        let norms_sq = mmap::get_f32s_arr(cur, src)?;
        if data.len() != norms_sq.len() * dim {
            return Err(corrupt("f16 store: data/norms length mismatch"));
        }
        Ok(F16Store {
            dim,
            data,
            norms_sq,
        })
    }
}

impl ScoreStore for F16Store {
    fn len(&self) -> usize {
        self.norms_sq.len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn bytes_per_vector(&self) -> usize {
        self.dim * 2 + 4
    }

    fn prepare(&self, q: &[f32], sim: Similarity) -> PreparedQuery {
        PreparedQuery {
            q: q.to_vec(),
            q_sum: 0.0,
            q_mu: 0.0,
            sim,
        }
    }

    fn score(&self, pq: &PreparedQuery, id: u32) -> f32 {
        // fused decode+dot, no temporaries: `_mm256_cvtph_ps` widening
        // on F16C hosts, the 64K decode table on the scalar path
        let ip = crate::simd::dot_f16(self.codes(id), &pq.q);
        finish_score(ip, self.norms_sq[id as usize], pq.sim)
    }

    /// Blocked scoring with software prefetch of the next row's f16
    /// codes.
    fn score_block(&self, pq: &PreparedQuery, ids: &[u32], out: &mut Vec<f32>) {
        super::blocked_scores(
            ids,
            out,
            |next| crate::simd::prefetch(&self.data[next as usize * self.dim..]),
            |id| self.score(pq, id),
        );
    }

    /// Single-level store: re-rank scoring is traversal scoring.
    fn score_rerank_block(&self, pq: &PreparedQuery, ids: &[u32], out: &mut Vec<f32>) {
        self.score_block(pq, ids, out);
    }

    fn prefetch_rows(&self, ids: &[u32]) {
        for &id in ids {
            let i = id as usize * self.dim;
            crate::simd::prefetch_row(&self.data[i..i + self.dim]);
        }
    }

    fn decode(&self, id: u32) -> Vec<f32> {
        f16::decode_slice(self.codes(id))
    }

    fn write_bytes(&self, out: &mut Vec<u8>) -> usize {
        bin::put_u8(out, Compression::F16.code());
        bin::put_u32(out, self.dim as u32);
        let anchor = out.len() + 8; // u16 data begins after the u64 count
        bin::put_u16s(out, &self.data);
        bin::put_f32s(out, &self.norms_sq);
        anchor
    }

    fn append_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim);
        let enc = f16::encode_slice(row);
        // norm of the *encoded* vector, same as the batch constructor
        let dec = f16::decode_slice(&enc);
        self.norms_sq.make_owned().push(dot(&dec, &dec));
        self.data.make_owned().extend_from_slice(&enc);
    }

    fn compact(&mut self, keep: &[u32]) {
        compact_flat(self.data.make_owned(), self.dim, keep);
        compact_scalars(self.norms_sq.make_owned(), keep);
    }

    fn check_invariants(&self, out: &mut Vec<crate::util::invariants::Violation>) {
        use crate::util::invariants::{check_finite, Violation};
        let n = self.norms_sq.len();
        if self.data.len() != n * self.dim {
            out.push(Violation::new(
                "store",
                "payload-size-mismatch",
                format!(
                    "f16 data has {} elements, want {n} rows x {} dims",
                    self.data.len(),
                    self.dim
                ),
            ));
        }
        check_finite(out, "store", "norms_sq", &self.norms_sq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    #[test]
    fn f32_store_exact_ip() {
        let rs = rows(10, 16, 1);
        let store = F32Store::from_rows(&rs);
        let q: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let pq = store.prepare(&q, Similarity::InnerProduct);
        for (i, r) in rs.iter().enumerate() {
            let want = dot(&q, r);
            assert!((store.score(&pq, i as u32) - want).abs() < 1e-5);
        }
    }

    #[test]
    fn f32_store_l2_ranking_matches_true_distances() {
        let rs = rows(50, 8, 2);
        let store = F32Store::from_rows(&rs);
        let q: Vec<f32> = rows(1, 8, 3).pop().unwrap();
        let pq = store.prepare(&q, Similarity::L2);
        let mut by_score: Vec<usize> = (0..50).collect();
        by_score.sort_by(|&a, &b| {
            store
                .score(&pq, b as u32)
                .partial_cmp(&store.score(&pq, a as u32))
                .unwrap()
        });
        let mut by_dist: Vec<usize> = (0..50).collect();
        by_dist.sort_by(|&a, &b| {
            crate::linalg::matrix::l2_sq(&q, &rs[a])
                .partial_cmp(&crate::linalg::matrix::l2_sq(&q, &rs[b]))
                .unwrap()
        });
        assert_eq!(by_score, by_dist);
    }

    #[test]
    fn f16_store_close_to_f32() {
        let rs = rows(20, 32, 4);
        let f32s = F32Store::from_rows(&rs);
        let f16s = F16Store::from_rows(&rs);
        let q: Vec<f32> = rows(1, 32, 5).pop().unwrap();
        let p32 = f32s.prepare(&q, Similarity::InnerProduct);
        let p16 = f16s.prepare(&q, Similarity::InnerProduct);
        for i in 0..20 {
            let a = f32s.score(&p32, i);
            let b = f16s.score(&p16, i);
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_roundtrip() {
        let rs = rows(5, 12, 6);
        let store = F16Store::from_rows(&rs);
        for i in 0..5 {
            let dec = store.decode(i);
            for (a, b) in dec.iter().zip(rs[i as usize].iter()) {
                assert!((a - b).abs() < 0.01);
            }
        }
    }

    #[test]
    fn bytes_per_vector_ordering() {
        let rs = rows(3, 64, 7);
        assert!(
            F16Store::from_rows(&rs).bytes_per_vector()
                < F32Store::from_rows(&rs).bytes_per_vector()
        );
    }

    #[test]
    fn f16_parallel_encoding_bit_identical() {
        let rs = rows(600, 20, 9);
        let serial = F16Store::from_rows(&rs);
        let parallel = F16Store::from_rows_threads(&rs, 4);
        assert_eq!(serial.data, parallel.data);
        assert_eq!(serial.norms_sq, parallel.norms_sq);
    }

    #[test]
    fn write_read_roundtrip_bit_identical() {
        let rs = rows(40, 17, 10); // odd dim
        let q: Vec<f32> = rows(1, 17, 11).pop().unwrap();
        for store in [
            Box::new(F32Store::from_rows(&rs)) as Box<dyn ScoreStore>,
            Box::new(F16Store::from_rows(&rs)),
        ] {
            let mut buf = Vec::new();
            store.write_bytes(&mut buf);
            let mut cur = crate::data::io::bin::Cursor::new(&buf);
            let back = crate::quant::read_store(&mut cur).unwrap();
            assert_eq!(cur.remaining(), 0);
            assert_eq!(back.len(), store.len());
            assert_eq!(back.dim(), store.dim());
            assert_eq!(back.bytes_per_vector(), store.bytes_per_vector());
            let (pa, pb) = (
                store.prepare(&q, Similarity::L2),
                back.prepare(&q, Similarity::L2),
            );
            for i in 0..store.len() as u32 {
                // bit-identical, not approximately equal
                assert_eq!(store.score(&pa, i).to_bits(), back.score(&pb, i).to_bits());
                assert_eq!(store.decode(i), back.decode(i));
            }
        }
    }

    #[test]
    fn score_block_matches_score() {
        let rs = rows(10, 8, 8);
        let store = F32Store::from_rows(&rs);
        let q = vec![1.0; 8];
        let pq = store.prepare(&q, Similarity::InnerProduct);
        let ids: Vec<u32> = (0..10).collect();
        let mut out = Vec::new();
        store.score_block(&pq, &ids, &mut out);
        for (i, &s) in out.iter().enumerate() {
            assert_eq!(s, store.score(&pq, i as u32));
        }
    }
}
