//! Locally-adaptive Vector Quantization (Aguerrebere et al., 2023).
//!
//! Per vector `x`: remove the global mean `u = x - mu`, then scalar-
//! quantize each component with that vector's own range:
//!
//! ```text
//! lo_i  = min(u),  hi_i = max(u),  delta_i = (hi - lo) / (2^B - 1)
//! code  = round((u - lo) / delta)            (B bits per component)
//! x_hat = mu + code * delta + lo
//! ```
//!
//! The inner product factorizes into one integer dot plus scalar fixups
//! (this is what makes LVQ fast — see python/compile/kernels/lvq_dot.py
//! for the Pallas twin of this loop):
//!
//! ```text
//! <q, x_hat> = delta_i * <q, code> + lo_i * sum(q) + <q, mu>
//! ```
//!
//! `Lvq4x8Store` adds a second-level 8-bit quantization of the residual
//! (the paper's LVQ4x8): traversal reads only the 4-bit codes; the
//! residual level is used for decode/re-ranking.

use super::{compact_flat, compact_scalars, corrupt, finish_score, PreparedQuery, ScoreStore};
use crate::config::{Compression, Similarity};
use crate::data::io::bin;
use crate::linalg::matrix::dot;
use crate::util::mmap::{self, Arr, SectionSrc};
use crate::util::threadpool::parallel_chunked;

/// Single-level LVQ store with B in {4, 8} bits per component.
///
/// Arrays are [`Arr`]-backed: owned on the heap path, borrowed from
/// the mapped snapshot on the `load_mmap` path (the mean and the code
/// bytes always borrow; the per-vector f32 constants borrow when the
/// file offset happens to be 4-aligned and decode otherwise).
pub struct LvqStore {
    dim: usize,
    bits: u8,
    mean: Arr<f32>,
    /// B=8: one byte per component; B=4: two components per byte
    codes: Arr<u8>,
    delta: Arr<f32>,
    lo: Arr<f32>,
    norms_sq: Arr<f32>,
    bytes_per_vec: usize,
}

fn compute_mean(rows: &[Vec<f32>], dim: usize) -> Vec<f32> {
    let mut mean = vec![0.0f64; dim];
    for r in rows {
        for (m, &v) in mean.iter_mut().zip(r.iter()) {
            *m += v as f64;
        }
    }
    let inv = 1.0 / rows.len().max(1) as f64;
    mean.iter().map(|&m| (m * inv) as f32).collect()
}

/// Quantize one centered vector; returns (codes, delta, lo).
fn quantize(u: &[f32], levels: u32) -> (Vec<u8>, f32, f32) {
    let lo = u.iter().copied().fold(f32::INFINITY, f32::min);
    let hi = u.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let range = (hi - lo).max(1e-12);
    let delta = range / (levels - 1) as f32;
    let inv = (levels - 1) as f32 / range;
    let codes = u
        .iter()
        .map(|&v| {
            let c = ((v - lo) * inv).round();
            c.clamp(0.0, (levels - 1) as f32) as u8
        })
        .collect();
    (codes, delta, lo)
}

/// Per-chunk encoder output, concatenated serially in chunk order.
struct EncodedChunk {
    codes: Vec<u8>,
    delta: Vec<f32>,
    lo: Vec<f32>,
    norms_sq: Vec<f32>,
}

/// Quantize `rows` against `mean` (pure per-row work; used by both the
/// serial and the chunk-parallel paths, so they agree bit-for-bit).
fn encode_rows(rows: &[Vec<f32>], mean: &[f32], bits: u8, stride: usize) -> EncodedChunk {
    let dim = mean.len();
    let levels = 1u32 << bits;
    let mut out = EncodedChunk {
        codes: Vec::with_capacity(rows.len() * stride),
        delta: Vec::with_capacity(rows.len()),
        lo: Vec::with_capacity(rows.len()),
        norms_sq: Vec::with_capacity(rows.len()),
    };
    let mut u = vec![0.0f32; dim];
    for r in rows {
        assert_eq!(r.len(), dim);
        for ((uv, &x), &m) in u.iter_mut().zip(r.iter()).zip(mean.iter()) {
            *uv = x - m;
        }
        let (c, d, l) = quantize(&u, levels);
        // reconstructed norm, consistent with scoring
        let mut ns = 0.0f32;
        for (i, &ci) in c.iter().enumerate() {
            let v = mean[i] + ci as f32 * d + l;
            ns += v * v;
        }
        out.norms_sq.push(ns);
        out.delta.push(d);
        out.lo.push(l);
        if bits == 8 {
            out.codes.extend_from_slice(&c);
        } else {
            // pack two 4-bit codes per byte, low nibble first
            for pair in c.chunks(2) {
                let lo_nib = pair[0] & 0x0F;
                let hi_nib = pair.get(1).copied().unwrap_or(0) & 0x0F;
                out.codes.push(lo_nib | (hi_nib << 4));
            }
        }
    }
    out
}

impl LvqStore {
    pub fn new(rows: &[Vec<f32>], bits: u8) -> LvqStore {
        Self::with_mean_threads(rows, bits, None, 1)
    }

    /// Parallel-encoding constructor (0 threads = all cores).
    pub fn new_threads(rows: &[Vec<f32>], bits: u8, threads: usize) -> LvqStore {
        Self::with_mean_threads(rows, bits, None, threads)
    }

    /// Build with an explicit global mean (used when the primary store
    /// quantizes *projected* vectors whose mean was computed upstream).
    pub fn with_mean(rows: &[Vec<f32>], bits: u8, mean: Option<Vec<f32>>) -> LvqStore {
        Self::with_mean_threads(rows, bits, mean, 1)
    }

    /// [`LvqStore::with_mean`] with each vector's quantization fanned
    /// out across `threads` workers in fixed-size row chunks.
    /// Bit-identical to the serial build for every thread count.
    pub fn with_mean_threads(
        rows: &[Vec<f32>],
        bits: u8,
        mean: Option<Vec<f32>>,
        threads: usize,
    ) -> LvqStore {
        assert!(bits == 4 || bits == 8, "LVQ supports 4 or 8 bits");
        let threads = crate::util::threadpool::resolve_threads(threads);
        let dim = rows.first().map(|r| r.len()).unwrap_or(0);
        let mean = mean.unwrap_or_else(|| compute_mean(rows, dim));
        let stride = if bits == 8 { dim } else { dim.div_ceil(2) };

        let mut codes = Vec::with_capacity(rows.len() * stride);
        let mut delta = Vec::with_capacity(rows.len());
        let mut lo = Vec::with_capacity(rows.len());
        let mut norms_sq = Vec::with_capacity(rows.len());

        let parts = parallel_chunked(rows.len(), threads, |start, end| {
            encode_rows(&rows[start..end], &mean, bits, stride)
        });
        for p in parts {
            codes.extend_from_slice(&p.codes);
            delta.extend_from_slice(&p.delta);
            lo.extend_from_slice(&p.lo);
            norms_sq.extend_from_slice(&p.norms_sq);
        }

        // bytes/vector: codes + delta + lo (mean is shared, amortized out)
        let bytes_per_vec = stride + 8;
        LvqStore {
            dim,
            bits,
            mean: mean.into(),
            codes: codes.into(),
            delta: delta.into(),
            lo: lo.into(),
            norms_sq: norms_sq.into(),
            bytes_per_vec,
        }
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }

    pub fn mean(&self) -> &[f32] {
        &self.mean
    }

    /// Packed code bytes per vector (one copy of the stride rule for
    /// every accessor; the constructors derive it from `bits` before
    /// the struct exists and store it via `bytes_per_vec`).
    #[inline]
    fn stride(&self) -> usize {
        self.bytes_per_vec - 8
    }

    #[inline]
    fn code_slice(&self, id: u32) -> &[u8] {
        let stride = self.stride();
        let i = id as usize * stride;
        &self.codes[i..i + stride]
    }

    /// Fused decode+dot against the raw codes: `<q, code>` through the
    /// dispatched integer kernels.
    #[inline]
    fn code_dot(&self, q: &[f32], id: u32) -> f32 {
        let codes = self.code_slice(id);
        if self.bits == 8 {
            crate::simd::dot_u8(codes, q)
        } else {
            crate::simd::dot_u4(codes, q)
        }
    }

    /// Serialize every field (shared by the one- and two-level wire
    /// formats; the caller writes the compression code byte first).
    /// Returns the alignment anchor: the offset of the raw mean f32
    /// data within `out` (the code bytes that follow are u8 and
    /// alignment-free, so the mean is the widest array to anchor on).
    fn write_fields(&self, out: &mut Vec<u8>) -> usize {
        bin::put_u32(out, self.dim as u32);
        bin::put_u8(out, self.bits);
        let anchor = out.len() + 8; // mean f32 data after the u64 count
        bin::put_f32s(out, &self.mean);
        bin::put_bytes(out, &self.codes);
        bin::put_f32s(out, &self.delta);
        bin::put_f32s(out, &self.lo);
        bin::put_f32s(out, &self.norms_sq);
        anchor
    }

    /// Inverse of [`LvqStore::write_fields`], with size cross-checks.
    fn read_fields(cur: &mut bin::Cursor, src: Option<&SectionSrc>) -> std::io::Result<LvqStore> {
        let dim = cur.get_u32()? as usize;
        let bits = cur.get_u8()?;
        if bits != 4 && bits != 8 {
            return Err(corrupt("lvq store: bits not 4 or 8"));
        }
        let mean = mmap::get_f32s_arr(cur, src)?;
        let codes = mmap::get_bytes_arr(cur, src)?;
        let delta = mmap::get_f32s_arr(cur, src)?;
        let lo = mmap::get_f32s_arr(cur, src)?;
        let norms_sq = mmap::get_f32s_arr(cur, src)?;
        let stride = if bits == 8 { dim } else { dim.div_ceil(2) };
        let n = delta.len();
        if mean.len() != dim
            || codes.len() != n * stride
            || lo.len() != n
            || norms_sq.len() != n
        {
            return Err(corrupt("lvq store: field length mismatch"));
        }
        Ok(LvqStore {
            dim,
            bits,
            mean,
            codes,
            delta,
            lo,
            norms_sq,
            bytes_per_vec: stride + 8,
        })
    }

    /// Deserialize a one-level payload written by this store's
    /// [`ScoreStore::write_bytes`] (after the compression code byte);
    /// `kind` is that code, used to cross-check the stored bit width.
    pub(crate) fn read_bytes(cur: &mut bin::Cursor, kind: Compression) -> std::io::Result<LvqStore> {
        Self::read_bytes_src(cur, kind, None)
    }

    /// [`LvqStore::read_bytes`], borrowing arrays from a mapped
    /// snapshot when `src` is given.
    pub(crate) fn read_bytes_src(
        cur: &mut bin::Cursor,
        kind: Compression,
        src: Option<&SectionSrc>,
    ) -> std::io::Result<LvqStore> {
        let store = Self::read_fields(cur, src)?;
        let want_bits = if kind == Compression::Lvq8 { 8 } else { 4 };
        if store.bits != want_bits {
            return Err(corrupt("lvq store: bit width disagrees with compression code"));
        }
        Ok(store)
    }

    /// Test-battery hook: overwrite one per-vector scale so the fsck
    /// checkers have a value-level corruption (one `read_fields` cannot
    /// reject — it validates lengths, not signs) to detect.
    #[doc(hidden)]
    pub fn corrupt_delta_for_fsck(&mut self, id: usize, value: f32) {
        self.delta.make_owned()[id] = value;
    }

    /// Shared by the one- and two-level checkers: every size relation
    /// and derived-constant invariant of one LVQ level, reported with
    /// `what` naming the level ("lvq" / "lvq4x8 first level").
    fn check_level(&self, what: &str, out: &mut Vec<crate::util::invariants::Violation>) {
        use crate::util::invariants::{check_finite, Violation};
        let n = self.delta.len();
        let stride = self.stride();
        if self.mean.len() != self.dim {
            out.push(Violation::new(
                "store",
                "payload-size-mismatch",
                format!("{what}: mean has {} dims, store dim {}", self.mean.len(), self.dim),
            ));
        }
        if self.codes.len() != n * stride {
            out.push(Violation::new(
                "store",
                "payload-size-mismatch",
                format!(
                    "{what}: {} code bytes, want {n} rows x {stride} stride",
                    self.codes.len()
                ),
            ));
        }
        if self.lo.len() != n || self.norms_sq.len() != n {
            out.push(Violation::new(
                "store",
                "payload-size-mismatch",
                format!(
                    "{what}: lo/norms rows {}/{} disagree with {n} deltas",
                    self.lo.len(),
                    self.norms_sq.len()
                ),
            ));
        }
        // delta is range / (levels - 1) with range clamped >= 1e-12 at
        // encode time, so a non-positive scale can only mean corruption
        if let Some((i, d)) = self
            .delta
            .iter()
            .enumerate()
            .find(|(_, d)| !d.is_finite() || **d <= 0.0)
        {
            out.push(Violation::new(
                "store",
                "scale-not-positive",
                format!("{what}: delta[{i}] = {d}"),
            ));
        }
        check_finite(out, "store", "lo", &self.lo);
        check_finite(out, "store", "norms_sq", &self.norms_sq);
        check_finite(out, "store", "mean", &self.mean);
    }
}

impl ScoreStore for LvqStore {
    fn len(&self) -> usize {
        self.delta.len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn bytes_per_vector(&self) -> usize {
        self.bytes_per_vec
    }

    fn prepare(&self, q: &[f32], sim: Similarity) -> PreparedQuery {
        PreparedQuery {
            q_sum: q.iter().sum(),
            q_mu: dot(q, &self.mean),
            q: q.to_vec(),
            sim,
        }
    }

    fn score(&self, pq: &PreparedQuery, id: u32) -> f32 {
        let i = id as usize;
        let ip = self.delta[i] * self.code_dot(&pq.q, id) + self.lo[i] * pq.q_sum + pq.q_mu;
        finish_score(ip, self.norms_sq[i], pq.sim)
    }

    /// Blocked scoring with software prefetch of the next row's code
    /// bytes while the current row's kernel runs.
    fn score_block(&self, pq: &PreparedQuery, ids: &[u32], out: &mut Vec<f32>) {
        let stride = self.stride();
        super::blocked_scores(
            ids,
            out,
            |next| crate::simd::prefetch(&self.codes[next as usize * stride..]),
            |id| self.score(pq, id),
        );
    }

    /// Single-level store: re-rank scoring is traversal scoring.
    fn score_rerank_block(&self, pq: &PreparedQuery, ids: &[u32], out: &mut Vec<f32>) {
        self.score_block(pq, ids, out);
    }

    fn prefetch_rows(&self, ids: &[u32]) {
        for &id in ids {
            crate::simd::prefetch_row(self.code_slice(id));
        }
    }

    fn decode(&self, id: u32) -> Vec<f32> {
        let i = id as usize;
        let (d, l) = (self.delta[i], self.lo[i]);
        let codes = self.code_slice(id);
        let mut out = Vec::with_capacity(self.dim);
        if self.bits == 8 {
            for (j, &c) in codes.iter().enumerate() {
                out.push(self.mean[j] + c as f32 * d + l);
            }
        } else {
            for (b, byte) in codes.iter().enumerate() {
                let j = b * 2;
                out.push(self.mean[j] + (byte & 0x0F) as f32 * d + l);
                if j + 1 < self.dim {
                    out.push(self.mean[j + 1] + (byte >> 4) as f32 * d + l);
                }
            }
        }
        out
    }

    fn write_bytes(&self, out: &mut Vec<u8>) -> usize {
        let kind = if self.bits == 8 {
            Compression::Lvq8
        } else {
            Compression::Lvq4
        };
        bin::put_u8(out, kind.code());
        self.write_fields(out)
    }

    fn append_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim);
        // centered against the *frozen* global mean: the mean is part of
        // the learned representation, so existing codes stay valid
        let one = [row.to_vec()];
        let chunk = encode_rows(&one, &self.mean, self.bits, self.stride());
        self.codes.make_owned().extend_from_slice(&chunk.codes);
        self.delta.make_owned().extend_from_slice(&chunk.delta);
        self.lo.make_owned().extend_from_slice(&chunk.lo);
        self.norms_sq.make_owned().extend_from_slice(&chunk.norms_sq);
    }

    fn compact(&mut self, keep: &[u32]) {
        let stride = self.stride();
        compact_flat(self.codes.make_owned(), stride, keep);
        compact_scalars(self.delta.make_owned(), keep);
        compact_scalars(self.lo.make_owned(), keep);
        compact_scalars(self.norms_sq.make_owned(), keep);
    }

    fn check_invariants(&self, out: &mut Vec<crate::util::invariants::Violation>) {
        self.check_level("lvq", out);
    }
}

/// Two-level LVQ4x8: 4-bit primary codes plus an 8-bit quantization of
/// the residual. `score()` reads only the first level (that is what
/// graph traversal touches); `decode()`/`score_full()` add the residual.
pub struct Lvq4x8Store {
    first: LvqStore,
    /// residual codes, 1 byte per component
    res_codes: Arr<u8>,
    res_delta: Arr<f32>,
    res_lo: Arr<f32>,
    full_norms_sq: Arr<f32>,
}

impl Lvq4x8Store {
    pub fn new(rows: &[Vec<f32>]) -> Lvq4x8Store {
        Self::new_threads(rows, 1)
    }

    /// Parallel two-level build: the 4-bit primary level is encoded in
    /// parallel chunks, then each chunk's 8-bit residual quantization
    /// runs in parallel too (per-row work again — bit-identical to the
    /// serial build).
    pub fn new_threads(rows: &[Vec<f32>], threads: usize) -> Lvq4x8Store {
        let threads = crate::util::threadpool::resolve_threads(threads);
        let first = LvqStore::new_threads(rows, 4, threads);
        let dim = first.dim();
        let mut res_codes = Vec::with_capacity(rows.len() * dim);
        let mut res_delta = Vec::with_capacity(rows.len());
        let mut res_lo = Vec::with_capacity(rows.len());
        let mut full_norms_sq = Vec::with_capacity(rows.len());

        let parts = parallel_chunked(rows.len(), threads, |start, end| {
            let mut out = EncodedChunk {
                codes: Vec::with_capacity((end - start) * dim),
                delta: Vec::with_capacity(end - start),
                lo: Vec::with_capacity(end - start),
                norms_sq: Vec::with_capacity(end - start),
            };
            let mut resid = vec![0.0f32; dim];
            for (i, r) in rows[start..end].iter().enumerate() {
                let dec = first.decode((start + i) as u32);
                for ((rv, &x), &xh) in resid.iter_mut().zip(r.iter()).zip(dec.iter()) {
                    *rv = x - xh;
                }
                let (c, d, l) = quantize(&resid, 256);
                let mut ns = 0.0f32;
                for (j, &cj) in c.iter().enumerate() {
                    let v = dec[j] + cj as f32 * d + l;
                    ns += v * v;
                }
                out.norms_sq.push(ns);
                out.codes.extend_from_slice(&c);
                out.delta.push(d);
                out.lo.push(l);
            }
            out
        });
        for p in parts {
            res_codes.extend_from_slice(&p.codes);
            res_delta.extend_from_slice(&p.delta);
            res_lo.extend_from_slice(&p.lo);
            full_norms_sq.extend_from_slice(&p.norms_sq);
        }
        Lvq4x8Store {
            first,
            res_codes: res_codes.into(),
            res_delta: res_delta.into(),
            res_lo: res_lo.into(),
            full_norms_sq: full_norms_sq.into(),
        }
    }

    /// Deserialize a two-level payload written by this store's
    /// [`ScoreStore::write_bytes`] (after the compression code byte).
    pub(crate) fn read_bytes(cur: &mut bin::Cursor) -> std::io::Result<Lvq4x8Store> {
        Self::read_bytes_src(cur, None)
    }

    /// [`Lvq4x8Store::read_bytes`], borrowing arrays from a mapped
    /// snapshot when `src` is given.
    pub(crate) fn read_bytes_src(
        cur: &mut bin::Cursor,
        src: Option<&SectionSrc>,
    ) -> std::io::Result<Lvq4x8Store> {
        let first = LvqStore::read_fields(cur, src)?;
        if first.bits != 4 {
            return Err(corrupt("lvq4x8 store: first level is not 4-bit"));
        }
        let res_codes = mmap::get_bytes_arr(cur, src)?;
        let res_delta = mmap::get_f32s_arr(cur, src)?;
        let res_lo = mmap::get_f32s_arr(cur, src)?;
        let full_norms_sq = mmap::get_f32s_arr(cur, src)?;
        let (n, dim) = (first.len(), first.dim());
        if res_codes.len() != n * dim
            || res_delta.len() != n
            || res_lo.len() != n
            || full_norms_sq.len() != n
        {
            return Err(corrupt("lvq4x8 store: residual length mismatch"));
        }
        Ok(Lvq4x8Store {
            first,
            res_codes,
            res_delta,
            res_lo,
            full_norms_sq,
        })
    }

    /// Score with both levels (re-ranking accuracy): one fused
    /// residual-combine kernel reads the 4-bit primary and 8-bit
    /// residual codes against the same query.
    pub fn score_full(&self, pq: &PreparedQuery, id: u32) -> f32 {
        let i = id as usize;
        let dim = self.first.dim();
        let res = &self.res_codes[i * dim..(i + 1) * dim];
        let (dot4, dot8) = crate::simd::dot_u4_u8(self.first.code_slice(id), res, &pq.q);
        let ip_first = self.first.delta[i] * dot4 + self.first.lo[i] * pq.q_sum + pq.q_mu;
        let ip_res = self.res_delta[i] * dot8 + self.res_lo[i] * pq.q_sum;
        finish_score(ip_first + ip_res, self.full_norms_sq[i], pq.sim)
    }
}

impl ScoreStore for Lvq4x8Store {
    fn len(&self) -> usize {
        self.first.len()
    }

    fn dim(&self) -> usize {
        self.first.dim()
    }

    /// Traversal traffic = first level only (the residual bytes are not
    /// touched during graph search); re-rank traffic is reported by
    /// [`ScoreStore::rerank_bytes_per_vector`].
    fn bytes_per_vector(&self) -> usize {
        self.first.bytes_per_vector()
    }

    /// Re-rank traffic: first level + residual codes + the residual's
    /// per-vector `delta`/`lo` constants — what `score_full`/`decode`
    /// actually read.
    fn rerank_bytes_per_vector(&self) -> usize {
        self.first.bytes_per_vector() + self.first.dim() + 8
    }

    fn prepare(&self, q: &[f32], sim: Similarity) -> PreparedQuery {
        self.first.prepare(q, sim)
    }

    fn score(&self, pq: &PreparedQuery, id: u32) -> f32 {
        self.first.score(pq, id)
    }

    /// Traversal reads only the first level — delegate to its blocked
    /// (prefetching) implementation.
    fn score_block(&self, pq: &PreparedQuery, ids: &[u32], out: &mut Vec<f32>) {
        self.first.score_block(pq, ids, out);
    }

    /// Traversal touches only the first level, so only its code rows
    /// are worth prefetching ahead of a hop.
    fn prefetch_rows(&self, ids: &[u32]) {
        self.first.prefetch_rows(ids);
    }

    /// Re-ranking reads both levels.
    fn score_rerank(&self, pq: &PreparedQuery, id: u32) -> f32 {
        self.score_full(pq, id)
    }

    /// Blocked two-level re-ranking: prefetch the next row's primary
    /// *and* residual code bytes, then run the fused residual-combine
    /// kernel on the current row.
    fn score_rerank_block(&self, pq: &PreparedQuery, ids: &[u32], out: &mut Vec<f32>) {
        let stride = self.first.stride();
        let dim = self.first.dim();
        super::blocked_scores(
            ids,
            out,
            |next| {
                let n = next as usize;
                crate::simd::prefetch(&self.first.codes[n * stride..]);
                crate::simd::prefetch(&self.res_codes[n * dim..]);
            },
            |id| self.score_full(pq, id),
        );
    }

    fn decode(&self, id: u32) -> Vec<f32> {
        let i = id as usize;
        let dim = self.first.dim();
        let res = &self.res_codes[i * dim..(i + 1) * dim];
        let mut out = self.first.decode(id);
        for (j, v) in out.iter_mut().enumerate() {
            *v += res[j] as f32 * self.res_delta[i] + self.res_lo[i];
        }
        out
    }

    fn write_bytes(&self, out: &mut Vec<u8>) -> usize {
        bin::put_u8(out, Compression::Lvq4x8.code());
        let anchor = self.first.write_fields(out);
        bin::put_bytes(out, &self.res_codes);
        bin::put_f32s(out, &self.res_delta);
        bin::put_f32s(out, &self.res_lo);
        bin::put_f32s(out, &self.full_norms_sq);
        anchor
    }

    fn append_row(&mut self, row: &[f32]) {
        let dim = self.first.dim();
        self.first.append_row(row);
        let id = (self.first.len() - 1) as u32;
        // second level: 8-bit quantization of the first-level residual,
        // exactly as the batch constructor computes it
        let dec = self.first.decode(id);
        let resid: Vec<f32> = row.iter().zip(dec.iter()).map(|(&x, &xh)| x - xh).collect();
        let (c, d, l) = quantize(&resid, 256);
        let mut ns = 0.0f32;
        for (j, &cj) in c.iter().enumerate() {
            let v = dec[j] + cj as f32 * d + l;
            ns += v * v;
        }
        debug_assert_eq!(c.len(), dim);
        self.res_codes.make_owned().extend_from_slice(&c);
        self.res_delta.make_owned().push(d);
        self.res_lo.make_owned().push(l);
        self.full_norms_sq.make_owned().push(ns);
    }

    fn compact(&mut self, keep: &[u32]) {
        let dim = self.first.dim();
        self.first.compact(keep);
        compact_flat(self.res_codes.make_owned(), dim, keep);
        compact_scalars(self.res_delta.make_owned(), keep);
        compact_scalars(self.res_lo.make_owned(), keep);
        compact_scalars(self.full_norms_sq.make_owned(), keep);
    }

    fn check_invariants(&self, out: &mut Vec<crate::util::invariants::Violation>) {
        use crate::util::invariants::{check_finite, Violation};
        self.first.check_level("lvq4x8 first level", out);
        let (n, dim) = (self.first.len(), self.first.dim());
        if self.res_codes.len() != n * dim
            || self.res_delta.len() != n
            || self.res_lo.len() != n
            || self.full_norms_sq.len() != n
        {
            out.push(Violation::new(
                "store",
                "payload-size-mismatch",
                format!(
                    "lvq4x8 residual: codes/delta/lo/norms lengths \
                     {}/{}/{}/{} disagree with {n} rows x {dim} dims",
                    self.res_codes.len(),
                    self.res_delta.len(),
                    self.res_lo.len(),
                    self.full_norms_sq.len()
                ),
            ));
        }
        if let Some((i, d)) = self
            .res_delta
            .iter()
            .enumerate()
            .find(|(_, d)| !d.is_finite() || **d <= 0.0)
        {
            out.push(Violation::new(
                "store",
                "scale-not-positive",
                format!("lvq4x8 residual: delta[{i}] = {d}"),
            ));
        }
        check_finite(out, "store", "res_lo", &self.res_lo);
        check_finite(out, "store", "full_norms_sq", &self.full_norms_sq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    fn rel_err(a: f32, b: f32, scale: f32) -> f32 {
        (a - b).abs() / scale.max(1e-6)
    }

    #[test]
    fn lvq8_decode_error_small() {
        let rs = rows(50, 64, 1);
        let store = LvqStore::new(&rs, 8);
        for (i, r) in rs.iter().enumerate() {
            let dec = store.decode(i as u32);
            let range: f32 = r.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            for (a, b) in dec.iter().zip(r.iter()) {
                assert!(rel_err(*a, *b, range) < 0.02, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn lvq8_score_matches_decode_dot() {
        let rs = rows(30, 48, 2);
        let store = LvqStore::new(&rs, 8);
        let q: Vec<f32> = rows(1, 48, 3).pop().unwrap();
        let pq = store.prepare(&q, Similarity::InnerProduct);
        for i in 0..30u32 {
            let via_score = store.score(&pq, i);
            let via_decode = dot(&q, &store.decode(i));
            assert!(
                (via_score - via_decode).abs() < 1e-3,
                "{via_score} vs {via_decode}"
            );
        }
    }

    #[test]
    fn lvq8_approximates_true_ip() {
        let rs = rows(100, 96, 4);
        let store = LvqStore::new(&rs, 8);
        let q: Vec<f32> = rows(1, 96, 5).pop().unwrap();
        let pq = store.prepare(&q, Similarity::InnerProduct);
        for (i, r) in rs.iter().enumerate() {
            let truth = dot(&q, r);
            let approx = store.score(&pq, i as u32);
            assert!((truth - approx).abs() < 0.25, "{truth} vs {approx}");
        }
    }

    #[test]
    fn lvq4_coarser_than_lvq8() {
        let rs = rows(60, 64, 6);
        let s8 = LvqStore::new(&rs, 8);
        let s4 = LvqStore::new(&rs, 4);
        let q: Vec<f32> = rows(1, 64, 7).pop().unwrap();
        let (p8, p4) = (
            s8.prepare(&q, Similarity::InnerProduct),
            s4.prepare(&q, Similarity::InnerProduct),
        );
        let (mut err8, mut err4) = (0.0f64, 0.0f64);
        for (i, r) in rs.iter().enumerate() {
            let truth = dot(&q, r) as f64;
            err8 += (truth - s8.score(&p8, i as u32) as f64).abs();
            err4 += (truth - s4.score(&p4, i as u32) as f64).abs();
        }
        assert!(err4 > err8, "lvq4 {err4} should be coarser than lvq8 {err8}");
        assert!(s4.bytes_per_vector() < s8.bytes_per_vector());
    }

    #[test]
    fn lvq4_packing_roundtrip_odd_dim() {
        let rs = rows(10, 33, 8); // odd dim exercises nibble tail
        let store = LvqStore::new(&rs, 4);
        for (i, r) in rs.iter().enumerate() {
            let dec = store.decode(i as u32);
            assert_eq!(dec.len(), 33);
            let range: f32 = r.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            for (a, b) in dec.iter().zip(r.iter()) {
                assert!(rel_err(*a, *b, range) < 0.2);
            }
        }
    }

    #[test]
    fn lvq4x8_decode_better_than_lvq4() {
        let rs = rows(40, 32, 9);
        let two = Lvq4x8Store::new(&rs);
        let one = LvqStore::new(&rs, 4);
        let (mut e2, mut e1) = (0.0f64, 0.0f64);
        for (i, r) in rs.iter().enumerate() {
            for (a, b) in two.decode(i as u32).iter().zip(r.iter()) {
                e2 += (a - b).abs() as f64;
            }
            for (a, b) in one.decode(i as u32).iter().zip(r.iter()) {
                e1 += (a - b).abs() as f64;
            }
        }
        assert!(e2 < e1 * 0.2, "two-level {e2} vs one-level {e1}");
    }

    #[test]
    fn lvq4x8_score_full_better_than_first_level() {
        let rs = rows(60, 48, 10);
        let store = Lvq4x8Store::new(&rs);
        let q: Vec<f32> = rows(1, 48, 11).pop().unwrap();
        let pq = store.prepare(&q, Similarity::InnerProduct);
        let (mut ef, mut e1) = (0.0f64, 0.0f64);
        for (i, r) in rs.iter().enumerate() {
            let truth = dot(&q, r) as f64;
            ef += (truth - store.score_full(&pq, i as u32) as f64).abs();
            e1 += (truth - store.score(&pq, i as u32) as f64).abs();
        }
        assert!(ef < e1, "full {ef} vs first {e1}");
    }

    #[test]
    fn l2_similarity_ranks_by_distance() {
        let rs = rows(80, 24, 12);
        let store = LvqStore::new(&rs, 8);
        let q: Vec<f32> = rows(1, 24, 13).pop().unwrap();
        let pq = store.prepare(&q, Similarity::L2);
        // top-1 by LVQ-L2 score must be among true top-5
        let mut best = (0usize, f32::NEG_INFINITY);
        for i in 0..80u32 {
            let s = store.score(&pq, i);
            if s > best.1 {
                best = (i as usize, s);
            }
        }
        let mut true_order: Vec<usize> = (0..80).collect();
        true_order.sort_by(|&a, &b| {
            crate::linalg::matrix::l2_sq(&q, &rs[a])
                .partial_cmp(&crate::linalg::matrix::l2_sq(&q, &rs[b]))
                .unwrap()
        });
        assert!(true_order[..5].contains(&best.0));
    }

    #[test]
    fn constant_vector_quantizes_exactly() {
        let rs = vec![vec![0.5f32; 16], vec![-0.25f32; 16]];
        let store = LvqStore::new(&rs, 8);
        for (i, r) in rs.iter().enumerate() {
            let dec = store.decode(i as u32);
            for (a, b) in dec.iter().zip(r.iter()) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn parallel_encoding_bit_identical_to_serial() {
        // span several encode chunks so the parallel path really fans out
        let rs = rows(700, 33, 15);
        for bits in [4u8, 8u8] {
            let serial = LvqStore::new(&rs, bits);
            let parallel = LvqStore::new_threads(&rs, bits, 4);
            assert_eq!(serial.codes, parallel.codes, "bits {bits}");
            assert_eq!(serial.delta, parallel.delta);
            assert_eq!(serial.lo, parallel.lo);
            assert_eq!(serial.norms_sq, parallel.norms_sq);
        }
        let s2 = Lvq4x8Store::new(&rs);
        let p2 = Lvq4x8Store::new_threads(&rs, 4);
        assert_eq!(s2.first.codes, p2.first.codes);
        assert_eq!(s2.res_codes, p2.res_codes);
        assert_eq!(s2.res_delta, p2.res_delta);
        assert_eq!(s2.res_lo, p2.res_lo);
        assert_eq!(s2.full_norms_sq, p2.full_norms_sq);
    }

    #[test]
    fn rerank_bytes_exceed_traversal_bytes_for_two_level() {
        let rs = rows(10, 32, 16);
        let two = Lvq4x8Store::new(&rs);
        assert!(two.rerank_bytes_per_vector() > two.bytes_per_vector());
        assert_eq!(
            two.rerank_bytes_per_vector(),
            two.bytes_per_vector() + 32 + 8
        );
        // single-level stores: rerank traffic == traversal traffic
        let one = LvqStore::new(&rs, 8);
        assert_eq!(one.rerank_bytes_per_vector(), one.bytes_per_vector());
    }

    #[test]
    fn score_rerank_uses_both_levels() {
        let rs = rows(40, 24, 17);
        let store = Lvq4x8Store::new(&rs);
        let q: Vec<f32> = rows(1, 24, 18).pop().unwrap();
        let pq = store.prepare(&q, Similarity::InnerProduct);
        for i in 0..40u32 {
            assert_eq!(store.score_rerank(&pq, i), store.score_full(&pq, i));
        }
    }

    #[test]
    fn write_read_roundtrip_bit_identical() {
        let rs = rows(60, 33, 20); // odd dim exercises the nibble tail
        let q: Vec<f32> = rows(1, 33, 21).pop().unwrap();
        let stores: [Box<dyn ScoreStore>; 3] = [
            Box::new(LvqStore::new(&rs, 4)),
            Box::new(LvqStore::new(&rs, 8)),
            Box::new(Lvq4x8Store::new(&rs)),
        ];
        for store in stores {
            let mut buf = Vec::new();
            store.write_bytes(&mut buf);
            let mut cur = crate::data::io::bin::Cursor::new(&buf);
            let back = crate::quant::read_store(&mut cur).unwrap();
            assert_eq!(cur.remaining(), 0);
            assert_eq!(back.len(), store.len());
            assert_eq!(back.dim(), store.dim());
            assert_eq!(back.bytes_per_vector(), store.bytes_per_vector());
            assert_eq!(back.rerank_bytes_per_vector(), store.rerank_bytes_per_vector());
            let (pa, pb) = (
                store.prepare(&q, Similarity::InnerProduct),
                back.prepare(&q, Similarity::InnerProduct),
            );
            for i in 0..store.len() as u32 {
                assert_eq!(store.score(&pa, i).to_bits(), back.score(&pb, i).to_bits());
                assert_eq!(
                    store.score_rerank(&pa, i).to_bits(),
                    back.score_rerank(&pb, i).to_bits()
                );
                assert_eq!(store.decode(i), back.decode(i));
            }
        }
    }

    #[test]
    fn read_rejects_inconsistent_payload() {
        let rs = rows(8, 16, 22);
        let store = LvqStore::new(&rs, 8);
        let mut buf = Vec::new();
        store.write_bytes(&mut buf);
        // truncation mid-payload -> UnexpectedEof, never a panic
        for cut in [1usize, 6, buf.len() / 2, buf.len() - 1] {
            let mut cur = crate::data::io::bin::Cursor::new(&buf[..cut]);
            assert!(crate::quant::read_store(&mut cur).is_err(), "cut {cut}");
        }
        // wrong compression code vs stored bit width -> InvalidData
        let mut wrong = buf.clone();
        wrong[0] = Compression::Lvq4.code();
        let mut cur = crate::data::io::bin::Cursor::new(&wrong);
        match crate::quant::read_store(&mut cur) {
            Err(err) => assert_eq!(err.kind(), std::io::ErrorKind::InvalidData),
            Ok(_) => panic!("mismatched code byte must fail"),
        }
    }

    /// One boxed store of every kind over `rs` (the five live-mutation
    /// arms).
    fn all_kinds(rs: &[Vec<f32>]) -> Vec<Box<dyn ScoreStore>> {
        vec![
            Box::new(crate::quant::F32Store::from_rows(rs)),
            Box::new(crate::quant::F16Store::from_rows(rs)),
            Box::new(LvqStore::new(rs, 4)),
            Box::new(LvqStore::new(rs, 8)),
            Box::new(Lvq4x8Store::new(rs)),
        ]
    }

    #[test]
    fn append_row_scores_self_consistently_all_kinds() {
        let base = rows(50, 24, 30);
        let extra = rows(10, 24, 31);
        let q: Vec<f32> = rows(1, 24, 32).pop().unwrap();
        for mut store in all_kinds(&base) {
            for r in &extra {
                store.append_row(r);
            }
            assert_eq!(store.len(), 60);
            let pq = store.prepare(&q, Similarity::InnerProduct);
            for (i, r) in extra.iter().enumerate() {
                let id = (50 + i) as u32;
                let dec = store.decode(id);
                // appended rows decode close to the original...
                let range: f32 = r.iter().fold(0.1f32, |m, &v| m.max(v.abs()));
                for (a, b) in dec.iter().zip(r.iter()) {
                    assert!(rel_err(*a, *b, range) < 0.2, "{a} vs {b}");
                }
                // ...and score consistently with their own decode
                let via_score = store.score(&pq, id);
                let via_decode = dot(&q, &dec);
                assert!(
                    (via_score - via_decode).abs() < 0.05 * (1.0 + via_decode.abs()),
                    "{via_score} vs {via_decode}"
                );
            }
        }
    }

    #[test]
    fn append_row_bit_identical_to_batch_for_fixed_constants() {
        // stores whose encoding has no dataset-level state (f32/f16) and
        // LVQ with an explicitly shared mean: appending one-by-one must
        // reproduce the batch construction bit-for-bit
        let all = rows(40, 16, 33);
        let (head, tail) = all.split_at(30);
        let q: Vec<f32> = rows(1, 16, 34).pop().unwrap();
        let mean = compute_mean(&all, 16);
        let pairs: Vec<(Box<dyn ScoreStore>, Box<dyn ScoreStore>)> = vec![
            (
                Box::new(crate::quant::F32Store::from_rows(&all)),
                Box::new(crate::quant::F32Store::from_rows(head)),
            ),
            (
                Box::new(crate::quant::F16Store::from_rows(&all)),
                Box::new(crate::quant::F16Store::from_rows(head)),
            ),
            (
                Box::new(LvqStore::with_mean(&all, 8, Some(mean.clone()))),
                Box::new(LvqStore::with_mean(head, 8, Some(mean.clone()))),
            ),
            (
                Box::new(LvqStore::with_mean(&all, 4, Some(mean.clone()))),
                Box::new(LvqStore::with_mean(head, 4, Some(mean))),
            ),
        ];
        for (batch, mut grown) in pairs {
            for r in tail {
                grown.append_row(r);
            }
            assert_eq!(grown.len(), batch.len());
            let (pa, pb) = (
                batch.prepare(&q, Similarity::L2),
                grown.prepare(&q, Similarity::L2),
            );
            for i in 0..batch.len() as u32 {
                assert_eq!(batch.score(&pa, i).to_bits(), grown.score(&pb, i).to_bits());
                assert_eq!(batch.decode(i), grown.decode(i));
            }
        }
    }

    #[test]
    fn compact_preserves_survivors_bitwise_all_kinds() {
        let rs = rows(60, 17, 35); // odd dim exercises the nibble tail
        let q: Vec<f32> = rows(1, 17, 36).pop().unwrap();
        let keep: Vec<u32> = (0..60u32).filter(|i| i % 3 != 1).collect();
        for (reference, mut store) in all_kinds(&rs).into_iter().zip(all_kinds(&rs)) {
            store.compact(&keep);
            assert_eq!(store.len(), keep.len());
            assert_eq!(store.dim(), 17);
            let (pa, pb) = (
                reference.prepare(&q, Similarity::InnerProduct),
                store.prepare(&q, Similarity::InnerProduct),
            );
            for (new_id, &old_id) in keep.iter().enumerate() {
                let new_id = new_id as u32;
                assert_eq!(
                    reference.score(&pa, old_id).to_bits(),
                    store.score(&pb, new_id).to_bits()
                );
                assert_eq!(
                    reference.score_rerank(&pa, old_id).to_bits(),
                    store.score_rerank(&pb, new_id).to_bits()
                );
                assert_eq!(reference.decode(old_id), store.decode(new_id));
            }
        }
    }

    #[test]
    fn compression_ratios_match_paper() {
        // D=768: FP16 = 1536 B; LVQ8 ~ 776 B (~2x); LVQ4 ~ 392 B (~4x)
        let rs = rows(4, 768, 14);
        let f16b = crate::quant::F16Store::from_rows(&rs).bytes_per_vector() as f64;
        let l8 = LvqStore::new(&rs, 8).bytes_per_vector() as f64;
        let l4 = LvqStore::new(&rs, 4).bytes_per_vector() as f64;
        assert!((f16b / l8 - 2.0).abs() < 0.1, "{}", f16b / l8);
        assert!((f16b / l4 - 4.0).abs() < 0.25, "{}", f16b / l4);
    }
}
