//! In-repo static analysis: the `leanvec-lint` rule engine.
//!
//! A deliberately small line/token scanner — no external parser, no
//! proc-macro machinery (the offline build vendors only `anyhow` and
//! the `xla` stub) — that enforces the repo's correctness conventions
//! over `rust/src` as CI-gated diagnostics:
//!
//! * every `unsafe` block/fn/impl is preceded by a `// SAFETY:`
//!   comment arguing why its preconditions hold;
//! * no `.unwrap()` / `.expect(` / `panic!` on the serve path
//!   (`coordinator/`, `shard/`, `index/`, `graph/`, `quant/`,
//!   `simd/`, `mutate/`, `util/mmap.rs`) outside `#[cfg(test)]`;
//! * float score ordering uses `total_cmp` — `partial_cmp` is banned
//!   on the serve path (NaN-poisoned comparators panic or, worse,
//!   silently misorder);
//! * every `Ordering::Relaxed` carries a `// ORDERING:` justification;
//! * no `std::time::Instant` inside the SIMD kernels (timing belongs
//!   in the harness, not per-call in a scoring loop) and no `println!`
//!   outside `main.rs` / `bin/` (library output goes through returned
//!   values; stray stdout corrupts machine-readable CLI output);
//! * metric names registered in `obs/` follow
//!   `leanvec_<subsystem>_<name>_<unit>` ([`metric_name_ok`]), so the
//!   exposition stays greppable and Prometheus-conventional;
//! * blocking waits on the request loop (`coordinator/`, `shard/`) —
//!   `.recv()`, `.lock(`, `.join()`, `.wait(` — either use the
//!   timeout-aware form (`recv_timeout`, `try_lock`, `wait_timeout`)
//!   or carry a `// DEADLINE:` comment arguing why the wait is
//!   bounded; an unannotated indefinite wait on that path is how a
//!   single stuck shard turns into a whole-engine hang.
//!
//! The scanner is token-ish, not a full lexer: it strips comments,
//! string/char literals, and tracks `#[cfg(test)]` regions by brace
//! depth, which is exactly enough to make the rules above
//! reliable on this codebase. Suppression is explicit and auditable:
//! a repo-level allowlist file (rule + path per line) for whole-file
//! waivers, and inline `lint:allow(rule-name)` markers in a comment on
//! or immediately above the flagged line for per-site waivers — both
//! are expected to carry a reason.

use std::collections::HashSet;
use std::fmt;
use std::path::Path;

/// The enforced rule set. `name()` is the stable identifier used in
/// diagnostics, allowlist entries, and inline `lint:allow(...)`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Rule {
    /// `unsafe` without a preceding `// SAFETY:` argument.
    UnsafeNeedsSafety,
    /// `.unwrap()` / `.expect(` / `panic!` on the serve path.
    ServePathPanic,
    /// `partial_cmp` on the serve path (use `total_cmp`).
    ServePathPartialCmp,
    /// `Ordering::Relaxed` without a `// ORDERING:` justification.
    RelaxedNeedsOrdering,
    /// `std::time::Instant` inside the SIMD kernel layer.
    InstantInKernel,
    /// `println!` outside `main.rs` / `bin/`.
    PrintlnOutsideCli,
    /// Metric registered in `obs/` whose name breaks the
    /// `leanvec_<subsystem>_<name>_<unit>` convention.
    ObsMetricName,
    /// Blocking wait (`.recv()` / `.lock(` / `.join()` / `.wait(`) on
    /// the request loop without a timeout-aware form or a
    /// `// DEADLINE:` justification that the wait is bounded.
    UnboundedWaitOnServePath,
}

pub const ALL_RULES: [Rule; 8] = [
    Rule::UnsafeNeedsSafety,
    Rule::ServePathPanic,
    Rule::ServePathPartialCmp,
    Rule::RelaxedNeedsOrdering,
    Rule::InstantInKernel,
    Rule::PrintlnOutsideCli,
    Rule::ObsMetricName,
    Rule::UnboundedWaitOnServePath,
];

impl Rule {
    pub fn name(&self) -> &'static str {
        match self {
            Rule::UnsafeNeedsSafety => "unsafe-safety-comment",
            Rule::ServePathPanic => "serve-path-panic",
            Rule::ServePathPartialCmp => "serve-path-partial-cmp",
            Rule::RelaxedNeedsOrdering => "relaxed-ordering-comment",
            Rule::InstantInKernel => "instant-in-kernel",
            Rule::PrintlnOutsideCli => "println-outside-cli",
            Rule::ObsMetricName => "obs-metric-name",
            Rule::UnboundedWaitOnServePath => "serve-path-unbounded-wait",
        }
    }

    pub fn from_name(name: &str) -> Option<Rule> {
        ALL_RULES.iter().copied().find(|r| r.name() == name)
    }
}

/// One finding: repo-relative path, 1-based line, rule, message.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub path: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.path,
            self.line,
            self.rule.name(),
            self.message
        )
    }
}

/// Serve-path module prefixes (relative to `rust/src/`): the modules a
/// production request or mutation flows through, where a panic is an
/// outage rather than a bug report.
const SERVE_PREFIXES: [&str; 7] = [
    "coordinator/",
    "shard/",
    "index/",
    "graph/",
    "quant/",
    "simd/",
    "mutate/",
];

fn is_serve_path(rel: &str) -> bool {
    SERVE_PREFIXES.iter().any(|p| rel.starts_with(p)) || rel == "util/mmap.rs"
}

fn is_kernel_path(rel: &str) -> bool {
    rel.starts_with("simd/")
}

/// The request loop proper: the threads that hold a live query or
/// mutation while they wait. A blocking primitive here must be
/// timeout-aware or carry a `// DEADLINE:` argument — these are the
/// only modules where an indefinite wait wedges client requests
/// rather than a background job.
fn is_request_loop_path(rel: &str) -> bool {
    rel.starts_with("coordinator/") || rel.starts_with("shard/")
}

/// Blocking call sites the `serve-path-unbounded-wait` rule inspects.
/// Plain-substring matched (the leading dot rules out free functions;
/// `has_token` would reject method receivers). The timeout-aware
/// forms — `recv_timeout`, `try_lock`, `wait_timeout` — don't contain
/// these spellings, so they pass without annotation. `.join()` is
/// matched with empty parens so `Path::join(arg)` stays exempt:
/// thread joins are always zero-arg.
const BLOCKING_TOKENS: [&str; 4] = [".recv()", ".lock(", ".join()", ".wait("];

/// `main.rs` and `bin/` entry points own stdout; everything else must
/// not print to it.
fn println_allowed(rel: &str) -> bool {
    rel == "main.rs" || rel.starts_with("bin/")
}

/// The metric-name convention the exposition layer promises:
/// `leanvec_<subsystem>_<name…>_<unit>` — all-lowercase alnum segments,
/// at least three of them, ending in a recognized unit. Shared by the
/// `obs-metric-name` lint rule and the obs catalog's own tests.
pub fn metric_name_ok(name: &str) -> bool {
    const UNITS: [&str; 6] = ["total", "seconds", "bytes", "ratio", "count", "info"];
    let segs: Vec<&str> = name.split('_').collect();
    segs.len() >= 3
        && segs[0] == "leanvec"
        && segs
            .iter()
            .all(|s| !s.is_empty() && s.bytes().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit()))
        && segs.last().is_some_and(|u| UNITS.contains(u))
}

/// Registration call sites the `obs-metric-name` rule inspects (leading
/// dot: method calls only, never the `Registry` definitions themselves).
const REGISTER_TOKENS: [&str; 6] = [
    ".register_counter(",
    ".register_gauge(",
    ".register_histogram(",
    ".register_counter_family(",
    ".register_gauge_family(",
    ".register_histogram_family(",
];

/// First plain `"…"` literal in a window of RAW source lines (the
/// lexer blanks string contents, so the rule reads the original text;
/// rustfmt often puts the name argument on the line after the call).
fn first_string_literal(raw_lines: &[&str]) -> Option<String> {
    for l in raw_lines {
        if let Some(start) = l.find('"') {
            let rest = &l[start + 1..];
            if let Some(end) = rest.find('"') {
                return Some(rest[..end].to_string());
            }
        }
    }
    None
}

/// One source line after lexical stripping: `code` has comments and
/// string/char-literal *contents* blanked to spaces (delimiters kept),
/// `comment` holds the text of any comment on the line, and `is_test`
/// marks lines inside a `#[cfg(test)]`-gated item.
struct ScanLine {
    code: String,
    comment: String,
    is_test: bool,
}

/// Lexical state carried across lines: nesting block comments, and
/// (rare but legal) string literals that span lines.
struct Lexer {
    block_depth: usize,
    in_str: bool,
    raw_hashes: Option<usize>,
}

impl Lexer {
    fn new() -> Lexer {
        Lexer {
            block_depth: 0,
            in_str: false,
            raw_hashes: None,
        }
    }

    /// Split one raw line into blanked code text + comment text.
    fn strip(&mut self, raw: &str) -> (String, String) {
        let b = raw.as_bytes();
        let mut code = String::with_capacity(raw.len());
        let mut comment = String::new();
        let mut i = 0;
        while i < b.len() {
            if self.block_depth > 0 {
                if b[i..].starts_with(b"*/") {
                    self.block_depth -= 1;
                    i += 2;
                } else if b[i..].starts_with(b"/*") {
                    self.block_depth += 1;
                    i += 2;
                } else {
                    comment.push(b[i] as char);
                    i += 1;
                }
                code.push(' ');
                continue;
            }
            if let Some(h) = self.raw_hashes {
                if b[i] == b'"' && b[i + 1..].len() >= h && b[i + 1..i + 1 + h].iter().all(|&c| c == b'#') {
                    self.raw_hashes = None;
                    code.push('"');
                    i += 1 + h;
                } else {
                    code.push(' ');
                    i += 1;
                }
                continue;
            }
            if self.in_str {
                if b[i] == b'\\' && i + 1 < b.len() {
                    code.push_str("  ");
                    i += 2;
                } else if b[i] == b'"' {
                    self.in_str = false;
                    code.push('"');
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
                continue;
            }
            match b[i] {
                b'/' if b[i..].starts_with(b"//") => {
                    comment.push_str(&raw[i..]);
                    break;
                }
                b'/' if b[i..].starts_with(b"/*") => {
                    self.block_depth += 1;
                    code.push(' ');
                    i += 2;
                }
                b'"' => {
                    self.in_str = true;
                    code.push('"');
                    i += 1;
                }
                b'r' | b'b' if raw_string_hashes(&b[i..]).is_some() => {
                    let (skip, hashes) = raw_string_hashes(&b[i..]).unwrap_or((1, 0));
                    self.raw_hashes = Some(hashes);
                    code.push('"');
                    i += skip;
                }
                b'b' if b[i + 1..].first() == Some(&b'"') => {
                    self.in_str = true;
                    code.push('"');
                    i += 2;
                }
                b'\'' => {
                    // char literal vs lifetime: 'x' / '\n' are literals,
                    // 'a in `&'a T` is a lifetime marker.
                    if let Some(adv) = char_literal_len(&b[i..]) {
                        code.push('\'');
                        for _ in 1..adv {
                            code.push(' ');
                        }
                        i += adv;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                }
                c => {
                    code.push(c as char);
                    i += 1;
                }
            }
        }
        (code, comment)
    }
}

/// If `b` starts a raw (possibly byte) string literal `r"`, `r#"`,
/// `br#"`…, return (bytes to skip to reach content, hash count).
fn raw_string_hashes(b: &[u8]) -> Option<(usize, usize)> {
    let mut i = 0;
    if b.first() == Some(&b'b') {
        i += 1;
    }
    if b.get(i) != Some(&b'r') {
        return None;
    }
    i += 1;
    let mut hashes = 0;
    while b.get(i + hashes) == Some(&b'#') {
        hashes += 1;
    }
    if b.get(i + hashes) == Some(&b'"') {
        Some((i + hashes + 1, hashes))
    } else {
        None
    }
}

/// Length in bytes of a char literal starting at a `'`, or `None` when
/// the quote starts a lifetime instead.
fn char_literal_len(b: &[u8]) -> Option<usize> {
    debug_assert_eq!(b.first(), Some(&b'\''));
    if b.get(1) == Some(&b'\\') {
        // escaped: scan to the closing quote (handles '\n', '\u{..}')
        let mut i = 2;
        while i < b.len() && i < 16 {
            if b[i] == b'\'' {
                return Some(i + 1);
            }
            i += 1;
        }
        return None;
    }
    // unescaped: one (possibly multi-byte) char then a closing quote
    let mut i = 2;
    while i < b.len() && i < 6 {
        if b[i] == b'\'' && i > 1 {
            // 'x' → 3 bytes; lifetimes ('a followed by non-quote) fall out
            return Some(i + 1);
        }
        if (b[i - 1] as char).is_ascii_whitespace() {
            return None;
        }
        i += 1;
    }
    None
}

/// Track `#[cfg(test)]`-gated regions by brace depth. The attribute
/// arms `pending`; the next `{` opens a test region that closes when
/// the depth returns to its opening level. A `;` before any `{`
/// disarms (attribute on a brace-less item).
struct TestTracker {
    depth: isize,
    pending: bool,
    regions: Vec<isize>,
}

impl TestTracker {
    fn new() -> TestTracker {
        TestTracker {
            depth: 0,
            pending: false,
            regions: Vec::new(),
        }
    }

    /// Feed one blanked code line; returns whether the line belongs to
    /// a test region.
    fn feed(&mut self, code: &str) -> bool {
        let has_attr = code.contains("#[cfg(test)]")
            || (code.contains("#[cfg(all(") && code.contains("test"));
        if has_attr {
            self.pending = true;
        }
        let started_inside = !self.regions.is_empty();
        for ch in code.chars() {
            match ch {
                '{' => {
                    if self.pending {
                        self.regions.push(self.depth);
                        self.pending = false;
                    }
                    self.depth += 1;
                }
                '}' => {
                    self.depth -= 1;
                    if let Some(&open) = self.regions.last() {
                        if self.depth <= open {
                            self.regions.pop();
                        }
                    }
                }
                ';' => {
                    if self.pending && self.regions.is_empty() {
                        self.pending = false;
                    }
                }
                _ => {}
            }
        }
        started_inside || !self.regions.is_empty() || has_attr || self.pending
    }
}

/// True when `tok` occurs in `code` as a standalone token (not a
/// substring of a longer identifier, e.g. `println!` inside
/// `eprintln!`).
fn has_token(code: &str, tok: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok) {
        let at = start + pos;
        let before_ok = at == 0
            || !code[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let after = at + tok.len();
        let after_ok = !tok
            .chars()
            .next_back()
            .is_some_and(|c| c.is_alphanumeric() || c == '_')
            || !code[after..]
                .chars()
                .next()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        start = after;
    }
    false
}

/// Does the comment on line `i`, or a comment in the contiguous run of
/// comment-only / attribute-only lines directly above it, contain
/// `needle`? This is how `// SAFETY:` / `// ORDERING:` /
/// `lint:allow(...)` attach to a flagged line.
fn nearby_comment_contains(lines: &[ScanLine], i: usize, needle: &str) -> bool {
    if lines[i].comment.contains(needle) {
        return true;
    }
    let mut j = i;
    let mut budget = 40; // arbitrary sanity bound on the walk-up
    while j > 0 && budget > 0 {
        j -= 1;
        budget -= 1;
        let t = lines[j].code.trim();
        let passthrough = t.is_empty() || t.starts_with("#[") || t.starts_with("#!");
        if lines[j].comment.contains(needle) {
            return true;
        }
        if !passthrough {
            return false;
        }
    }
    false
}

fn allow_marker(rule: Rule) -> String {
    // assembled at runtime so the scanner never matches its own source
    format!("lint:allow({})", rule.name())
}

/// Scan one file's source. `rel` is the path relative to the scan root
/// (`rust/src`), with forward slashes.
pub fn scan_file(rel: &str, source: &str) -> Vec<Diagnostic> {
    let serve = is_serve_path(rel);
    let kernel = is_kernel_path(rel);
    let cli = println_allowed(rel);
    let obs = rel.starts_with("obs/");
    let req_loop = is_request_loop_path(rel);
    let raw_lines: Vec<&str> = source.lines().collect();

    let mut lexer = Lexer::new();
    let mut tracker = TestTracker::new();
    let mut lines: Vec<ScanLine> = Vec::new();
    for raw in source.lines() {
        let (code, comment) = lexer.strip(raw);
        let is_test = tracker.feed(&code);
        lines.push(ScanLine {
            code,
            comment,
            is_test,
        });
    }

    let mut out = Vec::new();
    let mut push = |lines: &[ScanLine], i: usize, rule: Rule, msg: String| {
        if !nearby_comment_contains(lines, i, &allow_marker(rule)) {
            out.push(Diagnostic {
                path: rel.to_string(),
                line: i + 1,
                rule,
                message: msg,
            });
        }
    };

    for i in 0..lines.len() {
        let code = lines[i].code.as_str();
        if lines[i].is_test {
            continue;
        }
        if has_token(code, "unsafe") && !nearby_comment_contains(&lines, i, "SAFETY:") {
            push(
                &lines,
                i,
                Rule::UnsafeNeedsSafety,
                "`unsafe` without a `// SAFETY:` comment arguing its preconditions".into(),
            );
        }
        if serve {
            for pat in [".unwrap()", ".expect(", "panic!"] {
                if code.contains(pat) {
                    push(
                        &lines,
                        i,
                        Rule::ServePathPanic,
                        format!("`{pat}` on the serve path — return a typed error instead"),
                    );
                }
            }
            if code.contains("partial_cmp") {
                push(
                    &lines,
                    i,
                    Rule::ServePathPartialCmp,
                    "`partial_cmp` on the serve path — use `total_cmp` for float ordering".into(),
                );
            }
        }
        if req_loop {
            for pat in BLOCKING_TOKENS {
                if code.contains(pat) && !nearby_comment_contains(&lines, i, "DEADLINE:") {
                    push(
                        &lines,
                        i,
                        Rule::UnboundedWaitOnServePath,
                        format!(
                            "`{pat}` blocks the request loop without a bound — use the \
                             timeout-aware form or justify with a `// DEADLINE:` comment"
                        ),
                    );
                }
            }
        }
        if code.contains("Ordering::Relaxed")
            && !nearby_comment_contains(&lines, i, "ORDERING:")
        {
            push(
                &lines,
                i,
                Rule::RelaxedNeedsOrdering,
                "`Ordering::Relaxed` without a `// ORDERING:` justification".into(),
            );
        }
        if kernel && has_token(code, "Instant") {
            push(
                &lines,
                i,
                Rule::InstantInKernel,
                "`Instant` inside the kernel layer — time in the harness, not per call".into(),
            );
        }
        if !cli && has_token(code, "println!") {
            push(
                &lines,
                i,
                Rule::PrintlnOutsideCli,
                "`println!` outside main.rs/bin — stray stdout corrupts CLI output".into(),
            );
        }
        if obs && REGISTER_TOKENS.iter().any(|t| code.contains(t)) {
            let window = &raw_lines[i..raw_lines.len().min(i + 4)];
            match first_string_literal(window) {
                Some(name) if metric_name_ok(&name) => {}
                Some(name) => push(
                    &lines,
                    i,
                    Rule::ObsMetricName,
                    format!(
                        "metric name `{name}` breaks `leanvec_<subsystem>_<name>_<unit>` \
                         (unit: total|seconds|bytes|ratio|count|info)"
                    ),
                ),
                None => push(
                    &lines,
                    i,
                    Rule::ObsMetricName,
                    "metric registration without a string-literal name near the call".into(),
                ),
            }
        }
    }
    out
}

/// Whole-file waivers: `<rule-name> <path> [reason…]` per line, `#`
/// comments and blank lines ignored. Paths are relative to the scan
/// root (`rust/src`), forward slashes.
pub struct Allowlist {
    entries: HashSet<(String, String)>,
}

impl Allowlist {
    pub fn empty() -> Allowlist {
        Allowlist {
            entries: HashSet::new(),
        }
    }

    pub fn parse(text: &str) -> Result<Allowlist, String> {
        let mut entries = HashSet::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let (rule, path) = match (it.next(), it.next()) {
                (Some(r), Some(p)) => (r, p),
                _ => {
                    return Err(format!(
                        "allowlist line {}: expected `<rule> <path> [reason]`, got `{line}`",
                        ln + 1
                    ))
                }
            };
            if Rule::from_name(rule).is_none() {
                return Err(format!("allowlist line {}: unknown rule `{rule}`", ln + 1));
            }
            entries.insert((rule.to_string(), path.to_string()));
        }
        Ok(Allowlist { entries })
    }

    pub fn allows(&self, d: &Diagnostic) -> bool {
        self.entries
            .contains(&(d.rule.name().to_string(), d.path.clone()))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Recursively collect `.rs` files under `root`, returning
/// (relative-path, absolute-path) pairs sorted by relative path.
pub fn collect_sources(root: &Path) -> std::io::Result<Vec<(String, std::path::PathBuf)>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                let rel = p
                    .strip_prefix(root)
                    .unwrap_or(&p)
                    .to_string_lossy()
                    .replace('\\', "/");
                out.push((rel, p));
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Scan every `.rs` file under `root` (the repo's `rust/src`),
/// returning diagnostics sorted by (path, line).
pub fn scan_tree(root: &Path) -> std::io::Result<Vec<Diagnostic>> {
    let mut diags = Vec::new();
    for (rel, abs) in collect_sources(root)? {
        let source = std::fs::read_to_string(&abs)?;
        diags.extend(scan_file(&rel, &source));
    }
    diags.sort_by(|a, b| a.path.cmp(&b.path).then(a.line.cmp(&b.line)));
    Ok(diags)
}

/// Split diagnostics into (kept, allowlisted-count).
pub fn apply_allowlist(diags: Vec<Diagnostic>, allow: &Allowlist) -> (Vec<Diagnostic>, usize) {
    let before = diags.len();
    let kept: Vec<Diagnostic> = diags.into_iter().filter(|d| !allow.allows(d)).collect();
    let suppressed = before - kept.len();
    (kept, suppressed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_strings_and_comments() {
        let mut lx = Lexer::new();
        let (code, comment) = lx.strip(r#"let s = ".unwrap()"; // real comment"#);
        assert!(!code.contains(".unwrap()"));
        assert!(comment.contains("real comment"));
        assert!(code.contains("let s ="));
    }

    #[test]
    fn block_comments_span_lines() {
        let mut lx = Lexer::new();
        let (c1, _) = lx.strip("let a = 1; /* start");
        let (c2, m2) = lx.strip("still comment .unwrap()");
        let (c3, _) = lx.strip("end */ let b = 2;");
        assert!(c1.contains("let a"));
        assert!(!c2.contains(".unwrap()"));
        assert!(m2.contains(".unwrap()"));
        assert!(c3.contains("let b"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let mut lx = Lexer::new();
        let (code, _) = lx.strip("fn f<'a>(x: &'a str) { let c = 'u'; }");
        assert!(code.contains("<'a>"));
        assert!(!code.contains('u'), "char literal contents blanked: {code}");
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("println!(\"x\")", "println!"));
        assert!(!has_token("eprintln!(\"x\")", "println!"));
        assert!(has_token("unsafe {", "unsafe"));
        assert!(!has_token("unsafety", "unsafe"));
    }

    #[test]
    fn cfg_test_region_tracking() {
        let src = "fn live() { x.unwrap(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn t() { y.unwrap(); }\n\
                   }\n\
                   fn live2() { z.unwrap(); }\n";
        let d = scan_file("index/foo.rs", src);
        let lines: Vec<usize> = d.iter().map(|d| d.line).collect();
        assert_eq!(lines, vec![1, 6], "only non-test unwraps flagged: {d:?}");
    }

    #[test]
    fn safety_comment_walkup_through_attributes() {
        let ok = "// SAFETY: pointer is valid for len elements\n\
                  #[inline]\n\
                  unsafe fn f() {}\n";
        assert!(scan_file("util/x.rs", ok).is_empty());
        let bad = "#[inline]\nunsafe fn f() {}\n";
        let d = scan_file("util/x.rs", bad);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, Rule::UnsafeNeedsSafety);
    }

    #[test]
    fn relaxed_needs_ordering_comment() {
        let bad = "fn f(c: &AtomicUsize) { c.fetch_add(1, Ordering::Relaxed); }\n";
        assert_eq!(scan_file("util/x.rs", bad).len(), 1);
        let ok =
            "fn f(c: &AtomicUsize) { c.fetch_add(1, Ordering::Relaxed); // ORDERING: stat only\n}\n";
        assert!(scan_file("util/x.rs", ok).is_empty());
    }

    #[test]
    fn metric_name_convention() {
        assert!(metric_name_ok("leanvec_engine_queries_total"));
        assert!(metric_name_ok("leanvec_batcher_queue_wait_seconds"));
        assert!(metric_name_ok("leanvec_ingest_tombstone_ratio"));
        assert!(!metric_name_ok("engine_queries_total"), "missing prefix");
        assert!(!metric_name_ok("leanvec_queries"), "too few segments");
        assert!(!metric_name_ok("leanvec_engine_queries"), "bad unit");
        assert!(!metric_name_ok("leanvec_Engine_queries_total"), "case");
        assert!(!metric_name_ok("leanvec__queries_total"), "empty segment");
    }

    #[test]
    fn obs_metric_name_rule_fires_and_stays_quiet() {
        let bad = "fn f(r: &Registry) { let c = r.register_counter(\"bad_name\", \"h\"); }\n";
        let d = scan_file("obs/metrics.rs", bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, Rule::ObsMetricName);

        let ok = "fn f(r: &Registry) {\n    let c = r.register_counter(\n        \"leanvec_engine_queries_total\",\n        \"h\",\n    );\n}\n";
        assert!(
            scan_file("obs/metrics.rs", ok).is_empty(),
            "name on the rustfmt'd next line is found"
        );

        // same source outside obs/ is not this rule's business
        assert!(scan_file("coordinator/x.rs", bad).is_empty());

        // definitions (no leading dot) are not registrations
        let def = "impl Registry { pub fn register_counter(&self, name: &str) {} }\n";
        assert!(scan_file("obs/registry.rs", def).is_empty());
    }

    #[test]
    fn unbounded_wait_rule_fires_and_stays_quiet() {
        let bad = "fn f(rx: &Receiver<u32>) { let v = rx.recv(); }\n";
        let d = scan_file("coordinator/x.rs", bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, Rule::UnboundedWaitOnServePath);

        let ok =
            "fn f(rx: &Receiver<u32>) { let v = rx.recv(); // DEADLINE: shutdown closes tx\n}\n";
        assert!(scan_file("coordinator/x.rs", ok).is_empty());

        let above = "fn f(h: JoinHandle<()>) {\n\
                     // DEADLINE: worker exits once its channel closes\n\
                     h.join();\n\
                     }\n";
        assert!(scan_file("shard/x.rs", above).is_empty());

        let timed = "fn f(rx: &Receiver<u32>) { let v = rx.recv_timeout(d); }\n";
        assert!(scan_file("coordinator/x.rs", timed).is_empty());

        let path_join = "fn f(p: &Path) -> PathBuf { p.join(\"m\") }\n";
        assert!(
            scan_file("shard/x.rs", path_join).is_empty(),
            "Path::join takes an argument; thread joins are zero-arg"
        );

        // the rule polices only the request loop, not background jobs
        assert!(scan_file("util/x.rs", bad).is_empty());
        assert!(scan_file("mutate/x.rs", bad).is_empty());
    }

    #[test]
    fn allowlist_roundtrip() {
        let a = Allowlist::parse(
            "# comment\nserve-path-panic index/foo.rs lock poisoning is unreachable\n",
        )
        .unwrap();
        let d = Diagnostic {
            path: "index/foo.rs".into(),
            line: 3,
            rule: Rule::ServePathPanic,
            message: String::new(),
        };
        assert!(a.allows(&d));
        assert!(Allowlist::parse("bogus-rule x.rs\n").is_err());
    }
}
