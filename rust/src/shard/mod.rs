//! Sharded, multi-collection serving: the scale-out layer.
//!
//! One node stops being "one engine, one index" here. The layer stacks
//! three pieces:
//!
//! * [`sharded`] — [`ShardedIndex`]: external ids hash-partitioned
//!   across N shards (each a frozen [`LeanVecIndex`] or a live
//!   [`LiveIndex`]), searched by concurrent scatter-gather with a
//!   stats-merging top-k reduce, mutated by per-id hash routing with
//!   consolidation staggered one shard at a time. One projection model
//!   is trained over the full corpus and shared by every shard, so the
//!   engine's single batched query projection serves all of them.
//! * [`collection`] — [`Collection`] / [`CollectionRegistry`]: named
//!   tenants, each a `ShardedIndex` plus per-collection search defaults
//!   and admission quotas. The serving engine routes requests by
//!   collection name instead of holding one index.
//! * [`manifest`] — per-shard snapshot files plus a CRC'd routing
//!   manifest; [`ShardedIndex::save_dir`] / [`ShardedIndex::load_dir`]
//!   round-trip the whole layout bit-identically.
//!
//! [`LeanVecIndex`]: crate::index::LeanVecIndex
//! [`LiveIndex`]: crate::mutate::LiveIndex

pub mod collection;
pub mod manifest;
pub mod sharded;

pub use collection::{
    AdmissionCounters, Collection, CollectionRegistry, TenantQuota, DEFAULT_COLLECTION,
};
pub use manifest::{MANIFEST_MAGIC, MANIFEST_NAME, MANIFEST_VERSION};
pub use sharded::{
    merge_top_k, shard_of, ScatterTiming, ShardSpec, ShardedIndex, DEFAULT_HASH_SEED,
};
