//! [`ShardedIndex`]: hash-partitioned scatter-gather over N shards.
//!
//! External ids are routed to shards by a seeded splitmix hash
//! ([`shard_of`]); each shard is a complete index over its slice of the
//! corpus — a frozen [`LeanVecIndex`] or a mutable [`LiveIndex`] — and
//! a query fans out to every shard, takes per-shard top-k, and merges
//! by score ([`merge_top_k`]), summing the per-shard [`QueryStats`].
//! Because the partition is a uniform random sample of the corpus, each
//! shard's graph is smaller *and* needs a smaller search window for the
//! same merged recall — the scatter-gather batch-QPS win the e2e bench
//! records.
//!
//! All shards share ONE projection model: [`ShardedIndex::build`]
//! trains it over the full corpus ([`IndexBuilder::train_model`]) and
//! hands a clone to every per-shard build, so the serving engine's
//! single batched query projection `A q` stays valid across shards.

use crate::config::Similarity;
use crate::graph::beam::{CtxPool, SearchCtx};
use crate::index::builder::IndexBuilder;
use crate::index::leanvec_index::LeanVecIndex;
use crate::index::query::{Query, SearchResult, VectorIndex};
use crate::leanvec::model::LeanVecModel;
use crate::mutate::{ConsolidateReport, LiveIndex, MutateError};
use crate::util::cancel::CancelToken;
use std::sync::Arc;
use std::time::Instant;

/// Default shard-routing hash seed (persisted in the shard manifest).
pub const DEFAULT_HASH_SEED: u64 = 0x51AB_5EED;

/// Shard topology: how many shards and the routing-hash seed. Persisted
/// in the manifest so a reloaded index routes identically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// number of shards (>= 1)
    pub shards: usize,
    /// seed for the external-id routing hash
    pub hash_seed: u64,
}

impl ShardSpec {
    pub fn new(shards: usize) -> ShardSpec {
        ShardSpec {
            shards,
            hash_seed: DEFAULT_HASH_SEED,
        }
    }
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec::new(1)
    }
}

/// Which shard an external id lives on: a seeded splitmix64 finalizer
/// over the id, reduced modulo the shard count. Deterministic across
/// processes (no `std` hasher randomness), cheap enough for the
/// per-mutation routing path, and well-spread even for the sequential
/// ids synthetic corpora use.
pub fn shard_of(ext_id: u32, hash_seed: u64, shards: usize) -> usize {
    assert!(shards > 0, "shard count must be >= 1");
    if shards == 1 {
        return 0;
    }
    let mut z = (ext_id as u64) ^ hash_seed;
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % shards as u64) as usize
}

/// One frozen shard: the index plus its local-slot -> external-id map.
/// `ext_of` is empty when the map is the identity (the single-shard
/// wrap of a whole index), so that hot path skips translation entirely.
pub(crate) struct FrozenShard {
    pub(crate) index: Arc<LeanVecIndex>,
    pub(crate) ext_of: Vec<u32>,
}

impl FrozenShard {
    fn identity(&self) -> bool {
        self.ext_of.is_empty()
    }
}

/// The shard set: all-frozen or all-live (mixing would give mutation
/// routing dead targets).
pub(crate) enum ShardSet {
    Frozen(Vec<FrozenShard>),
    Live(Vec<Arc<LiveIndex>>),
}

/// Hash-partitioned scatter-gather index over N shards; implements
/// [`VectorIndex`], so every consumer of the one query API (engine,
/// CLI, benches) can serve a sharded corpus unchanged. See the module
/// docs for the partition/merge contract.
pub struct ShardedIndex {
    spec: ShardSpec,
    set: ShardSet,
    /// the shared projection model (clone of every shard's)
    model: LeanVecModel,
    sim: Similarity,
    /// per-shard context pools for the concurrent scatter path — sized
    /// to the core count, so up to that many in-flight queries fan out
    /// without blocking on a context
    pools: Vec<CtxPool>,
}

fn make_pools(shards: usize) -> Vec<CtxPool> {
    let per_shard = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    // size 0: graph searches grow their visited arrays lazily
    (0..shards).map(|_| CtxPool::new(per_shard, 0)).collect()
}

/// Merge per-shard [`SearchResult`]s into the global top-`k`:
/// concatenate `(score, id)` pairs, stable-sort by score descending
/// (NaN-safe `total_cmp`; ties keep shard order), truncate to `k`, and
/// sum the per-shard [`QueryStats`](crate::index::query::QueryStats)
/// via `QueryStats::merge`. A single-shard merge returns that shard's
/// result unchanged — the shards=1 serve path is bit-identical to the
/// unsharded one.
pub fn merge_top_k(results: Vec<SearchResult>, k: usize) -> SearchResult {
    let mut iter = results.into_iter();
    let Some(mut first) = iter.next() else {
        return SearchResult::default();
    };
    let rest: Vec<SearchResult> = iter.collect();
    if rest.is_empty() {
        first.ids.truncate(k);
        first.scores.truncate(k);
        return first;
    }
    let mut stats = first.stats;
    let mut degraded = first.degraded;
    let mut shards_failed = first.shards_failed;
    let mut pairs: Vec<(f32, u32)> = first
        .scores
        .iter()
        .copied()
        .zip(first.ids.iter().copied())
        .collect();
    for r in rest {
        stats.merge(&r.stats);
        degraded |= r.degraded;
        shards_failed += r.shards_failed;
        pairs.extend(r.scores.iter().copied().zip(r.ids.iter().copied()));
    }
    pairs.sort_by(|a, b| b.0.total_cmp(&a.0));
    pairs.truncate(k);
    SearchResult {
        ids: pairs.iter().map(|&(_, id)| id).collect(),
        scores: pairs.iter().map(|&(s, _)| s).collect(),
        stats,
        degraded,
        shards_failed,
    }
}

/// External ids `0..n` partitioned by the routing hash: one id list per
/// shard, each in ascending order.
fn partition(n: usize, spec: &ShardSpec) -> Vec<Vec<u32>> {
    let mut parts: Vec<Vec<u32>> = vec![Vec::new(); spec.shards];
    for id in 0..n as u32 {
        parts[shard_of(id, spec.hash_seed, spec.shards)].push(id);
    }
    parts
}

impl ShardedIndex {
    /// Wrap a whole frozen index as one shard (identity id map). The
    /// serve path through this wrapper is bit-identical to serving the
    /// index directly.
    pub fn from_single(index: Arc<LeanVecIndex>) -> ShardedIndex {
        let model = index.model.clone();
        let sim = index.sim;
        ShardedIndex {
            spec: ShardSpec::new(1),
            set: ShardSet::Frozen(vec![FrozenShard {
                index,
                ext_of: Vec::new(),
            }]),
            model,
            sim,
            pools: make_pools(1),
        }
    }

    /// Wrap a whole live index as one shard (it already owns its
    /// external-id map).
    pub fn from_live(live: Arc<LiveIndex>) -> ShardedIndex {
        let model = live.model().clone();
        let sim = live.similarity();
        ShardedIndex {
            spec: ShardSpec::new(1),
            set: ShardSet::Live(vec![live]),
            model,
            sim,
            pools: make_pools(1),
        }
    }

    /// Assemble a sharded index from pre-built live shards (the live
    /// loader and the live builder both end here). Shard `i` must hold
    /// exactly the external ids that hash to `i` under `spec`.
    pub fn from_live_shards(shards: Vec<Arc<LiveIndex>>, spec: ShardSpec) -> ShardedIndex {
        assert_eq!(shards.len(), spec.shards, "shard count disagrees with spec");
        assert!(!shards.is_empty(), "at least one shard required");
        let model = shards[0].model().clone();
        let sim = shards[0].similarity();
        let pools = make_pools(shards.len());
        ShardedIndex {
            spec,
            set: ShardSet::Live(shards),
            model,
            sim,
            pools,
        }
    }

    /// Assemble from pre-built frozen shards plus their external-id
    /// maps (the manifest loader ends here).
    pub(crate) fn from_frozen_parts(
        parts: Vec<(Arc<LeanVecIndex>, Vec<u32>)>,
        spec: ShardSpec,
    ) -> ShardedIndex {
        assert_eq!(parts.len(), spec.shards, "shard count disagrees with spec");
        assert!(!parts.is_empty(), "at least one shard required");
        let model = parts[0].0.model.clone();
        let sim = parts[0].0.sim;
        let pools = make_pools(parts.len());
        let shards = parts
            .into_iter()
            .map(|(index, ext_of)| {
                assert!(
                    ext_of.is_empty() || ext_of.len() == index.len(),
                    "external-id map must cover every row"
                );
                FrozenShard { index, ext_of }
            })
            .collect();
        ShardedIndex {
            spec,
            set: ShardSet::Frozen(shards),
            model,
            sim,
            pools,
        }
    }

    /// Build the per-shard indexes: train the shared model once over the
    /// full corpus, partition rows by the routing hash, and run the
    /// per-shard builds embarrassingly parallel — one thread per shard,
    /// each an [`IndexBuilder::build`] with `build_threads / shards`
    /// inner workers (`build_threads` 0 = all cores).
    fn build_parts<F>(
        rows: &[Vec<f32>],
        learn_queries: Option<&[Vec<f32>]>,
        sim: Similarity,
        spec: ShardSpec,
        build_threads: usize,
        configure: &F,
    ) -> (Vec<(LeanVecIndex, Vec<u32>)>, LeanVecModel)
    where
        F: Fn(IndexBuilder) -> IndexBuilder + Sync,
    {
        assert!(!rows.is_empty(), "cannot shard an empty corpus");
        assert!(spec.shards >= 1, "shard count must be >= 1");
        let parts = partition(rows.len(), &spec);
        for (s, ids) in parts.iter().enumerate() {
            assert!(
                !ids.is_empty(),
                "hash partition left shard {s} empty; use fewer shards for {} vectors",
                rows.len()
            );
        }
        let model = configure(IndexBuilder::new()).train_model(rows, learn_queries, sim);
        let threads = crate::util::threadpool::resolve_threads(build_threads);
        let inner = (threads / spec.shards).max(1);
        let outer = threads.min(spec.shards);
        let built: Vec<LeanVecIndex> =
            crate::util::threadpool::parallel_map(spec.shards, outer, |s| {
                let shard_rows: Vec<Vec<f32>> = parts[s]
                    .iter()
                    .map(|&id| rows[id as usize].clone())
                    .collect();
                // the shared model short-circuits training; learn
                // queries are therefore not needed per shard
                configure(IndexBuilder::new())
                    .model(model.clone())
                    .build_threads(inner)
                    .build(&shard_rows, None, sim)
            });
        (built.into_iter().zip(parts).collect(), model)
    }

    /// Build a frozen sharded index over `rows` (external ids = row
    /// positions). `configure` customizes each per-shard
    /// [`IndexBuilder`] (projection, compression, graph params); the
    /// projection model is trained ONCE over the full corpus and shared
    /// across shards, and per-shard builds run in parallel across
    /// `build_threads` workers (0 = all cores).
    pub fn build<F>(
        rows: &[Vec<f32>],
        learn_queries: Option<&[Vec<f32>]>,
        sim: Similarity,
        spec: ShardSpec,
        build_threads: usize,
        configure: F,
    ) -> ShardedIndex
    where
        F: Fn(IndexBuilder) -> IndexBuilder + Sync,
    {
        let (parts, model) =
            Self::build_parts(rows, learn_queries, sim, spec, build_threads, &configure);
        let pools = make_pools(spec.shards);
        let shards = parts
            .into_iter()
            .map(|(index, ext_of)| FrozenShard {
                index: Arc::new(index),
                ext_of,
            })
            .collect();
        ShardedIndex {
            spec,
            set: ShardSet::Frozen(shards),
            model,
            sim: if sim == Similarity::Cosine {
                Similarity::InnerProduct
            } else {
                sim
            },
            pools,
        }
    }

    /// [`ShardedIndex::build`], thawed: every shard becomes a
    /// [`LiveIndex`] speaking the global external ids of the rows it was
    /// built over, so streaming inserts/deletes route by shard hash.
    pub fn build_live<F>(
        rows: &[Vec<f32>],
        learn_queries: Option<&[Vec<f32>]>,
        sim: Similarity,
        spec: ShardSpec,
        build_threads: usize,
        configure: F,
    ) -> ShardedIndex
    where
        F: Fn(IndexBuilder) -> IndexBuilder + Sync,
    {
        let (parts, _model) =
            Self::build_parts(rows, learn_queries, sim, spec, build_threads, &configure);
        let shards: Vec<Arc<LiveIndex>> = parts
            .into_iter()
            .map(|(index, ext_of)| Arc::new(LiveIndex::from_index_with_ids(index, ext_of)))
            .collect();
        ShardedIndex::from_live_shards(shards, spec)
    }

    /// The shard topology.
    pub fn spec(&self) -> ShardSpec {
        self.spec
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.spec.shards
    }

    /// The shared projection model (the engine's batcher projects whole
    /// batches through `model().a` once, for all shards).
    pub fn model(&self) -> &LeanVecModel {
        &self.model
    }

    /// Whether the shards are mutable [`LiveIndex`]es.
    pub fn is_live(&self) -> bool {
        matches!(self.set, ShardSet::Live(_))
    }

    /// The live shards (empty slice when frozen).
    pub fn live_shards(&self) -> &[Arc<LiveIndex>] {
        match &self.set {
            ShardSet::Live(shards) => shards,
            ShardSet::Frozen(_) => &[],
        }
    }

    pub(crate) fn set(&self) -> &ShardSet {
        &self.set
    }

    /// Which shard `ext_id` routes to.
    pub fn shard_for(&self, ext_id: u32) -> usize {
        shard_of(ext_id, self.spec.hash_seed, self.spec.shards)
    }

    /// Deep consistency check for the fsck layer: the spec's shard
    /// count matches the actual set, every shard's own invariants hold
    /// ([`LeanVecIndex::check_invariants`] /
    /// [`LiveIndex::check_invariants`]), every external id lives on the
    /// shard the routing hash assigns it to (a seed or shard-count
    /// mismatch after a partial restore shows up here), and no external
    /// id is owned by two shards. Returns a typed report instead of
    /// panicking; `repro fsck` and the corruption battery share it.
    pub fn check_invariants(&self) -> crate::util::invariants::FsckReport {
        use crate::util::invariants::{FsckReport, Violation};
        use std::collections::HashMap;
        let mut report = FsckReport::default();
        let actual = match &self.set {
            ShardSet::Frozen(shards) => shards.len(),
            ShardSet::Live(shards) => shards.len(),
        };
        if actual != self.spec.shards {
            report.violations.push(Violation::new(
                "sharded-index",
                "shard-count",
                format!("spec says {} shards, set holds {actual}", self.spec.shards),
            ));
        }
        // per-shard external ids: a frozen shard's identity mapping
        // (single-shard case) owns ids 0..len implicitly and is skipped
        // by the routing check — nothing was hash-partitioned.
        let mut owned: Vec<(usize, Vec<u32>)> = Vec::new();
        match &self.set {
            ShardSet::Frozen(shards) => {
                for (s, shard) in shards.iter().enumerate() {
                    report.absorb(&format!("shard {s}"), shard.index.check_invariants());
                    if !shard.identity() {
                        if shard.ext_of.len() != shard.index.len() {
                            report.violations.push(Violation::new(
                                "sharded-index",
                                "store-len-mismatch",
                                format!(
                                    "shard {s}: {} ext ids for {} rows",
                                    shard.ext_of.len(),
                                    shard.index.len()
                                ),
                            ));
                        }
                        owned.push((s, shard.ext_of.clone()));
                    }
                }
            }
            ShardSet::Live(shards) => {
                for (s, live) in shards.iter().enumerate() {
                    report.absorb(&format!("shard {s}"), live.check_invariants());
                    owned.push((s, live.live_ids()));
                }
            }
        }
        let mut seen: HashMap<u32, usize> = HashMap::new();
        let mut routing_samples = 0;
        let mut overlap_samples = 0;
        for (s, ids) in &owned {
            for &ext in ids {
                if actual > 1 || self.spec.shards > 1 {
                    let want = shard_of(ext, self.spec.hash_seed, self.spec.shards.max(1));
                    if want != *s && routing_samples < 16 {
                        report.violations.push(Violation::new(
                            "sharded-index",
                            "routing-seed",
                            format!(
                                "ext id {ext} lives on shard {s} but routes to {want} \
                                 (seed {:#x}, {} shards)",
                                self.spec.hash_seed, self.spec.shards
                            ),
                        ));
                        routing_samples += 1;
                    }
                }
                if let Some(prev) = seen.insert(ext, *s) {
                    if overlap_samples < 16 {
                        report.violations.push(Violation::new(
                            "sharded-index",
                            "ext-id-overlap",
                            format!("ext id {ext} owned by both shard {prev} and shard {s}"),
                        ));
                        overlap_samples += 1;
                    }
                }
            }
        }
        report.checked.push(format!(
            "sharded index: {actual} {} shard(s), seed {:#x}, {} external ids",
            if self.is_live() { "live" } else { "frozen" },
            self.spec.hash_seed,
            seen.len()
        ));
        report
    }

    /// Total slots across shards (live + tombstoned for live shards).
    pub fn total_slots(&self) -> usize {
        match &self.set {
            ShardSet::Frozen(shards) => shards.iter().map(|s| s.index.len()).sum(),
            ShardSet::Live(shards) => shards.iter().map(|s| s.total_slots()).sum(),
        }
    }

    /// The worst (maximum) per-shard tombstone fraction — what the
    /// ingest lane's staggered consolidation trigger watches.
    pub fn max_tombstone_fraction(&self) -> f64 {
        self.live_shards()
            .iter()
            .map(|s| s.tombstone_fraction())
            .fold(0.0, f64::max)
    }

    /// Pending (un-consolidated) inserts summed across shards.
    pub fn pending_inserts(&self) -> usize {
        self.live_shards().iter().map(|s| s.pending_inserts()).sum()
    }

    /// Is `ext_id` currently live? (False on frozen shard sets — frozen
    /// shards track no external liveness.)
    pub fn contains(&self, ext_id: u32) -> bool {
        match &self.set {
            ShardSet::Live(shards) => shards[self.shard_for(ext_id)].contains(ext_id),
            ShardSet::Frozen(_) => false,
        }
    }

    /// Route an insert to its shard by external-id hash (live shard
    /// sets only).
    pub fn insert(&self, ext_id: u32, vector: &[f32]) -> Result<u32, MutateError> {
        match &self.set {
            ShardSet::Live(shards) => shards[self.shard_for(ext_id)].insert(ext_id, vector),
            ShardSet::Frozen(_) => Err(MutateError::Frozen),
        }
    }

    /// Route a delete to its shard by external-id hash (live shard sets
    /// only).
    pub fn delete(&self, ext_id: u32) -> Result<u32, MutateError> {
        match &self.set {
            ShardSet::Live(shards) => shards[self.shard_for(ext_id)].delete(ext_id),
            ShardSet::Frozen(_) => Err(MutateError::Frozen),
        }
    }

    /// Staggered consolidation: consolidate AT MOST ONE shard — the one
    /// with the highest tombstone fraction among those due (fraction >=
    /// `threshold`, or pending insert log >= `pending_fold`). The ingest
    /// lane calls this after every applied mutation, so shard
    /// consolidations spread out over the mutation stream instead of
    /// stalling every shard at once — the p99 stays flat while each
    /// shard still gets compacted. Returns the consolidated shard's
    /// position and report, or `None` when nothing was due (or the set
    /// is frozen). `threshold <= 0` disables the fraction trigger.
    pub fn consolidate_one(
        &self,
        threshold: f64,
        pending_fold: usize,
    ) -> Option<(usize, ConsolidateReport)> {
        let ShardSet::Live(shards) = &self.set else {
            return None;
        };
        let mut pick: Option<(usize, f64)> = None;
        for (s, live) in shards.iter().enumerate() {
            let frac = live.tombstone_fraction();
            let due = (threshold > 0.0 && frac >= threshold)
                || live.pending_inserts() >= pending_fold;
            if due && pick.map_or(true, |(_, best)| frac > best) {
                pick = Some((s, frac));
            }
        }
        pick.map(|(s, _)| (s, shards[s].consolidate()))
    }

    /// Search one shard, translating ids and the filter predicate
    /// between the global external namespace and the shard's local one.
    fn search_shard(
        &self,
        s: usize,
        ctx: &mut SearchCtx,
        q_proj: &[f32],
        query: &Query,
    ) -> SearchResult {
        match &self.set {
            // live shards already speak external ids (filter included)
            ShardSet::Live(shards) => shards[s].search_prepared(ctx, q_proj, query),
            ShardSet::Frozen(shards) => {
                let sh = &shards[s];
                if sh.identity() {
                    return sh.index.search_prepared(ctx, q_proj, query);
                }
                let ext_of = &sh.ext_of;
                let mut r = match query.filter_fn() {
                    Some(user) => {
                        // the caller's predicate sees external ids; the
                        // shard's traversal sees local slots
                        let local = |id: u32| user(ext_of[id as usize]);
                        sh.index
                            .search_prepared(ctx, q_proj, &query.replace_filter(Some(&local)))
                    }
                    None => sh.index.search_prepared(ctx, q_proj, query),
                };
                for id in r.ids.iter_mut() {
                    *id = ext_of[*id as usize];
                }
                r
            }
        }
    }

    /// Sequential scatter-gather with a caller-provided context: search
    /// every shard in turn, then [`merge_top_k`]. The engine's
    /// batch-projected entry point ([`LeanVecIndex::search_prepared`]
    /// contract: `q_proj` is the projected query, `query.vector()` the
    /// original full-D vector).
    pub fn search_prepared(
        &self,
        ctx: &mut SearchCtx,
        q_proj: &[f32],
        query: &Query,
    ) -> SearchResult {
        let n = self.shards();
        if n == 1 {
            return self.search_shard(0, ctx, q_proj, query);
        }
        let results: Vec<SearchResult> = (0..n)
            .map(|s| self.search_shard(s, ctx, q_proj, query))
            .collect();
        merge_top_k(results, query.top_k())
    }

    /// Search one shard for the concurrent scatter path: install the
    /// request's cancellation token into the pooled context (cleared
    /// again before the context returns to the pool), consult the chaos
    /// failpoints, and absorb a panic — from a poisoned shard, a
    /// panicking filter predicate, or an injected fault — into `None`.
    /// One failing participant degrades the query instead of owning it:
    /// the merge proceeds over the survivors.
    fn scatter_shard(
        &self,
        s: usize,
        q_proj: &[f32],
        query: &Query,
        cancel: Option<&Arc<CancelToken>>,
    ) -> Option<SearchResult> {
        let searched = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            #[cfg(any(test, feature = "failpoints"))]
            {
                crate::util::failpoints::hit("slow_shard", Some(s));
                crate::util::failpoints::hit("panic_shard", Some(s));
            }
            let mut ctx = self.pools[s].acquire();
            ctx.set_cancel(cancel.cloned());
            let r = self.search_shard(s, &mut ctx, q_proj, query);
            ctx.set_cancel(None);
            r
        }));
        match searched {
            Ok(r) => Some(r),
            Err(_payload) => {
                // the panic payload is intentionally dropped: the
                // request must survive, and the failure is visible
                // through the counter, the degraded flag, and the
                // panic hook's own stderr report
                if crate::obs::enabled() {
                    crate::obs::handles().shard_failures.inc();
                }
                None
            }
        }
    }

    /// Merge scatter outcomes: failed shards (None) degrade the result
    /// instead of failing the query; an all-shards-failed query yields
    /// an empty, fully degraded result (the worker layers a typed error
    /// or partial-result decision on top).
    fn merge_scatter(results: Vec<Option<SearchResult>>, k: usize) -> SearchResult {
        let n = results.len();
        let ok: Vec<SearchResult> = results.into_iter().flatten().collect();
        let failed = n - ok.len();
        let mut merged = merge_top_k(ok, k);
        if failed > 0 {
            merged.degraded = true;
            merged.shards_failed += failed;
        }
        merged
    }

    /// Concurrent scatter-gather: every shard searched on its own
    /// thread, each drawing a context from that shard's [`CtxPool`];
    /// shard 0 runs on the calling thread. Single-shard sets skip the
    /// fan-out entirely (one pooled context, no spawn), so the shards=1
    /// serve path stays identical to the unsharded engine's.
    pub fn search_scatter(&self, q_proj: &[f32], query: &Query) -> SearchResult {
        self.search_scatter_cancel(q_proj, query, None)
    }

    /// [`ShardedIndex::search_scatter`] with a shared [`CancelToken`]
    /// threaded into every per-shard traversal, which polls it every
    /// [`CANCEL_POLL_HOPS`](crate::graph::beam::CANCEL_POLL_HOPS)
    /// expansions: a tripped token (explicit or deadline) stops each
    /// shard within microseconds and the merge returns whatever the
    /// shards had found — the partial-results contract.
    pub fn search_scatter_cancel(
        &self,
        q_proj: &[f32],
        query: &Query,
        cancel: Option<&Arc<CancelToken>>,
    ) -> SearchResult {
        let n = self.shards();
        if n == 1 {
            return Self::merge_scatter(
                vec![self.scatter_shard(0, q_proj, query, cancel)],
                query.top_k(),
            );
        }
        let results: Vec<Option<SearchResult>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (1..n)
                .map(|s| scope.spawn(move || self.scatter_shard(s, q_proj, query, cancel)))
                .collect();
            let mut results = Vec::with_capacity(n);
            results.push(self.scatter_shard(0, q_proj, query, cancel));
            for h in handles {
                // DEADLINE: scoped join on a shard search that is
                // itself deadline-bounded via the shared cancel token
                // (and panic-proofed by scatter_shard) — it cannot
                // outlive the request by more than one poll interval.
                results.push(h.join().unwrap_or_else(|_| {
                    // unreachable in practice: scatter_shard catches
                    // shard panics; treat a join failure as one more
                    // failed shard rather than killing the request
                    if crate::obs::enabled() {
                        crate::obs::handles().shard_failures.inc();
                    }
                    None
                }));
            }
            results
        });
        Self::merge_scatter(results, query.top_k())
    }

    /// [`ShardedIndex::search_scatter`] plus per-stage timing: each
    /// shard's wall time and the merge step land in the returned
    /// [`ScatterTiming`] *and* in the `leanvec_shard_scatter_seconds` /
    /// `leanvec_shard_merge_seconds` histograms. When telemetry is
    /// disabled the untimed path runs instead (returning `None`), so
    /// the hot path pays no extra clock reads.
    pub fn search_scatter_timed(
        &self,
        q_proj: &[f32],
        query: &Query,
    ) -> (SearchResult, Option<ScatterTiming>) {
        self.search_scatter_timed_cancel(q_proj, query, None)
    }

    /// [`ShardedIndex::search_scatter_timed`] with the request's
    /// [`CancelToken`] threaded down — the engine worker's entry point.
    pub fn search_scatter_timed_cancel(
        &self,
        q_proj: &[f32],
        query: &Query,
        cancel: Option<&Arc<CancelToken>>,
    ) -> (SearchResult, Option<ScatterTiming>) {
        if !crate::obs::enabled() {
            return (self.search_scatter_cancel(q_proj, query, cancel), None);
        }
        let h = crate::obs::handles();
        let n = self.shards();
        if n == 1 {
            let t = Instant::now();
            let r = Self::merge_scatter(
                vec![self.scatter_shard(0, q_proj, query, cancel)],
                query.top_k(),
            );
            let dt = t.elapsed().as_secs_f64();
            h.shard_scatter.with("0").record_seconds(dt);
            return (
                r,
                Some(ScatterTiming {
                    per_shard_seconds: vec![dt],
                    merge_seconds: 0.0,
                }),
            );
        }
        // same fan-out shape as search_scatter (shard 0 on the calling
        // thread), each shard timed individually; a failed shard still
        // reports its wall time (how long the failure took to surface)
        let mut timed: Vec<(Option<SearchResult>, f64)> = std::thread::scope(|scope| {
            let spawned: Vec<_> = (1..n)
                .map(|s| {
                    scope.spawn(move || {
                        let t = Instant::now();
                        let r = self.scatter_shard(s, q_proj, query, cancel);
                        (r, t.elapsed().as_secs_f64())
                    })
                })
                .collect();
            let mut results = Vec::with_capacity(n);
            {
                let t = Instant::now();
                let r = self.scatter_shard(0, q_proj, query, cancel);
                results.push((r, t.elapsed().as_secs_f64()));
            }
            for handle in spawned {
                // DEADLINE: scoped join on a deadline-bounded,
                // panic-proofed shard search — see search_scatter_cancel.
                results.push(handle.join().unwrap_or_else(|_| {
                    if crate::obs::enabled() {
                        crate::obs::handles().shard_failures.inc();
                    }
                    (None, 0.0)
                }));
            }
            results
        });
        let mut per_shard_seconds = Vec::with_capacity(n);
        let mut results = Vec::with_capacity(n);
        for (s, (r, dt)) in timed.drain(..).enumerate() {
            h.shard_scatter.with(&s.to_string()).record_seconds(dt);
            per_shard_seconds.push(dt);
            results.push(r);
        }
        let t = Instant::now();
        let merged = Self::merge_scatter(results, query.top_k());
        let merge_seconds = t.elapsed().as_secs_f64();
        h.shard_merge.record_seconds(merge_seconds);
        (
            merged,
            Some(ScatterTiming {
                per_shard_seconds,
                merge_seconds,
            }),
        )
    }
}

/// Per-stage timing of one scatter-gather search, produced by
/// [`ShardedIndex::search_scatter_timed`] and surfaced in the engine's
/// [`StageTimes`] / flight records.
///
/// [`StageTimes`]: crate::coordinator::StageTimes
#[derive(Clone, Debug, Default)]
pub struct ScatterTiming {
    /// wall time of each shard's search, indexed by shard position
    pub per_shard_seconds: Vec<f64>,
    /// wall time of the final top-k merge (0 for single-shard sets)
    pub merge_seconds: f64,
}

impl VectorIndex for ShardedIndex {
    /// Project once (`A q` through the shared model), then sequential
    /// scatter-gather with the caller's context.
    fn search(&self, ctx: &mut SearchCtx, query: &Query) -> SearchResult {
        let q_proj = self.model.project_query(query.vector());
        self.search_prepared(ctx, &q_proj, query)
    }

    /// Searchable vectors across shards (live shards count live rows
    /// only, matching [`LiveIndex`]'s trait impl).
    fn len(&self) -> usize {
        match &self.set {
            ShardSet::Frozen(shards) => shards.iter().map(|s| s.index.len()).sum(),
            ShardSet::Live(shards) => shards.iter().map(|s| s.live_len()).sum(),
        }
    }

    fn dim(&self) -> usize {
        self.model.input_dim()
    }

    fn sim(&self) -> Similarity {
        self.sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, ProjectionKind};
    use crate::index::query::QueryStats;
    use crate::util::rng::Rng;

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn routing_is_deterministic_and_spread() {
        let n = 10_000u32;
        let shards = 4;
        let mut counts = vec![0usize; shards];
        for id in 0..n {
            let s = shard_of(id, DEFAULT_HASH_SEED, shards);
            assert_eq!(s, shard_of(id, DEFAULT_HASH_SEED, shards), "deterministic");
            counts[s] += 1;
        }
        let expected = n as usize / shards;
        for (s, &c) in counts.iter().enumerate() {
            assert!(
                c > expected / 2 && c < expected * 2,
                "shard {s} got {c} of {n} ids (expected ~{expected})"
            );
        }
        // a different seed produces a different partition
        let moved = (0..n)
            .filter(|&id| {
                shard_of(id, DEFAULT_HASH_SEED, shards) != shard_of(id, 12345, shards)
            })
            .count();
        assert!(moved > 0, "seed must matter");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn single_shard_routes_everything_to_zero() {
        for id in [0u32, 1, 99, u32::MAX] {
            assert_eq!(shard_of(id, DEFAULT_HASH_SEED, 1), 0);
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn partition_covers_every_id_once() {
        let spec = ShardSpec {
            shards: 3,
            hash_seed: 7,
        };
        let parts = partition(1000, &spec);
        let mut seen = vec![false; 1000];
        for (s, ids) in parts.iter().enumerate() {
            for &id in ids {
                assert_eq!(shard_of(id, 7, 3), s);
                assert!(!seen[id as usize], "id {id} in two shards");
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "every id assigned");
    }

    fn result(ids: Vec<u32>, scores: Vec<f32>, hops: usize) -> SearchResult {
        SearchResult {
            ids,
            scores,
            stats: QueryStats {
                hops,
                primary_scored: hops * 2,
                ..QueryStats::default()
            },
            ..SearchResult::default()
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn merge_orders_by_score_and_sums_stats() {
        let a = result(vec![1, 2], vec![0.9, 0.5], 10);
        let b = result(vec![3, 4], vec![0.7, 0.6], 20);
        let m = merge_top_k(vec![a, b], 3);
        assert_eq!(m.ids, vec![1, 3, 4]);
        assert_eq!(m.scores, vec![0.9, 0.7, 0.6]);
        assert_eq!(m.stats.hops, 30);
        assert_eq!(m.stats.primary_scored, 60);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn merge_single_shard_is_identity() {
        let a = result(vec![5, 6, 7], vec![0.3, 0.2, 0.1], 4);
        let m = merge_top_k(vec![a.clone()], 3);
        assert_eq!(m, a, "single-shard merge must be bit-identical");
        // and an empty merge is empty
        assert_eq!(merge_top_k(Vec::new(), 5), SearchResult::default());
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn merge_ties_keep_shard_order() {
        let a = result(vec![1], vec![0.5], 1);
        let b = result(vec![2], vec![0.5], 1);
        let m = merge_top_k(vec![a, b], 2);
        assert_eq!(m.ids, vec![1, 2], "stable sort: earlier shard wins ties");
    }

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    fn configure(b: IndexBuilder) -> IndexBuilder {
        let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
        gp.max_degree = 12;
        gp.build_window = 30;
        b.projection(ProjectionKind::Id).target_dim(8).graph_params(gp)
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn sharded_build_shares_one_model() {
        let x = rows(400, 16, 3);
        let ix = ShardedIndex::build(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(3),
            1,
            configure,
        );
        assert_eq!(ix.shards(), 3);
        assert_eq!(VectorIndex::len(&ix), 400);
        let ShardSet::Frozen(shards) = ix.set() else {
            panic!("frozen build")
        };
        for sh in shards {
            assert_eq!(sh.index.model.a.data, ix.model().a.data, "shared model");
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn sharded_search_returns_external_ids() {
        let x = rows(500, 16, 4);
        let ix = ShardedIndex::build(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(4),
            1,
            configure,
        );
        // a self-query's own id must come back under its external number
        let mut hits = 0;
        for probe in [0u32, 17, 333, 499] {
            let r = ix.search_one(&Query::new(&x[probe as usize]).k(5).window(40));
            assert_eq!(r.ids.len(), 5);
            assert!(r.ids.iter().all(|&id| (id as usize) < x.len()));
            if r.ids.contains(&probe) {
                hits += 1;
            }
        }
        assert!(hits >= 3, "self-recall through id translation: {hits}/4");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn scatter_matches_sequential_scatter() {
        let x = rows(600, 16, 5);
        let ix = ShardedIndex::build(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(4),
            0,
            configure,
        );
        for probe in 0..8usize {
            let q = Query::new(&x[probe * 70]).k(10).window(30);
            let q_proj = ix.model().project_query(q.vector());
            let seq = {
                let mut ctx = SearchCtx::new(0);
                ix.search_prepared(&mut ctx, &q_proj, &q)
            };
            let scat = ix.search_scatter(&q_proj, &q);
            assert_eq!(seq, scat, "concurrent scatter must equal sequential");
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn sharded_filter_sees_external_ids() {
        let x = rows(400, 16, 6);
        let ix = ShardedIndex::build(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(4),
            1,
            configure,
        );
        let pred = |id: u32| id % 2 == 0;
        let r = ix.search_one(&Query::new(&x[0]).k(10).window(60).filter(&pred));
        assert!(!r.ids.is_empty());
        assert!(
            r.ids.iter().all(|&id| id % 2 == 0),
            "filter must apply to external ids: {:?}",
            r.ids
        );
        assert!(r.stats.filtered > 0, "filter skips counted across shards");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn live_sharded_mutations_route_by_hash() {
        let x = rows(300, 16, 7);
        let ix = ShardedIndex::build_live(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(3),
            1,
            configure,
        );
        assert!(ix.is_live());
        assert_eq!(VectorIndex::len(&ix), 300);
        // delete routes to the owning shard
        assert!(ix.contains(42));
        ix.delete(42).unwrap();
        assert!(!ix.contains(42));
        assert_eq!(ix.delete(42), Err(MutateError::UnknownId(42)));
        // insert routes a fresh id
        let v = rows(1, 16, 99).pop().unwrap();
        ix.insert(1000, &v).unwrap();
        assert!(ix.contains(1000));
        let shard = ix.shard_for(1000);
        assert!(ix.live_shards()[shard].contains(1000), "landed on its hash shard");
        assert_eq!(VectorIndex::len(&ix), 300);
        // deleted id never comes back from search
        let r = ix.search_one(&Query::new(&x[42]).k(10).window(80));
        assert!(!r.ids.contains(&42), "tombstoned id served: {:?}", r.ids);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn frozen_set_rejects_mutations() {
        let x = rows(200, 16, 8);
        let ix = ShardedIndex::build(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(2),
            1,
            configure,
        );
        assert_eq!(ix.insert(999, &x[0]), Err(MutateError::Frozen));
        assert_eq!(ix.delete(0), Err(MutateError::Frozen));
        assert!(ix.consolidate_one(0.01, 1).is_none());
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn consolidate_one_staggers_across_shards() {
        let x = rows(400, 16, 9);
        let ix = ShardedIndex::build_live(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(4),
            1,
            configure,
        );
        // tombstone ~20% of every shard
        for id in 0..80u32 {
            ix.delete(id).unwrap();
        }
        assert!(ix.max_tombstone_fraction() > 0.0);
        // each call consolidates exactly one shard; after at most 4
        // passes nothing is due any more
        let mut consolidated = Vec::new();
        while let Some((s, report)) = ix.consolidate_one(0.05, usize::MAX) {
            assert!(report.remaining > 0);
            consolidated.push(s);
            assert!(consolidated.len() <= 4, "more passes than shards");
        }
        assert!(!consolidated.is_empty());
        let mut unique = consolidated.clone();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), consolidated.len(), "no shard consolidated twice");
        assert_eq!(ix.max_tombstone_fraction(), 0.0);
        assert_eq!(VectorIndex::len(&ix), 320);
    }
}
