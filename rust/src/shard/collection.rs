//! Named collections: the unit of multi-tenant serving.
//!
//! A [`Collection`] binds a name to a [`ShardedIndex`] plus the
//! per-tenant serving policy — default [`SearchParams`] applied when a
//! request leaves its knobs unset, and an admission [`TenantQuota`]
//! (max in-flight searches, max pending mutations) enforced at
//! `Engine::submit*` time so one tenant cannot starve the shared worker
//! pool. The [`CollectionRegistry`] is the name → collection map the
//! engine routes by; requests carry the collection name in their
//! [`QuerySpec`](crate::coordinator::protocol::QuerySpec).

use crate::index::leanvec_index::SearchParams;
use crate::shard::sharded::ShardedIndex;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

/// The collection single-index engines serve under
/// ([`Engine::start`](crate::coordinator::Engine::start) wraps its
/// index into this name).
pub const DEFAULT_COLLECTION: &str = "default";

/// Per-tenant admission limits. `0` means unlimited (the default): the
/// quota only rejects when a bound is explicitly configured.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantQuota {
    /// max searches in flight (submitted, response not yet drained)
    pub max_inflight: usize,
    /// max mutations queued on the ingest lane and not yet applied
    pub max_pending_mutations: usize,
}

/// Live admission/usage counters for one collection, updated lock-free
/// on the submit and completion paths.
#[derive(Debug, Default)]
pub struct AdmissionCounters {
    /// searches submitted and not yet answered
    pub inflight: AtomicUsize,
    /// searches admitted over the collection's lifetime
    pub submitted: AtomicU64,
    /// submissions rejected by quota
    pub rejected: AtomicU64,
    /// mutations queued on the ingest lane and not yet applied
    pub pending_mutations: AtomicUsize,
    /// mutations admitted over the collection's lifetime
    pub mutations: AtomicU64,
}

/// One named, sharded, quota-governed index.
pub struct Collection {
    name: String,
    /// The sharded index this collection serves. Behind an `RwLock` so a
    /// hot-swap can atomically replace the serve index while queries keep
    /// their own `Arc` snapshot; readers never block on a swap for longer
    /// than the pointer exchange.
    index: RwLock<Arc<ShardedIndex>>,
    /// per-collection serving defaults (window / rerank window) applied
    /// when a request's `QuerySpec` leaves them unset
    pub defaults: SearchParams,
    quota: TenantQuota,
    admission: AdmissionCounters,
}

impl Collection {
    /// A collection with default search params and no quota.
    pub fn new(name: impl Into<String>, index: ShardedIndex) -> Collection {
        Collection {
            name: name.into(),
            index: RwLock::new(Arc::new(index)),
            defaults: SearchParams::default(),
            quota: TenantQuota::default(),
            admission: AdmissionCounters::default(),
        }
    }

    /// Snapshot the current serve index. Callers hold the returned `Arc`
    /// for the duration of one query (or one batch group), so a
    /// concurrent [`Collection::swap_index`] never invalidates an
    /// in-flight search — the old index stays alive until the last
    /// snapshot drops.
    pub fn index(&self) -> Arc<ShardedIndex> {
        // DEADLINE: read lock held only for the Arc clone (no I/O, no
        // allocation beyond the refcount bump); cannot block the serve
        // path measurably. Poisoning is impossible to observe here in a
        // harmful way — the lock only guards a pointer swap — so recover.
        Arc::clone(&self.index.read().unwrap_or_else(|e| e.into_inner()))
    }

    /// Atomically replace the serve index, returning the previous one so
    /// the caller can drain it (wait for its refcount to reach one)
    /// before dropping heavy resources.
    pub fn swap_index(&self, new: Arc<ShardedIndex>) -> Arc<ShardedIndex> {
        // DEADLINE: write lock held only for the pointer exchange.
        let mut slot = self.index.write().unwrap_or_else(|e| e.into_inner());
        std::mem::replace(&mut *slot, new)
    }

    /// Replace the per-collection search defaults.
    pub fn with_defaults(mut self, defaults: SearchParams) -> Collection {
        self.defaults = defaults;
        self
    }

    /// Attach an admission quota.
    pub fn with_quota(mut self, quota: TenantQuota) -> Collection {
        self.quota = quota;
        self
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn quota(&self) -> TenantQuota {
        self.quota
    }

    /// The live admission counters (observability).
    pub fn admission(&self) -> &AdmissionCounters {
        &self.admission
    }

    /// Try to admit one search. On success the in-flight gauge is
    /// already incremented; the caller MUST pair it with
    /// [`Collection::finish_search`] exactly once.
    pub(crate) fn admit_search(&self) -> bool {
        let limit = self.quota.max_inflight;
        if limit == 0 {
            self.admission.inflight.fetch_add(1, Ordering::AcqRel);
        } else {
            // CAS loop: never exceed the bound even under contention
            let admitted = self
                .admission
                .inflight
                .fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                    (cur < limit).then(|| cur + 1)
                })
                .is_ok();
            if !admitted {
                // ORDERING: Relaxed — stat counter; admission itself is
                // decided by the AcqRel CAS above.
                self.admission.rejected.fetch_add(1, Ordering::Relaxed);
                return false;
            }
        }
        // ORDERING: Relaxed — stat counter (reporting only).
        self.admission.submitted.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// A previously admitted search completed (its response was built).
    pub(crate) fn finish_search(&self) {
        self.admission.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    /// Try to admit one mutation onto the ingest lane; pairs with
    /// [`Collection::finish_mutation`].
    pub(crate) fn admit_mutation(&self) -> bool {
        let limit = self.quota.max_pending_mutations;
        if limit == 0 {
            self.admission.pending_mutations.fetch_add(1, Ordering::AcqRel);
        } else {
            let admitted = self
                .admission
                .pending_mutations
                .fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                    (cur < limit).then(|| cur + 1)
                })
                .is_ok();
            if !admitted {
                // ORDERING: Relaxed — stat counter; admission itself is
                // decided by the AcqRel CAS above.
                self.admission.rejected.fetch_add(1, Ordering::Relaxed);
                return false;
            }
        }
        // ORDERING: Relaxed — stat counter (reporting only).
        self.admission.mutations.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// A previously admitted mutation was applied (or dropped).
    pub(crate) fn finish_mutation(&self) {
        self.admission.pending_mutations.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Name → [`Collection`] map the engine serves. Built up-front and
/// immutable while serving (collections are added between engine runs),
/// so lookups are lock-free `HashMap` reads through an `Arc`.
#[derive(Default)]
pub struct CollectionRegistry {
    by_name: HashMap<String, Arc<Collection>>,
}

impl CollectionRegistry {
    pub fn new() -> CollectionRegistry {
        CollectionRegistry::default()
    }

    /// Add a collection; replaces any previous one with the same name.
    pub fn register(&mut self, collection: Collection) -> &mut Self {
        self.by_name
            .insert(collection.name.clone(), Arc::new(collection));
        self
    }

    pub fn get(&self, name: &str) -> Option<&Arc<Collection>> {
        self.by_name.get(name)
    }

    /// Registered names, sorted (deterministic display order).
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.by_name.keys().cloned().collect();
        names.sort();
        names
    }

    pub fn collections(&self) -> impl Iterator<Item = &Arc<Collection>> {
        self.by_name.values()
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Whether any registered collection has live (mutable) shards —
    /// decides if the engine starts an ingest lane.
    pub fn any_live(&self) -> bool {
        self.by_name.values().any(|c| c.index().is_live())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, ProjectionKind, Similarity};
    use crate::index::builder::IndexBuilder;
    use crate::shard::sharded::ShardSpec;
    use crate::util::rng::Rng;

    fn tiny_index() -> ShardedIndex {
        let mut rng = Rng::new(11);
        let rows: Vec<Vec<f32>> = (0..80)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        ShardedIndex::build(
            &rows,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(2),
            1,
            |b: IndexBuilder| {
                let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
                gp.max_degree = 8;
                gp.build_window = 16;
                b.projection(ProjectionKind::Id).target_dim(4).graph_params(gp)
            },
        )
    }

    #[test]
    fn registry_routes_by_name() {
        let mut reg = CollectionRegistry::new();
        reg.register(Collection::new("tenant-a", tiny_index()));
        reg.register(Collection::new("tenant-b", tiny_index()).with_defaults(SearchParams {
            window: 17,
            rerank_window: 23,
        }));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.names(), vec!["tenant-a".to_string(), "tenant-b".to_string()]);
        assert!(reg.get("tenant-a").is_some());
        assert!(reg.get("missing").is_none());
        assert_eq!(reg.get("tenant-b").unwrap().defaults.window, 17);
        assert!(!reg.any_live(), "frozen shards");
    }

    #[test]
    fn unlimited_quota_always_admits() {
        let c = Collection::new("t", tiny_index());
        for _ in 0..100 {
            assert!(c.admit_search());
        }
        assert_eq!(c.admission().inflight.load(Ordering::Acquire), 100);
        assert_eq!(c.admission().submitted.load(Ordering::Relaxed), 100);
        assert_eq!(c.admission().rejected.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn inflight_quota_rejects_at_bound_and_recovers() {
        let c = Collection::new("t", tiny_index()).with_quota(TenantQuota {
            max_inflight: 2,
            max_pending_mutations: 0,
        });
        assert!(c.admit_search());
        assert!(c.admit_search());
        assert!(!c.admit_search(), "third in-flight search must be rejected");
        assert_eq!(c.admission().rejected.load(Ordering::Relaxed), 1);
        c.finish_search();
        assert!(c.admit_search(), "capacity freed by completion");
        assert_eq!(c.admission().inflight.load(Ordering::Acquire), 2);
    }

    #[test]
    fn swap_index_keeps_old_snapshot_alive_until_dropped() {
        let c = Collection::new("t", tiny_index());
        let before = c.index();
        let replacement = Arc::new(tiny_index());
        let old = c.swap_index(Arc::clone(&replacement));
        assert!(
            Arc::ptr_eq(&before, &old),
            "swap must return the previous serve index"
        );
        assert!(
            Arc::ptr_eq(&c.index(), &replacement),
            "post-swap snapshots must see the new index"
        );
        // The pre-swap snapshot is still usable: old index stays alive.
        assert_eq!(before.shards(), 2);
        drop(before);
        drop(old);
        assert_eq!(
            Arc::strong_count(&replacement),
            2,
            "replacement held by collection + this test only"
        );
    }

    #[test]
    fn mutation_quota_is_independent_of_search_quota() {
        let c = Collection::new("t", tiny_index()).with_quota(TenantQuota {
            max_inflight: 1,
            max_pending_mutations: 1,
        });
        assert!(c.admit_search());
        assert!(c.admit_mutation(), "search quota must not consume mutation quota");
        assert!(!c.admit_mutation());
        c.finish_mutation();
        assert!(c.admit_mutation());
    }
}
