//! Sharded snapshot persistence: one snapshot file per shard plus a
//! small CRC'd manifest, written into a directory.
//!
//! ```text
//! <dir>/MANIFEST.lvshard     routing + integrity (spec below)
//! <dir>/shard-000.leanvec    shard 0 snapshot
//! <dir>/shard-001.leanvec    shard 1 snapshot
//! ...
//! ```
//!
//! Each shard file is a standard snapshot (`docs/SNAPSHOT_FORMAT.md`):
//! live shards go through [`LiveIndex::save`]/[`LiveIndex::load`]
//! unchanged; a frozen shard with a non-identity external-id map is
//! written as a pristine live snapshot ([`FORMAT_VERSION_LIVE`] with an
//! all-zero `TOMBS` bitmap, the shard's `IDMAP`, and an empty `MUTLOG`)
//! — the id map *reshapes the meaning* of result ids, so a frozen-only
//! reader ([`LeanVecIndex::load`]) rejects the file loudly instead of
//! serving shard-local ids as if they were external. The identity
//! single-shard case writes a plain version-1 snapshot, byte-identical
//! to [`LeanVecIndex::save`].
//!
//! Manifest byte layout (all integers little-endian; full spec with a
//! worked example in `docs/SNAPSHOT_FORMAT.md`):
//!
//! ```text
//! magic "LVSHARD\0"                      8 bytes
//! manifest version u32                   currently 1
//! kind u8                                0 = frozen shards, 1 = live
//! shard count u32
//! hash seed u64                          routing-hash seed (ShardSpec)
//! per shard, in shard order:
//!   file name     u64 len + bytes        relative to the directory
//!   file crc32    u32                    CRC-32 of the whole shard file
//!   rows          u64                    row count (slots) in the shard
//! crc32 u32                              CRC-32 of all preceding bytes
//! ```
//!
//! Saving is byte-deterministic and save → load → save reproduces every
//! file exactly; the loaded index serves bit-identically (ids, scores,
//! [`QueryStats`]) because each shard file round-trips bit-identically
//! and the manifest restores the exact routing spec.
//!
//! [`LeanVecIndex::save`]: crate::index::LeanVecIndex::save
//! [`LeanVecIndex::load`]: crate::index::LeanVecIndex::load
//! [`LiveIndex::save`]: crate::mutate::LiveIndex
//! [`FORMAT_VERSION_LIVE`]: crate::index::persist::FORMAT_VERSION_LIVE
//! [`QueryStats`]: crate::index::query::QueryStats

use crate::data::io::{bin, crc32};
use crate::index::persist::{
    core_sections, force_mmap_requested, load_core_sections, load_mmap_any, read_sections_any,
    tag_str, write_sections_versioned, MetaFacts, MmapPolicy, RawSection, SnapshotError,
    SnapshotMeta, FORMAT_VERSION_LIVE, SECTION_IDMAP, SECTION_MUTLOG, SECTION_TOMBS,
};
use crate::index::leanvec_index::LeanVecIndex;
use crate::mutate::LiveIndex;
use crate::shard::sharded::{FrozenShard, ShardSet, ShardSpec, ShardedIndex};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// First 8 bytes of every shard manifest.
pub const MANIFEST_MAGIC: [u8; 8] = *b"LVSHARD\0";

/// Current manifest format version.
pub const MANIFEST_VERSION: u32 = 1;

/// Manifest file name inside a sharded snapshot directory.
pub const MANIFEST_NAME: &str = "MANIFEST.lvshard";

fn corrupt(what: impl Into<String>) -> SnapshotError {
    SnapshotError::Corrupt(what.into())
}

fn shard_file_name(i: usize) -> String {
    format!("shard-{i:03}.leanvec")
}

/// Write a frozen shard's snapshot. Identity id map -> plain version-1
/// file; non-identity -> pristine live layout stamped
/// [`FORMAT_VERSION_LIVE`] so version-1 readers reject it (see the
/// module docs).
fn save_frozen_shard(
    shard: &FrozenShard,
    path: &Path,
    meta: &SnapshotMeta,
) -> Result<u64, SnapshotError> {
    let ix: &LeanVecIndex = &shard.index;
    if shard.ext_of.is_empty() {
        return ix.save(path, meta);
    }
    let facts = MetaFacts {
        sim: ix.sim,
        projection: ix.model.kind,
        primary: ix.primary_compression,
        secondary: ix.secondary_compression,
        n: ix.len(),
        input_dim: ix.model.input_dim(),
        target_dim: ix.model.target_dim(),
        breakdown: ix.build_breakdown,
    };
    let mut sections = core_sections(
        meta,
        &facts,
        &ix.model,
        ix.primary.as_ref(),
        ix.secondary.as_ref(),
        &ix.graph,
    );
    let n = ix.len();
    // TOMBS: all-zero bitmap (nothing is deleted in a frozen shard)
    let mut tombs = Vec::new();
    bin::put_u64(&mut tombs, n as u64);
    let canonical = n.div_ceil(64);
    bin::put_u64(&mut tombs, canonical as u64);
    tombs.extend(std::iter::repeat(0u8).take(canonical * 8));
    // IDMAP: local slot -> external id
    let mut idmap = Vec::new();
    bin::put_u32s(&mut idmap, &shard.ext_of);
    // MUTLOG: zero counters, empty insert log
    let mut log = Vec::new();
    bin::put_u64(&mut log, 0);
    bin::put_u64(&mut log, 0);
    bin::put_u64(&mut log, 0);
    bin::put_u64(&mut log, 0);
    sections.push(RawSection::new(SECTION_TOMBS, tombs));
    sections.push(RawSection::new(SECTION_IDMAP, idmap));
    sections.push(RawSection::new(SECTION_MUTLOG, log));
    write_sections_versioned(path, &sections, FORMAT_VERSION_LIVE)
}

/// Load one frozen shard: a version-1 file is an identity-mapped shard;
/// a live-stamped file must be pristine (all-zero tombstones) and
/// contributes its `IDMAP` as the shard's external-id map. With
/// `mmap: Some(policy)` the shard's stores and graph serve straight off
/// a memory map of its file per the policy.
fn load_frozen_shard(
    path: &Path,
    mmap: Option<MmapPolicy>,
) -> Result<(Arc<LeanVecIndex>, Vec<u32>, SnapshotMeta), SnapshotError> {
    let (version, sections, index, meta) = match mmap {
        None => {
            let (version, sections) = read_sections_any(path)?;
            let (index, meta) = load_core_sections(&sections)?;
            (version, sections, index, meta)
        }
        Some(policy) => {
            // sections here are only the small live-layout extras
            // (TOMBS/IDMAP/MUTLOG) as owned copies; the core tiers stay
            // in the mapping
            let snap = load_mmap_any(path, policy, FORMAT_VERSION_LIVE)?;
            (snap.version, snap.extra, snap.index, snap.meta)
        }
    };
    if version < FORMAT_VERSION_LIVE {
        return Ok((Arc::new(index), Vec::new(), meta));
    }
    let find = |tag: [u8; 8]| -> Result<&[u8], SnapshotError> {
        sections
            .iter()
            .find(|s| s.tag == tag)
            .map(|s| s.bytes.as_slice())
            .ok_or_else(|| SnapshotError::MissingSection(tag_str(&tag)))
    };
    // a frozen manifest must never point at a file with tombstones or a
    // pending mutation log — that state belongs to a live shard set
    let mut cur = bin::Cursor::new(find(SECTION_TOMBS)?);
    let slots = cur.get_u64()? as usize;
    if slots != index.len() {
        return Err(corrupt(format!(
            "shard tombstone bitmap covers {slots} slots, stores hold {}",
            index.len()
        )));
    }
    let word_count = cur.get_u64()? as usize;
    for _ in 0..word_count {
        if cur.get_u64()? != 0 {
            return Err(corrupt(
                "frozen shard manifest points at a snapshot with tombstones",
            ));
        }
    }
    let mut cur = bin::Cursor::new(find(SECTION_IDMAP)?);
    let ext_of = cur.get_u32s()?;
    if ext_of.len() != index.len() || cur.remaining() != 0 {
        return Err(corrupt("shard id map length disagrees with stores"));
    }
    Ok((Arc::new(index), ext_of, meta))
}

impl ShardedIndex {
    /// Snapshot the whole sharded index into `dir`: one file per shard
    /// plus [`MANIFEST_NAME`] (see the module docs for the layout).
    /// Returns total bytes written. The directory is created if absent;
    /// shard files are written first, the manifest last (each write is
    /// atomic-by-rename), so a crash mid-save never leaves a manifest
    /// pointing at missing or truncated shards.
    pub fn save_dir(&self, dir: &Path, meta: &SnapshotMeta) -> Result<u64, SnapshotError> {
        std::fs::create_dir_all(dir).map_err(SnapshotError::Io)?;
        let spec = self.spec();
        let (kind, rows): (u8, Vec<u64>) = match self.set() {
            ShardSet::Frozen(shards) => (0, shards.iter().map(|s| s.index.len() as u64).collect()),
            ShardSet::Live(shards) => (1, shards.iter().map(|s| s.total_slots() as u64).collect()),
        };
        let mut total = 0u64;
        let mut entries: Vec<(String, u32, u64)> = Vec::with_capacity(spec.shards);
        for i in 0..spec.shards {
            let name = shard_file_name(i);
            let path = dir.join(&name);
            total += match self.set() {
                ShardSet::Frozen(shards) => save_frozen_shard(&shards[i], &path, meta)?,
                ShardSet::Live(shards) => shards[i].save(&path, meta)?,
            };
            // checksum the bytes as written: load_dir verifies the same
            // CRC before parsing, so shard-file bit rot (or a manifest
            // pointing at the wrong generation) is caught up front
            let bytes = std::fs::read(&path).map_err(SnapshotError::Io)?;
            entries.push((name, crc32(&bytes), rows[i]));
        }

        let mut m = Vec::new();
        m.extend_from_slice(&MANIFEST_MAGIC);
        bin::put_u32(&mut m, MANIFEST_VERSION);
        bin::put_u8(&mut m, kind);
        bin::put_u32(&mut m, spec.shards as u32);
        bin::put_u64(&mut m, spec.hash_seed);
        for (name, crc, n) in &entries {
            bin::put_bytes(&mut m, name.as_bytes());
            bin::put_u32(&mut m, *crc);
            bin::put_u64(&mut m, *n);
        }
        let trailer = crc32(&m);
        bin::put_u32(&mut m, trailer);

        // same atomic write discipline as the snapshot sections
        let path = dir.join(MANIFEST_NAME);
        let tmp = dir.join(format!("{MANIFEST_NAME}.tmp"));
        let write_all = || -> std::io::Result<()> {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&m)?;
            f.sync_all()?;
            Ok(())
        };
        if let Err(e) = write_all() {
            std::fs::remove_file(&tmp).ok();
            return Err(SnapshotError::Io(e));
        }
        std::fs::rename(&tmp, &path).map_err(SnapshotError::Io)?;
        Ok(total + m.len() as u64)
    }

    /// Load a sharded snapshot directory written by
    /// [`ShardedIndex::save_dir`]. The loaded index routes and serves
    /// bit-identically to the saved one. Returns the [`SnapshotMeta`]
    /// recorded with shard 0. Honors `LEANVEC_FORCE_MMAP` for frozen
    /// shard sets (same contract as [`LeanVecIndex::load`]).
    pub fn load_dir(dir: &Path) -> Result<(ShardedIndex, SnapshotMeta), SnapshotError> {
        let mmap = if force_mmap_requested() {
            Some(MmapPolicy::default())
        } else {
            None
        };
        Self::load_dir_with(dir, mmap)
    }

    /// [`ShardedIndex::load_dir`] with each frozen shard served off a
    /// memory map of its file per `policy` (see
    /// [`LeanVecIndex::load_mmap_with`]); `None` decodes everything to
    /// owned memory. Live shard sets always load owned — their arrays
    /// must be mutable — so the policy only applies to frozen
    /// directories. Any per-shard failure is wrapped in
    /// [`SnapshotError::Shard`] carrying the shard file's name.
    pub fn load_dir_with(
        dir: &Path,
        mmap: Option<MmapPolicy>,
    ) -> Result<(ShardedIndex, SnapshotMeta), SnapshotError> {
        let m = std::fs::read(dir.join(MANIFEST_NAME)).map_err(SnapshotError::Io)?;
        if m.len() < 8 || m[..8] != MANIFEST_MAGIC {
            return Err(SnapshotError::BadMagic);
        }
        if m.len() < 12 {
            return Err(SnapshotError::Truncated("shard manifest".into()));
        }
        let body = &m[..m.len() - 4];
        // fixed-width copy (the >= 12 length check above covers it)
        let mut w4 = [0u8; 4];
        w4.copy_from_slice(&m[m.len() - 4..]);
        let stored = u32::from_le_bytes(w4);
        if crc32(body) != stored {
            return Err(SnapshotError::ChecksumMismatch {
                section: "shard manifest".into(),
            });
        }
        let mut cur = bin::Cursor::new(&body[8..]);
        let version = cur.get_u32()?;
        if version == 0 || version > MANIFEST_VERSION {
            return Err(SnapshotError::UnsupportedVersion {
                found: version,
                supported: MANIFEST_VERSION,
            });
        }
        let kind = cur.get_u8()?;
        if kind > 1 {
            return Err(corrupt(format!("unknown shard kind {kind}")));
        }
        let count = cur.get_u32()? as usize;
        if count == 0 {
            return Err(corrupt("shard manifest lists zero shards"));
        }
        let hash_seed = cur.get_u64()?;
        let mut entries: Vec<(PathBuf, u32, u64)> = Vec::with_capacity(count);
        for _ in 0..count {
            let name_bytes = cur.get_bytes()?;
            let name = String::from_utf8(name_bytes)
                .map_err(|_| corrupt("shard file name is not UTF-8"))?;
            let crc = cur.get_u32()?;
            let n = cur.get_u64()?;
            entries.push((dir.join(name), crc, n));
        }
        if cur.remaining() != 0 {
            return Err(corrupt("trailing bytes in shard manifest"));
        }
        let spec = ShardSpec {
            shards: count,
            hash_seed,
        };

        // verify every shard file against its manifest CRC up front, so
        // a mixed-generation directory fails before anything is served
        for (path, crc, _) in &entries {
            let bytes = std::fs::read(path).map_err(SnapshotError::Io)?;
            if crc32(&bytes) != *crc {
                return Err(SnapshotError::ChecksumMismatch {
                    section: path
                        .file_name()
                        .map(|n| n.to_string_lossy().into_owned())
                        .unwrap_or_else(|| "shard file".into()),
                });
            }
        }

        // any failure past the manifest itself names the shard file it
        // came from — a 32-shard directory with one rotten file should
        // say which one to restore
        let shard_err = |path: &Path, e: SnapshotError| SnapshotError::Shard {
            file: path
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_else(|| path.display().to_string()),
            source: Box::new(e),
        };

        let mut meta0: Option<SnapshotMeta> = None;
        if kind == 0 {
            let mut parts = Vec::with_capacity(count);
            for (path, _, rows) in &entries {
                let (index, ext_of, meta) =
                    load_frozen_shard(path, mmap).map_err(|e| shard_err(path, e))?;
                if index.len() as u64 != *rows {
                    return Err(shard_err(
                        path,
                        corrupt(format!(
                            "shard holds {} rows, manifest says {rows}",
                            index.len()
                        )),
                    ));
                }
                if meta0.is_none() {
                    meta0 = Some(meta);
                }
                parts.push((index, ext_of));
            }
            Ok((
                ShardedIndex::from_frozen_parts(parts, spec),
                meta0.unwrap_or_default(),
            ))
        } else {
            let mut shards = Vec::with_capacity(count);
            for (path, _, rows) in &entries {
                let (live, meta) = LiveIndex::load(path).map_err(|e| shard_err(path, e))?;
                if live.total_slots() as u64 != *rows {
                    return Err(shard_err(
                        path,
                        corrupt(format!(
                            "shard holds {} slots, manifest says {rows}",
                            live.total_slots()
                        )),
                    ));
                }
                if meta0.is_none() {
                    meta0 = Some(meta);
                }
                shards.push(Arc::new(live));
            }
            Ok((
                ShardedIndex::from_live_shards(shards, spec),
                meta0.unwrap_or_default(),
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, ProjectionKind, Similarity};
    use crate::index::builder::IndexBuilder;
    use crate::index::query::{Query, VectorIndex};
    use crate::util::rng::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    fn configure(b: IndexBuilder) -> IndexBuilder {
        let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
        gp.max_degree = 12;
        gp.build_window = 30;
        b.projection(ProjectionKind::Id).target_dim(8).graph_params(gp)
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "leanvec-shard-{tag}-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn frozen_dir_roundtrip_serves_bit_identically() {
        let x = rows(500, 16, 21);
        let ix = ShardedIndex::build(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(4),
            1,
            configure,
        );
        let dir = tmp_dir("frozen");
        ix.save_dir(&dir, &SnapshotMeta::default()).unwrap();
        let (back, _meta) = ShardedIndex::load_dir(&dir).unwrap();
        assert_eq!(back.shards(), 4);
        assert_eq!(back.spec(), ix.spec());
        for probe in 0..10usize {
            let q = Query::new(&x[probe * 50]).k(10).window(40);
            let a = ix.search_one(&q);
            let b = back.search_one(&q);
            assert_eq!(a, b, "loaded sharded index must serve bit-identically");
        }
        // byte-determinism: re-saving the loaded index reproduces every
        // file, manifest included
        let dir2 = tmp_dir("frozen2");
        back.save_dir(&dir2, &SnapshotMeta::default()).unwrap();
        for i in 0..4 {
            let f1 = std::fs::read(dir.join(shard_file_name(i))).unwrap();
            let f2 = std::fs::read(dir2.join(shard_file_name(i))).unwrap();
            assert_eq!(f1, f2, "shard {i} re-save must be byte-identical");
        }
        assert_eq!(
            std::fs::read(dir.join(MANIFEST_NAME)).unwrap(),
            std::fs::read(dir2.join(MANIFEST_NAME)).unwrap()
        );
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn live_dir_roundtrip_preserves_mutation_state() {
        let x = rows(300, 16, 22);
        let ix = ShardedIndex::build_live(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(3),
            1,
            configure,
        );
        for id in 0..30u32 {
            ix.delete(id).unwrap();
        }
        let v = rows(1, 16, 23).pop().unwrap();
        ix.insert(900, &v).unwrap();
        let dir = tmp_dir("live");
        ix.save_dir(&dir, &SnapshotMeta::default()).unwrap();
        let (back, _meta) = ShardedIndex::load_dir(&dir).unwrap();
        assert!(back.is_live());
        assert_eq!(back.spec(), ix.spec());
        assert_eq!(VectorIndex::len(&back), 271);
        assert!(!back.contains(5), "deleted id must stay deleted after reload");
        assert!(back.contains(900), "inserted id must survive reload");
        for probe in [40usize, 120, 280] {
            let q = Query::new(&x[probe]).k(10).window(60);
            assert_eq!(ix.search_one(&q), back.search_one(&q));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_rejects_corruption_and_skew() {
        let x = rows(200, 16, 24);
        let ix = ShardedIndex::build(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(2),
            1,
            configure,
        );
        let dir = tmp_dir("corrupt");
        ix.save_dir(&dir, &SnapshotMeta::default()).unwrap();

        // flip a manifest byte -> checksum mismatch
        let mpath = dir.join(MANIFEST_NAME);
        let good = std::fs::read(&mpath).unwrap();
        let mut bad = good.clone();
        bad[10] ^= 0xFF;
        std::fs::write(&mpath, &bad).unwrap();
        assert!(matches!(
            ShardedIndex::load_dir(&dir),
            Err(SnapshotError::ChecksumMismatch { .. }) | Err(SnapshotError::UnsupportedVersion { .. })
        ));
        std::fs::write(&mpath, &good).unwrap();

        // flip a shard-file byte -> per-file CRC catches it before parse
        let spath = dir.join(shard_file_name(1));
        let sgood = std::fs::read(&spath).unwrap();
        let mut sbad = sgood.clone();
        let last = sbad.len() - 1;
        sbad[last] ^= 0xFF;
        std::fs::write(&spath, &sbad).unwrap();
        assert!(matches!(
            ShardedIndex::load_dir(&dir),
            Err(SnapshotError::ChecksumMismatch { .. })
        ));
        std::fs::write(&spath, &sgood).unwrap();

        // wrong magic -> BadMagic
        let mut nomagic = good.clone();
        nomagic[0] = b'X';
        std::fs::write(&mpath, &nomagic).unwrap();
        assert!(matches!(
            ShardedIndex::load_dir(&dir),
            Err(SnapshotError::BadMagic)
        ));
        std::fs::write(&mpath, &good).unwrap();

        // a missing shard file fails with Io
        std::fs::remove_file(&spath).unwrap();
        assert!(matches!(
            ShardedIndex::load_dir(&dir),
            Err(SnapshotError::Io(_))
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn frozen_reader_rejects_id_mapped_shard_file() {
        // a sharded (non-identity) shard file is stamped with the live
        // format version, so the frozen-only reader must refuse it
        let x = rows(200, 16, 25);
        let ix = ShardedIndex::build(
            &x,
            None,
            Similarity::InnerProduct,
            ShardSpec::new(2),
            1,
            configure,
        );
        let dir = tmp_dir("reject");
        ix.save_dir(&dir, &SnapshotMeta::default()).unwrap();
        let err = LeanVecIndex::load(&dir.join(shard_file_name(0))).unwrap_err();
        assert!(
            matches!(err, SnapshotError::UnsupportedVersion { found: 2, .. }),
            "got {err:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn single_identity_shard_writes_plain_v1_snapshot() {
        let x = rows(150, 16, 26);
        let single = Arc::new(configure(IndexBuilder::new()).build(
            &x,
            None,
            Similarity::InnerProduct,
        ));
        let dir = tmp_dir("single");
        // direct save of the same index for byte comparison
        std::fs::create_dir_all(&dir).unwrap();
        let direct = dir.join("direct.leanvec");
        single.save(&direct, &SnapshotMeta::default()).unwrap();
        let ix = ShardedIndex::from_single(single);
        ix.save_dir(&dir, &SnapshotMeta::default()).unwrap();
        assert_eq!(
            std::fs::read(dir.join(shard_file_name(0))).unwrap(),
            std::fs::read(&direct).unwrap(),
            "identity single shard must be byte-identical to LeanVecIndex::save"
        );
        // and the frozen-only reader accepts it
        assert!(LeanVecIndex::load(&dir.join(shard_file_name(0))).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
