//! Newton-Schulz polar iteration — the native mirror of the Layer-1
//! kernel in `python/compile/kernels/fw_step.py`.
//!
//! Computes the orthogonal polar factor `U V^T` of a (d, D) matrix,
//! which is exactly the Frank-Wolfe linear-minimization oracle over the
//! spectral-norm unit ball (Jaggi 2013). Matmul-only, so it matches the
//! AOT artifact bit-for-bit in structure (the tests cross-check both).

use super::matrix::Matrix;

/// Iterations matching NEWTON_SCHULZ_ITERS in the Pallas kernel.
pub const NEWTON_SCHULZ_ITERS: usize = 14;

/// Orthogonal polar factor of `c` (rows <= cols expected, as in the
/// (d, D) gradients). `X_{t+1} = 1.5 X_t - 0.5 (X_t X_t^T) X_t` starting
/// from `c / ||c||_F`, which keeps the spectrum in the convergence basin.
pub fn polar(c: &Matrix, iters: usize) -> Matrix {
    let norm = c.frobenius_norm().max(1e-30);
    let mut x = c.clone();
    x.scale(1.0 / norm);
    for _ in 0..iters {
        // Small side first: (d, d) Gram, then (d, D) product.
        let xxt = x.matmul_nt(&x);
        let xxtx = xxt.matmul(&x);
        x.lerp(&xxtx, 1.5, -0.5);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn result_has_orthonormal_rows() {
        let mut rng = Rng::new(1);
        for &(d, dd) in &[(4, 16), (12, 40), (24, 96)] {
            let c = Matrix::randn(d, dd, &mut rng);
            let p = polar(&c, NEWTON_SCHULZ_ITERS);
            assert!(
                p.row_orthonormality_defect() < 5e-3,
                "defect {} at ({d},{dd})",
                p.row_orthonormality_defect()
            );
        }
    }

    #[test]
    fn polar_of_orthonormal_is_self() {
        // rows of a rotation-ish matrix built by normalizing + deflating
        let mut rng = Rng::new(2);
        let c = Matrix::randn(6, 24, &mut rng);
        let q = polar(&c, 30); // converged orthonormal input
        let p = polar(&q, NEWTON_SCHULZ_ITERS);
        assert!(p.max_abs_diff(&q) < 1e-3);
    }

    #[test]
    fn is_linear_minimization_oracle() {
        // <polar(C), C> must be within 1% of the nuclear norm of C
        // (computed via eigh of C C^T).
        let mut rng = Rng::new(3);
        let c = Matrix::randn(10, 32, &mut rng);
        let p = polar(&c, 30);
        let align: f64 = p
            .data
            .iter()
            .zip(c.data.iter())
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        let gram = c.matmul_nt(&c);
        let (w, _) = crate::linalg::eigen::eigh(&gram);
        let nuclear: f64 = w.iter().map(|&x| (x.max(0.0) as f64).sqrt()).sum();
        assert!(align >= 0.99 * nuclear, "{align} vs {nuclear}");
    }

    #[test]
    fn zero_matrix_stays_zero() {
        // Documented behaviour: the NS oracle cannot escape a zero
        // gradient (unlike an SVD LMO); drivers must not init at zero.
        let z = Matrix::zeros(3, 8);
        let p = polar(&z, NEWTON_SCHULZ_ITERS);
        assert!(p.frobenius_norm() < 1e-6);
    }
}
