//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Jacobi is O(D^3) per sweep but embarrassingly stable and dependency-
//! free; at the D <= 1024 sizes used for `K_X`/`K_Q` it runs in well
//! under a second, and the accuracy (off-diagonal -> ~1e-7 * ||K||) is
//! far below the statistical noise of the sampled second moments.

use super::matrix::Matrix;

/// Full symmetric eigendecomposition. Returns `(eigenvalues, V)` with
/// eigenvalues sorted descending and the *columns* of `V` holding the
/// corresponding eigenvectors (`K = V diag(w) V^T`).
pub fn eigh(k: &Matrix) -> (Vec<f32>, Matrix) {
    assert_eq!(k.rows, k.cols, "eigh needs a square matrix");
    let n = k.rows;
    // f64 working copy for accuracy
    let mut a: Vec<f64> = k.data.iter().map(|&v| v as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let max_sweeps = 30;
    let eps = 1e-12f64;
    for _ in 0..max_sweeps {
        // total off-diagonal magnitude
        let mut off = 0.0f64;
        for r in 0..n {
            for c in r + 1..n {
                off += a[r * n + c] * a[r * n + c];
            }
        }
        let scale: f64 = (0..n).map(|i| a[i * n + i].abs()).sum::<f64>().max(eps);
        if off.sqrt() <= eps * scale {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = a[p * n + q];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // A <- J^T A J applied to rows/cols p and q
                for i in 0..n {
                    let aip = a[i * n + p];
                    let aiq = a[i * n + q];
                    a[i * n + p] = c * aip - s * aiq;
                    a[i * n + q] = s * aip + c * aiq;
                }
                for i in 0..n {
                    let api = a[p * n + i];
                    let aqi = a[q * n + i];
                    a[p * n + i] = c * api - s * aqi;
                    a[q * n + i] = s * api + c * aqi;
                }
                // V <- V J
                for i in 0..n {
                    let vip = v[i * n + p];
                    let viq = v[i * n + q];
                    v[i * n + p] = c * vip - s * viq;
                    v[i * n + q] = s * vip + c * viq;
                }
            }
        }
    }

    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (a[i * n + i], i)).collect();
    pairs.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());

    let w: Vec<f32> = pairs.iter().map(|&(val, _)| val as f32).collect();
    let mut vm = Matrix::zeros(n, n);
    for (col, &(_, src)) in pairs.iter().enumerate() {
        for r in 0..n {
            vm.data[r * n + col] = v[r * n + src] as f32;
        }
    }
    (w, vm)
}

/// The top-`d` eigenvectors of symmetric `k` as a **row-orthonormal
/// (d x D) projection matrix** (each row is an eigenvector), matching
/// the paper's `P in St(D, d)` convention.
pub fn top_eigvecs(k: &Matrix, d: usize) -> Matrix {
    let (_, v) = eigh(k);
    let n = k.rows;
    assert!(d <= n);
    let mut p = Matrix::zeros(d, n);
    for r in 0..d {
        for c in 0..n {
            p.data[r * n + c] = v.data[c * n + r]; // column r of V -> row r of P
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_symmetric(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let x = Matrix::randn(n * 3, n, &mut rng);
        x.second_moment() // PSD by construction
    }

    #[test]
    fn reconstructs_matrix() {
        let k = random_symmetric(12, 1);
        let (w, v) = eigh(&k);
        // K ?= V diag(w) V^T
        let mut vw = v.clone();
        for r in 0..12 {
            for c in 0..12 {
                vw.data[r * 12 + c] = v.at(r, c) * w[c];
            }
        }
        let rec = vw.matmul_nt(&v);
        assert!(k.max_abs_diff(&rec) < 1e-4, "{}", k.max_abs_diff(&rec));
    }

    #[test]
    fn eigenvalues_sorted_descending_and_psd() {
        let k = random_symmetric(10, 2);
        let (w, _) = eigh(&k);
        for i in 1..w.len() {
            assert!(w[i - 1] >= w[i] - 1e-6);
        }
        assert!(w.iter().all(|&x| x > -1e-5));
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let k = random_symmetric(9, 3);
        let (_, v) = eigh(&k);
        assert!(v.transpose().row_orthonormality_defect() < 1e-5);
    }

    #[test]
    fn diagonal_matrix_recovers_diagonal() {
        let mut k = Matrix::zeros(4, 4);
        for (i, val) in [4.0, 3.0, 2.0, 1.0].iter().enumerate() {
            k.set(i, i, *val);
        }
        let (w, v) = eigh(&k);
        assert_eq!(w, vec![4.0, 3.0, 2.0, 1.0]);
        // V is a signed permutation (here: identity up to sign)
        for i in 0..4 {
            assert!((v.at(i, i).abs() - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn top_eigvecs_capture_max_energy() {
        let k = random_symmetric(16, 4);
        let (w, _) = eigh(&k);
        let p = top_eigvecs(&k, 4);
        assert!(p.row_orthonormality_defect() < 1e-5);
        // Tr(P K P^T) == sum of top-4 eigenvalues
        let captured = p.matmul(&k).matmul_nt(&p).trace();
        let want: f32 = w[..4].iter().sum();
        assert!((captured - want).abs() < 1e-3, "{captured} vs {want}");
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1
        let k = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (w, _) = eigh(&k);
        assert!((w[0] - 3.0).abs() < 1e-6);
        assert!((w[1] - 1.0).abs() < 1e-6);
    }
}
