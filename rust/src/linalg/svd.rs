//! Thin SVD of wide (d x D) matrices via the eigendecomposition of the
//! small-side Gram matrix — all the learners ever need (spectral norms,
//! exact polar factors for validation, Prop. 1 bounds).

use super::eigen::eigh;
use super::matrix::Matrix;

/// Thin SVD `c = U diag(s) V^T` for `c` with `rows <= cols`.
pub struct SvdThin {
    /// (d x d) left singular vectors (columns)
    pub u: Matrix,
    /// singular values, descending
    pub s: Vec<f32>,
    /// (d x D): rows are the right singular vectors (i.e. V^T)
    pub vt: Matrix,
}

/// Compute the thin SVD through `eigh(c c^T)`:
/// `c c^T = U diag(s^2) U^T`, then `V^T = diag(1/s) U^T c`.
/// Singular values below `1e-6 * s_max` get their `vt` row replaced by
/// zeros (rank-deficient directions are never consumed by callers).
pub fn svd_thin(c: &Matrix) -> SvdThin {
    assert!(c.rows <= c.cols, "svd_thin expects a wide matrix");
    let d = c.rows;
    let gram = c.matmul_nt(c); // (d, d)
    let (w, u) = eigh(&gram);
    let s: Vec<f32> = w.iter().map(|&x| x.max(0.0).sqrt()).collect();
    let smax = s.first().copied().unwrap_or(0.0);

    // V^T = diag(1/s) U^T C
    let utc = u.matmul_tn(c); // (d, D)
    let mut vt = utc;
    for r in 0..d {
        let inv = if s[r] > 1e-6 * smax.max(1e-30) {
            1.0 / s[r]
        } else {
            0.0
        };
        for v in vt.row_mut(r) {
            *v *= inv;
        }
    }
    SvdThin { u, s, vt }
}

/// Spectral norm (largest singular value).
pub fn spectral_norm(c: &Matrix) -> f32 {
    if c.rows <= c.cols {
        svd_thin(c).s.first().copied().unwrap_or(0.0)
    } else {
        svd_thin(&c.transpose()).s.first().copied().unwrap_or(0.0)
    }
}

/// Exact polar factor `U V^T` (the SVD-based LMO used as the oracle the
/// Newton-Schulz kernel is validated against).
pub fn polar_exact(c: &Matrix) -> Matrix {
    let svd = svd_thin(c);
    svd.u.matmul(&svd.vt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::polar::polar;
    use crate::util::rng::Rng;

    #[test]
    fn reconstructs_input() {
        let mut rng = Rng::new(1);
        let c = Matrix::randn(6, 20, &mut rng);
        let svd = svd_thin(&c);
        // U diag(s) V^T
        let mut us = svd.u.clone();
        for r in 0..6 {
            for k in 0..6 {
                us.data[r * 6 + k] = svd.u.at(r, k) * svd.s[k];
            }
        }
        let rec = us.matmul(&svd.vt);
        assert!(c.max_abs_diff(&rec) < 1e-3, "{}", c.max_abs_diff(&rec));
    }

    #[test]
    fn singular_values_descending_nonneg() {
        let mut rng = Rng::new(2);
        let c = Matrix::randn(8, 30, &mut rng);
        let s = svd_thin(&c).s;
        for i in 1..s.len() {
            assert!(s[i - 1] >= s[i] - 1e-5);
            assert!(s[i] >= 0.0);
        }
    }

    #[test]
    fn orthonormal_factors() {
        let mut rng = Rng::new(3);
        let c = Matrix::randn(5, 17, &mut rng);
        let svd = svd_thin(&c);
        assert!(svd.u.row_orthonormality_defect() < 1e-4); // U square orthogonal
        assert!(svd.vt.row_orthonormality_defect() < 1e-4);
    }

    #[test]
    fn spectral_norm_of_orthonormal_is_one() {
        let mut rng = Rng::new(4);
        let q = crate::linalg::qr::random_orthonormal(6, 24, &mut rng);
        assert!((spectral_norm(&q) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn polar_exact_matches_newton_schulz() {
        let mut rng = Rng::new(5);
        let c = Matrix::randn(8, 24, &mut rng);
        let exact = polar_exact(&c);
        let ns = polar(&c, 30);
        assert!(exact.max_abs_diff(&ns) < 1e-2, "{}", exact.max_abs_diff(&ns));
    }

    #[test]
    fn known_diagonal_case() {
        // c = [[3, 0, 0], [0, 2, 0]] -> s = [3, 2]
        let c = Matrix::from_vec(2, 3, vec![3.0, 0.0, 0.0, 0.0, 2.0, 0.0]);
        let s = svd_thin(&c).s;
        assert!((s[0] - 3.0).abs() < 1e-5);
        assert!((s[1] - 2.0).abs() < 1e-5);
    }
}
