//! Thin QR via modified Gram-Schmidt (numerically adequate for the
//! random-init bases used by subspace iteration and the generators).

use super::matrix::{dot, normalize, Matrix};

/// Orthonormalize the columns of `a` (rows x cols, rows >= cols) with
/// modified Gram-Schmidt + one reorthogonalization pass. Returns Q with
/// the same shape; any numerically-dependent column is replaced by a
/// deterministic fresh direction and re-orthogonalized.
pub fn qr_orthonormal_columns(a: &Matrix) -> Matrix {
    let (n, k) = (a.rows, a.cols);
    assert!(n >= k, "need rows >= cols");
    // column-major working copy
    let mut cols: Vec<Vec<f32>> = (0..k)
        .map(|c| (0..n).map(|r| a.at(r, c)).collect())
        .collect();

    for j in 0..k {
        // two MGS passes for stability
        for _pass in 0..2 {
            for i in 0..j {
                // safety: cols[i] finished; project out
                let proj = dot(&cols[j], &cols[i]);
                let ci = cols[i].clone();
                for (x, y) in cols[j].iter_mut().zip(ci.iter()) {
                    *x -= proj * y;
                }
            }
        }
        let norm = normalize(&mut cols[j]);
        if norm < 1e-6 {
            // degenerate column: replace with canonical basis vector e_j
            // then orthogonalize again
            for (r, x) in cols[j].iter_mut().enumerate() {
                *x = if r == j % n { 1.0 } else { 0.0 };
            }
            for i in 0..j {
                let proj = dot(&cols[j], &cols[i]);
                let ci = cols[i].clone();
                for (x, y) in cols[j].iter_mut().zip(ci.iter()) {
                    *x -= proj * y;
                }
            }
            normalize(&mut cols[j]);
        }
    }

    let mut q = Matrix::zeros(n, k);
    for c in 0..k {
        for r in 0..n {
            q.set(r, c, cols[c][r]);
        }
    }
    q
}

/// A random row-orthonormal (d x D) matrix (e.g. FW initialization,
/// random-projection baseline in the Fig. 11 ablation).
pub fn random_orthonormal(d: usize, dd: usize, rng: &mut crate::util::rng::Rng) -> Matrix {
    let g = Matrix::randn(dd, d, rng);
    qr_orthonormal_columns(&g).transpose()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn columns_are_orthonormal() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(20, 7, &mut rng);
        let q = qr_orthonormal_columns(&a);
        assert!(q.transpose().row_orthonormality_defect() < 1e-5);
    }

    #[test]
    fn preserves_span_of_full_rank_input() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(10, 3, &mut rng);
        let q = qr_orthonormal_columns(&a);
        // every original column must be (nearly) in span(Q):
        // || a_j - Q Q^T a_j || ~ 0
        let qt = q.transpose();
        for j in 0..3 {
            let col: Vec<f32> = (0..10).map(|r| a.at(r, j)).collect();
            let coeffs = qt.matvec(&col);
            let rec = q.matvec(&coeffs);
            let err: f32 = col
                .iter()
                .zip(rec.iter())
                .map(|(x, y)| (x - y) * (x - y))
                .sum();
            assert!(err < 1e-6, "col {j}: {err}");
        }
    }

    #[test]
    fn handles_duplicate_columns() {
        let mut a = Matrix::zeros(6, 3);
        for r in 0..6 {
            a.set(r, 0, (r + 1) as f32);
            a.set(r, 1, (r + 1) as f32); // duplicate
            a.set(r, 2, if r == 0 { 1.0 } else { 0.0 });
        }
        let q = qr_orthonormal_columns(&a);
        assert!(q.transpose().row_orthonormality_defect() < 1e-4);
    }

    #[test]
    fn random_orthonormal_shape_and_defect() {
        let mut rng = Rng::new(3);
        let p = random_orthonormal(8, 32, &mut rng);
        assert_eq!((p.rows, p.cols), (8, 32));
        assert!(p.row_orthonormality_defect() < 1e-5);
    }
}
