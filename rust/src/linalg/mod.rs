//! Dense f32 linear algebra substrate (no external BLAS/LAPACK).
//!
//! Everything the LeanVec learners need: row-major matrices, blocked
//! matmul, Gram/second-moment accumulation, a cyclic-Jacobi symmetric
//! eigensolver, thin SVD, QR, and the Newton-Schulz polar iteration
//! mirrored from the Layer-1 Pallas kernel (used as the native fallback
//! when a PJRT artifact for the shape is not available).

pub mod eigen;
pub mod matrix;
pub mod polar;
pub mod qr;
pub mod svd;

pub use eigen::{eigh, top_eigvecs};
pub use matrix::Matrix;
pub use polar::polar;
pub use qr::qr_orthonormal_columns;
pub use svd::{svd_thin, SvdThin};
