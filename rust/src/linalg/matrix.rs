//! Row-major dense f32 matrix with the operations the learners need.

use crate::util::rng::Rng;

/// Row-major dense matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// Standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        Matrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.gaussian_f32()).collect(),
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Blocked matmul `self * other`, f32 with per-row f64-free kahan-less
    /// accumulation (adequate at the sizes used; validated against the
    /// PJRT artifacts in tests). Inner loops are written for
    /// autovectorization: contiguous slices, no bounds checks in the hot
    /// loop.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        // i-k-j loop order: out_row += a[i][k] * b_row[k], streaming b.
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for (kk, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self * other^T` without materializing the transpose.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let b_row = &other.data[j * k..(j + 1) * k];
                out.data[i * n + j] = dot(a_row, b_row);
            }
        }
        out
    }

    /// `self^T * other` without materializing the transpose.
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let (m, k, n) = (self.cols, self.rows, other.cols);
        let mut out = Matrix::zeros(m, n);
        for kk in 0..k {
            let a_row = &self.data[kk * m..(kk + 1) * m];
            let b_row = &other.data[kk * n..(kk + 1) * n];
            for (i, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Matrix-vector product `self * v`.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, v.len());
        (0..self.rows).map(|r| dot(self.row(r), v)).collect()
    }

    /// Second-moment (Gram) matrix of row-vectors: `self^T self / rows`.
    ///
    /// Rows of `self` are data points (n x D) — this is the `K_X`/`K_Q`
    /// of Eq. (8), normalized by the sample count.
    pub fn second_moment(&self) -> Matrix {
        let mut k = self.matmul_tn(self);
        let inv = 1.0 / self.rows.max(1) as f32;
        for v in k.data.iter_mut() {
            *v *= inv;
        }
        k
    }

    pub fn scale(&mut self, s: f32) -> &mut Self {
        for v in self.data.iter_mut() {
            *v *= s;
        }
        self
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    /// `self = alpha*self + beta*other`.
    pub fn lerp(&mut self, other: &Matrix, alpha: f32, beta: f32) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a = alpha * *a + beta * b;
        }
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn trace(&self) -> f32 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.at(i, i) as f64).sum::<f64>() as f32
    }

    /// `Tr(self * other)` computed as sum(self .* other^T) — O(n^2).
    pub fn trace_product(&self, other: &Matrix) -> f64 {
        assert_eq!(self.cols, other.rows);
        assert_eq!(self.rows, other.cols);
        let mut acc = 0.0f64;
        for i in 0..self.rows {
            for j in 0..self.cols {
                acc += self.at(i, j) as f64 * other.at(j, i) as f64;
            }
        }
        acc
    }

    /// Max |a - b| over entries.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// `|| self * self^T - I ||_max` — orthonormality defect of rows.
    pub fn row_orthonormality_defect(&self) -> f32 {
        let g = self.matmul_nt(self);
        let mut worst = 0.0f32;
        for i in 0..g.rows {
            for j in 0..g.cols {
                let target = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((g.at(i, j) - target).abs());
            }
        }
        worst
    }
}

/// Dot product through the dispatched f32 kernel
/// ([`crate::simd::dot_f32`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    // dispatched kernel (AVX2+FMA when the host has it; the scalar
    // fallback is the historical 8-way-unrolled loop, bit-identical)
    crate::simd::dot_f32(a, b)
}

/// Euclidean distance squared.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// In-place L2 normalization; returns the original norm.
pub fn normalize(v: &mut [f32]) -> f32 {
    let norm = dot(v, v).sqrt();
    if norm > 0.0 {
        let inv = 1.0 / norm;
        for x in v.iter_mut() {
            *x *= inv;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: &Matrix, b: &Matrix, tol: f32) {
        assert!(
            a.max_abs_diff(b) < tol,
            "matrices differ by {}",
            a.max_abs_diff(b)
        );
    }

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(13, 29, &mut rng);
        let b = Matrix::randn(29, 17, &mut rng);
        let direct = a.matmul(&b);
        approx(&a.matmul_nt(&b.transpose()), &direct, 1e-4);
        approx(&a.transpose().matmul_tn(&b), &direct, 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(8, 8, &mut rng);
        approx(&a.matmul(&Matrix::eye(8)), &a, 1e-6);
        approx(&Matrix::eye(8).matmul(&a), &a, 1e-6);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(5, 9, &mut rng);
        let v = Matrix::randn(9, 1, &mut rng);
        let mv = a.matvec(&v.data);
        let mm = a.matmul(&v);
        for i in 0..5 {
            assert!((mv[i] - mm.data[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn second_moment_is_symmetric_psd_diag() {
        let mut rng = Rng::new(4);
        let x = Matrix::randn(50, 7, &mut rng);
        let k = x.second_moment();
        for i in 0..7 {
            assert!(k.at(i, i) > 0.0);
            for j in 0..7 {
                assert!((k.at(i, j) - k.at(j, i)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn trace_product_matches_matmul_trace() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(6, 9, &mut rng);
        let b = Matrix::randn(9, 6, &mut rng);
        let direct = a.matmul(&b).trace() as f64;
        assert!((a.trace_product(&b) - direct).abs() < 1e-3);
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(6);
        for n in [0, 1, 7, 8, 9, 31, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn normalize_unit_norm() {
        let mut v = vec![3.0, 4.0];
        let norm = normalize(&mut v);
        assert_eq!(norm, 5.0);
        assert!((dot(&v, &v) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn l2_sq_known() {
        assert_eq!(l2_sq(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn orthonormality_defect_of_identity_is_zero() {
        assert!(Matrix::eye(5).row_orthonormality_defect() < 1e-7);
    }
}
